"""End-to-end serving driver: batched requests through the tiered-KV engine.

The engine decodes against software-defined compressed KV tiers (warm int8 /
cold int4 device pools + host tiers), with per-page attention-mass telemetry
feeding the TierScape analytical placement model every window. Prints the
paper's metrics: TCO savings, placement distribution, migrations, daemon tax.

    PYTHONPATH=src python examples/serve_tiered_kv.py --requests 4
"""

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.configs.base import TierScapeRunConfig
from repro.models import Model
from repro.serving import TieredEngine
from repro.serving.kv_cache import COLD, HOST4, HOST8, WARM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2_1_2b",
                    help="any smoke arch with attention")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--alpha", type=float, default=0.3,
                    help="TierScape knob: 1=perf, 0=max TCO savings")
    ap.add_argument("--policy", default="analytical",
                    choices=["analytical", "waterfall"])
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--serial-migration", action="store_true",
                    help="opt back into blocking window boundaries (async "
                         "overlapped migration is the default; this runs "
                         "the serial equivalence oracle instead)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable speculative staging of warming host pages "
                         "(prefetch is the default now that the fused decode "
                         "kernel feeds the predictor in-engine; it is a "
                         "no-op anyway with --serial-migration)")
    ap.add_argument("--vary-prompts", action="store_true",
                    help="submit unequal prompt lengths (per-slot decode)")
    args = ap.parse_args()
    prefetch = not args.no_prefetch and not args.serial_migration

    cfg = configs.get_smoke(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = TieredEngine(
        model, params,
        batch_slots=args.slots, page_tokens=8,
        max_seq_len=args.prompt_len + args.new_tokens + 32,
        recent_window=16,
        ts=TierScapeRunConfig(enabled=True, policy=args.policy,
                              alpha=args.alpha, window_steps=8,
                              async_migration=not args.serial_migration,
                              prefetch=prefetch),
    )

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = args.prompt_len
        if args.vary_prompts:  # per-slot lengths: each request its own size
            plen = max(args.prompt_len - 8 * (i % args.slots), 8)
        reqs.append(eng.submit(rng.integers(1, cfg.vocab_size, plen),
                               max_new_tokens=args.new_tokens))

    t0 = time.time()
    stats = eng.run(max_steps=args.requests * args.new_tokens * 2)
    wall = time.time() - t0

    print(f"arch={args.arch} policy={args.policy} alpha={args.alpha}")
    print(f"completed {stats.completed}/{args.requests} requests in "
          f"{stats.steps} engine steps ({wall:.1f}s wall)")
    print(f"windows={stats.windows} migrations={stats.migrations} "
          f"daemon_s={stats.daemon_s:.2f} overlapped_steps={stats.overlapped_steps}")
    if prefetch:
        print(f"prefetch: staged={stats.prefetch_staged} "
              f"hits={stats.prefetch_hits} misses={stats.prefetch_misses}")
    print(f"attn launches: {stats.attn_launches} "
          f"({stats.attn_launches / max(stats.steps, 1):.0f}/step, fused)")
    busy = {d: round(s * 1e6, 2)
            for d, s in eng.cache.pipeline.media_busy_s().items() if s > 0}
    if busy:
        print(f"media busy (us, executed): {busy}")
    pl = eng.cache.manager.placement[eng.cache._page_exists]
    hist = np.bincount(pl, minlength=5)
    names = {0: "dram", WARM: "warm-int8-hbm", COLD: "cold-int4-hbm",
             HOST8: "host-int8", HOST4: "host-int4"}
    live = ", ".join(f"{names[i]}={hist[i]}" for i in range(5) if hist[i])
    print("live page placement:", live or "(all requests done; pages freed)")
    print(f"peak KV memory TCO savings vs uncompressed HBM: "
          f"{stats.tco_savings_pct:.1f}%")
    for r in reqs[:2]:
        print(f"req{r.rid}: {r.out_tokens[:12]}...")


if __name__ == "__main__":
    main()
