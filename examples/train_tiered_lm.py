"""End-to-end training driver: a small dense LM trained for a few hundred
steps with the full production stack —

  * synthetic packed corpus via the sharded HostLoader (prefetch +
    straggler mitigation),
  * TierScape tiered optimizer state: embedding/lm_head Adam moments live
    in an int8 compressed tier (µ-law dynamic code) — the paper's
    warm-data-compression idea applied to training state,
  * cosine schedule + global-norm clipping,
  * atomic async checkpointing with resume,
  * (optional) int8 error-feedback gradient compression, exercising the
    cross-pod wire format.

Defaults are CPU-friendly (~25M params, 200 steps). Scale up with flags on
real hardware (e.g. --d-model 768 --layers 12 for ~100M).

    PYTHONPATH=src python examples/train_tiered_lm.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, HostLoader
from repro.models import Model
from repro.optim import adamw, grad_compress, tiered_adam
from repro.optim.adamw import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 error-feedback roundtrip on gradients")
    ap.add_argument("--moment-codec", default="int8",
                    choices=["none", "bf16", "int8", "int4"])
    args = ap.parse_args()

    cfg = ModelConfig(
        name="tiered_lm", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 2),
        n_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 4, vocab_size=args.vocab, act="swiglu",
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L x {cfg.d_model}d, vocab {cfg.vocab_size})")

    policy = tiered_adam.default_policy(params, cold_codec=args.moment_codec)
    opt_state = tiered_adam.init(params, policy)
    f32_bytes = sum(x.size * 8 for x in jax.tree.leaves(params))  # m+v f32
    print(f"optimizer moments: {tiered_adam.moment_bytes(opt_state)/1e6:.1f}MB "
          f"(f32 baseline {f32_bytes/1e6:.1f}MB) — embeddings in the "
          f"{args.moment_codec} tier")

    opt_cfg = AdamWConfig(lr=args.lr, schedule=adamw.cosine_schedule(20, args.steps))
    residual = grad_compress.init_residual(params) if args.grad_compress else None

    @jax.jit
    def train_step(params, opt_state, resid, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        if resid is not None:
            flat_g, treedef = jax.tree.flatten(grads)
            flat_r = treedef.flatten_up_to(resid)
            out = [grad_compress.compress_roundtrip(g.astype(jnp.float32) + r)
                   for g, r in zip(flat_g, flat_r)]
            grads = jax.tree.unflatten(treedef, [o[0] for o in out])
            resid = jax.tree.unflatten(treedef, [o[1] for o in out])
        params, opt_state, om = tiered_adam.update(grads, opt_state, params, opt_cfg)
        return params, opt_state, resid, loss, om["grad_norm"]

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, restored = ckpt.restore({"params": params})
        params = restored["params"]
        print(f"resumed from step {start}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    loader = HostLoader(data_cfg, start_step=start)

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch_np = next(loader)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, residual, loss, gnorm = train_step(
            params, opt_state, residual, batch)
        losses.append(float(loss))
        if (step + 1) % 20 == 0:
            rate = (step + 1 - start) / (time.time() - t0)
            print(f"step {step+1:4d} loss {np.mean(losses[-20:]):.4f} "
                  f"gnorm {float(gnorm):.2f} ({rate:.2f} steps/s, "
                  f"stragglers {loader.straggler_events})")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params}, blocking=False)
    ckpt.wait()
    loader.close()
    print(f"final loss {np.mean(losses[-20:]):.4f} "
          f"(from {np.mean(losses[:20]):.4f}); checkpoints in {args.ckpt_dir}")
    assert np.mean(losses[-20:]) < np.mean(losses[:20]), "training must descend"


if __name__ == "__main__":
    main()
