"""Quickstart: TierScape in two minutes.

1. Characterize the 12 software-defined compressed tiers (codec x pool x
   media) — the paper's Fig. 3.
2. Run the window simulator: DRAM + 1 compressed tier (the 2-Tier
   state-of-the-art) vs DRAM + 5 tiers under waterfall and analytical
   placement — the paper's Fig. 8 headline.

    PYTHONPATH=src python examples/quickstart.py
"""


from repro.core import simulator, tiers
from repro.core.manager import make_manager

REGION = 1 << 20  # 2MB region / 2B per element


def main() -> None:
    print("== The 12 characterized software-defined compressed tiers ==")
    print(f"{'id':4s} {'name':10s} {'ratio':>6s} {'lat(2MB) us':>12s} {'USD/GB':>7s}")
    for t in tiers.characterized():
        print(
            f"{t.tid:4s} {t.name:10s} {t.effective_ratio(REGION):6.2f} "
            f"{t.access_latency_s(REGION) * 1e6:12.1f} "
            f"{t.usd_per_source_byte(REGION) * (1 << 30):7.2f}"
        )
    print("\nselected (paper Table 2 analogue):",
          ", ".join(t.tid + ":" + t.name for t in tiers.selected()))

    print("\n== 2-Tier vs TierScape on a Memcached-like workload ==")
    wl = simulator.gaussian_kv(n_regions=2048, accesses_per_window=500_000)
    thresholds = {"C": 50.0, "M": 200.0, "A": 800.0}
    print(f"{'config':12s} {'slowdown %':>10s} {'TCO saved %':>11s} {'p99 us':>8s} {'tax %':>6s}")
    for cfg in ("2T-C", "2T-M", "2T-A", "6T-WF-C", "6T-WF-M", "6T-WF-A",
                "6T-AM-0.9", "6T-AM-0.5", "6T-AM-0.1"):
        mgr = make_manager(cfg, wl.n_regions, thresholds=thresholds)
        r = simulator.simulate(wl, mgr, windows=20, seed=1)
        print(f"{cfg:12s} {r.slowdown_pct:10.2f} {r.tco_savings_pct:11.2f} "
              f"{r.p99_access_us:8.2f} {r.daemon_tax_pct:6.2f}")
    print("\nN-Tier saves 10-20pp more memory TCO than 2-Tier at equal or "
          "better slowdown — the paper's Fig. 8 claim.")


if __name__ == "__main__":
    main()
