"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

Tolerances: dequantized values may differ by at most one quantization step
(jit reciprocal-multiply vs eager divide flips round-to-nearest ties); the
attention partials are compared at f32 accumulation tolerance.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.dequant_page import dequant_pages
from repro.kernels.paged_attention import paged_quant_attention
from repro.kernels.quant_page import quant_pages
from repro.kernels.transcode_page import transcode_pages

from proptest import cases, draw_choice, draw_log_float


def _pages(rng, p, t, kv, hd, dtype=jnp.bfloat16, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, (p, t, kv, hd)), dtype)


SWEEP = [
    # (P, T, KV, HD)
    (4, 8, 1, 32),
    (4, 16, 4, 64),
    (8, 32, 2, 128),
    (2, 64, 8, 128),
]


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_quant_dequant_vs_ref(shape, bits, dtype):
    rng = np.random.default_rng(42)
    pages = _pages(rng, *shape, dtype=dtype)
    pay_k, sc_k = quant_pages(pages, bits)
    pay_r, sc_r = ref.quant_kv_page(pages, bits)
    np.testing.assert_allclose(np.asarray(sc_k), np.asarray(sc_r), rtol=1e-6)
    deq_k = dequant_pages(pay_k, sc_k, bits, jnp.float32)
    deq_r = ref.dequant_kv_page(pay_r, sc_r, bits)
    # <= 1 quantization step anywhere; >98% identical payloads.
    step = np.asarray(sc_r).max() * (1.0 if bits == 8 else 1.0)
    np.testing.assert_allclose(np.asarray(deq_k), np.asarray(deq_r), atol=step + 1e-6)
    mismatch = (np.asarray(pay_k) != np.asarray(pay_r)).mean()
    assert mismatch < 0.02, mismatch


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize("bits", [8, 4])
def test_quant_roundtrip_error_bound(shape, bits):
    rng = np.random.default_rng(0)
    pages = _pages(rng, *shape, dtype=jnp.float32)
    pay, sc = ref.quant_kv_page(pages, bits)
    deq = ref.dequant_kv_page(pay, sc, bits)
    rel = np.linalg.norm(np.asarray(deq - pages)) / np.linalg.norm(np.asarray(pages))
    assert rel < (0.012 if bits == 8 else 0.12), rel


@pytest.mark.parametrize("kv,heads", [(1, 4), (2, 8), (4, 4), (8, 16)])
@pytest.mark.parametrize("bits", [8, 4])
def test_paged_attention_vs_ref(kv, heads, bits):
    rng = np.random.default_rng(7)
    P, T, HD, B, MP = 6, 16, 64, 3, 4
    pages = _pages(rng, P, T, kv, HD)
    kp, ks = ref.quant_kv_page(pages, bits)
    vp, vs = ref.quant_kv_page(pages * 0.3, bits)
    q = jnp.asarray(rng.normal(0, 1, (B, heads, HD)), jnp.float32)
    table = jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32)
    n_pages = jnp.asarray([MP, 1, 0], jnp.int32)
    out_k = paged_quant_attention(q, kp, ks, vp, vs, table, n_pages, bits)
    out_r = ref.paged_quant_attention(q, kp, ks, vp, vs, table, n_pages, bits)
    for name, a, b in zip(["out", "m", "l", "mass", "base"], out_k, out_r):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4, err_msg=name
        )


def test_merge_partials_matches_monolithic_softmax():
    """Splitting a KV set into pools + merging partials == one softmax."""
    rng = np.random.default_rng(3)
    B, H, HD, S = 2, 4, 32, 64
    q = jnp.asarray(rng.normal(0, 1, (B, H, HD)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, HD)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, HD)), jnp.float32)
    full = ref.dense_recent_attention(q, k, v, S)
    out_full = full[0] / jnp.maximum(full[2], 1e-30)[..., None]
    p1 = ref.dense_recent_attention(q, k[:, :32], v[:, :32], 32)
    p2 = ref.dense_recent_attention(q, k[:, 32:], v[:, 32:], 32)
    merged = ref.merge_partials([p1, p2])
    np.testing.assert_allclose(np.asarray(merged), np.asarray(out_full), rtol=1e-5, atol=1e-5)


def test_tiered_decode_attention_quality():
    """Tiered (int8 warm + int4 cold) output stays close to exact bf16."""
    rng = np.random.default_rng(11)
    B, H, KV, HD, T = 2, 8, 4, 64, 16
    n_warm, n_cold, R = 4, 4, 8
    S = (n_warm + n_cold) * T + R

    k_full = jnp.asarray(rng.normal(0, 1, (B, S, KV, HD)), jnp.float32)
    v_full = jnp.asarray(rng.normal(0, 1, (B, S, KV, HD)), jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (B, H, HD)), jnp.float32)

    pools = {}
    for name, bits, lo, hi in (("warm", 8, 0, n_warm), ("cold", 4, n_warm, n_warm + n_cold)):
        kp_list, vp_list = [], []
        for b in range(B):
            for p in range(lo, hi):
                sl = slice(p * T, (p + 1) * T)
                kp_list.append(k_full[b, sl])
                vp_list.append(v_full[b, sl])
        kp, ks = ref.quant_kv_page(jnp.stack(kp_list), bits)
        vp, vs = ref.quant_kv_page(jnp.stack(vp_list), bits)
        n = hi - lo
        table = jnp.asarray([[b * n + i for i in range(n)] for b in range(B)], jnp.int32)
        pools[name] = dict(k_pages=kp, k_scales=ks, v_pages=vp, v_scales=vs,
                           page_table=table, n_pages=jnp.full((B,), n, jnp.int32), bits=bits)

    recent_k = k_full[:, -R:]
    recent_v = v_full[:, -R:]
    out_tiered = ops.tiered_decode_attention(q, pools, recent_k, recent_v, R)
    exact = ref.dense_recent_attention(q, k_full, v_full, S)
    out_exact = exact[0] / jnp.maximum(exact[2], 1e-30)[..., None]
    rel = float(jnp.linalg.norm(out_tiered - out_exact) / jnp.linalg.norm(out_exact))
    # int4 absmax on N(0,1) data has ~11% elementwise error (worst case for
    # the cold tier); real KV distributions are smoother (see fig3 bench).
    assert rel < 0.12, rel


def test_telemetry_hotness_sums_to_one():
    """Normalized page hotness + recent-window share == full softmax mass."""
    rng = np.random.default_rng(5)
    B, H, KV, HD, T, P, MP, R = 2, 4, 2, 32, 8, 6, 4, 4
    pages = _pages(rng, P, T, KV, HD)
    kp, ks = ref.quant_kv_page(pages, 8)
    vp, vs = ref.quant_kv_page(pages, 8)
    pools = {"warm": dict(k_pages=kp, k_scales=ks, v_pages=vp, v_scales=vs,
                          page_table=jnp.asarray(rng.integers(0, P, (B, MP)), jnp.int32),
                          n_pages=jnp.full((B,), MP, jnp.int32), bits=8)}
    recent_k = _pages(rng, 1, R, KV, HD)[0][None].repeat(B, 0).astype(jnp.float32)
    recent_v = recent_k
    q = jnp.asarray(rng.normal(0, 1, (B, H, HD)), jnp.float32)
    out, hot = ops.tiered_decode_attention(q, pools, recent_k, recent_v, R, with_telemetry=True)
    mass = np.asarray(hot["warm"]).sum(axis=1)
    assert (mass > 0).all() and (mass <= 1.0 + 1e-5).all()


def test_quant_property_randomized():
    for i, rng in cases(50):
        bits = draw_choice(rng, [8, 4])
        pages = _pages(rng, 2, 8, 2, 32, dtype=jnp.float32,
                       scale=draw_log_float(rng, 0.1, 10))
        pay, sc = ref.quant_kv_page(pages, bits)
        deq = ref.dequant_kv_page(pay, sc, bits)
        # Per-element error bounded by its group scale (one quantization step).
        err = np.abs(np.asarray(deq - pages))
        bound = np.asarray(sc)[..., None] * 0.51 + 1e-7
        assert (err <= bound).all(), (i, bits)


# ---------------------------------------------------------------------------
# fused transcode kernel (the batched migration path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize("route", [(8, 4), (4, 8)])
def test_transcode_pages_vs_ref_composition(shape, route):
    """Fused transcode == dequant -> requant composition, interpret mode."""
    src_bits, dst_bits = route
    rng = np.random.default_rng(21)
    pages = _pages(rng, *shape, dtype=jnp.float32)
    pay, sc = ref.quant_kv_page(pages, src_bits)
    k_pay, k_sc = transcode_pages(pay, sc, src_bits, dst_bits)
    r_pay, r_sc = ref.quant_kv_page(ref.dequant_kv_page(pay, sc, src_bits), dst_bits)
    np.testing.assert_allclose(np.asarray(k_sc), np.asarray(r_sc), rtol=1e-6)
    # Payloads may differ only where a round-to-nearest tie flips: bound the
    # dequantized disagreement by one quantization step of the new scale.
    deq_k = ref.dequant_kv_page(k_pay, k_sc, dst_bits)
    deq_r = ref.dequant_kv_page(r_pay, r_sc, dst_bits)
    step = np.asarray(r_sc).max()
    np.testing.assert_allclose(np.asarray(deq_k), np.asarray(deq_r), atol=step + 1e-6)
    mismatch = (np.asarray(k_pay) != np.asarray(r_pay)).mean()
    assert mismatch < 0.02, mismatch


@pytest.mark.parametrize("route", [(8, 4), (4, 8)])
def test_transcode_pages_ops_dispatch(route):
    """ops.transcode_pages: pallas and ref backends agree; same-width is
    the identity (the same-codec fast path never transcodes)."""
    src_bits, dst_bits = route
    rng = np.random.default_rng(5)
    pages = _pages(rng, 3, 8, 2, 32, dtype=jnp.float32)
    pay, sc = ref.quant_kv_page(pages, src_bits)
    try:
        ops.use_pallas(False)
        rp, rs = ops.transcode_pages(pay, sc, src_bits, dst_bits)
    finally:
        ops.use_pallas(True)
    kp, ks = ops.transcode_pages(pay, sc, src_bits, dst_bits)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), rtol=1e-6)
    ip, isc = ops.transcode_pages(pay, sc, src_bits, src_bits)
    assert ip is pay and isc is sc


def test_transcode_roundtrip_error_bounded():
    """int8 -> int4 -> int8 stays within int4 quantization error of the
    int8 dequant (migrating down and back must not compound losses)."""
    for i, rng in cases(50):
        pages = _pages(rng, 2, 8, 2, 32, dtype=jnp.float32,
                       scale=draw_log_float(rng, 0.1, 10))
        pay8, sc8 = ref.quant_kv_page(pages, 8)
        x8 = np.asarray(ref.dequant_kv_page(pay8, sc8, 8))
        pay4, sc4 = transcode_pages(pay8, sc8, 8, 4)
        pay8b, sc8b = transcode_pages(pay4, sc4, 4, 8)
        x8b = np.asarray(ref.dequant_kv_page(pay8b, sc8b, 8))
        bound = np.asarray(sc4)[..., None] * 0.51 + np.asarray(sc8b)[..., None] * 0.51 + 1e-6
        assert (np.abs(x8b - x8) <= bound).all(), i


def test_paged_attention_slot_pos_equivalence():
    """Explicit slot positions (SP shards pass these) == default iota."""
    rng = np.random.default_rng(9)
    P_, T, KV, HD, B, MP = 5, 8, 2, 32, 2, 4
    pages = _pages(rng, P_, T, KV, HD)
    kp, ks = ref.quant_kv_page(pages, 8)
    vp, vs = ref.quant_kv_page(pages, 8)
    q = jnp.asarray(rng.normal(0, 1, (B, 4, HD)), jnp.float32)
    table = jnp.asarray(rng.integers(0, P_, (B, MP)), jnp.int32)
    n = jnp.asarray([3, 2], jnp.int32)
    base = ref.paged_quant_attention(q, kp, ks, vp, vs, table, n, 8)
    pos = jnp.broadcast_to(jnp.arange(MP, dtype=jnp.int32)[None], (B, MP))
    with_pos = ref.paged_quant_attention(q, kp, ks, vp, vs, table, n, 8, slot_pos=pos)
    for a, b in zip(base, with_pos):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # Shifted positions change validity (SP shard with offset slots).
    pos2 = pos + 2
    shifted = ref.paged_quant_attention(q, kp, ks, vp, vs, table, n, 8, slot_pos=pos2)
    assert float(shifted[2].sum()) < float(base[2].sum())  # fewer valid slots
