"""Single-launch fused tiered attention vs the per-pool oracle.

Covers the megakernel contract: fused == per-pool == pure-jnp ref for both
outputs and normalized page hotness (fp32 tolerance) across mixed int8/int4
pools; exactly one Pallas launch per decode step independent of tier count;
empty-pool and all-host-pages edge cases; host sentinel would-have-touched
mass matching the ref oracle; the in-engine host-mass route into the
prefetch predictor; and placement neutrality of the host telemetry.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref

B, H, KV, HD, T, R = 2, 8, 2, 32, 8, 6
TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(autouse=True)
def _restore_ops_toggles():
    yield
    ops.use_pallas(True)
    ops.use_fused(True)


def _mk_pool(rng, n_pages, bits, mp, n_valid):
    pages = jnp.asarray(rng.normal(0, 1, (n_pages, T, KV, HD)), jnp.bfloat16)
    kp, ks = ref.quant_kv_page(pages, bits)
    vp, vs = ref.quant_kv_page(pages * 0.5, bits)
    return dict(
        k_pages=kp, k_scales=ks, v_pages=vp, v_scales=vs,
        page_table=jnp.asarray(rng.integers(0, n_pages, (B, mp)), jnp.int32),
        n_pages=jnp.asarray(n_valid, jnp.int32), bits=bits,
    )


def _mk_host(rng, hs=5, mp=3, n=(2, 3), page_tokens=T):
    return dict(
        summary=jnp.asarray(rng.normal(0, 1, (hs, KV, HD)), jnp.float32),
        table=jnp.asarray(rng.integers(0, hs, (B, mp)), jnp.int32),
        n=jnp.asarray(n, jnp.int32), page_tokens=page_tokens,
    )


def _inputs(rng):
    q = jnp.asarray(rng.normal(0, 1, (B, H, HD)), jnp.float32)
    rk = jnp.asarray(rng.normal(0, 1, (B, R, KV, HD)), jnp.bfloat16)
    rv = jnp.asarray(rng.normal(0, 1, (B, R, KV, HD)), jnp.bfloat16)
    rlen = jnp.asarray([R, R // 2], jnp.int32)
    return q, rk, rv, rlen


def _assert_same(res_a, res_b):
    out_a, hot_a = res_a
    out_b, hot_b = res_b
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), **TOL)
    assert set(hot_a) == set(hot_b)
    for k in hot_a:
        np.testing.assert_allclose(
            np.asarray(hot_a[k]), np.asarray(hot_b[k]), err_msg=k, **TOL
        )


@pytest.mark.parametrize("n_tiers", [2, 3, 4])
def test_fused_equals_per_pool_mixed_codecs(n_tiers):
    rng = np.random.default_rng(7)
    bits_seq = (8, 4, 8, 4)
    pools = {
        f"t{i}": _mk_pool(rng, 6, bits_seq[i], 4, rng.integers(1, 5, B))
        for i in range(n_tiers)
    }
    host = _mk_host(rng)
    q, rk, rv, rlen = _inputs(rng)

    ops.use_fused(True)
    fused = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                        with_telemetry=True, host=host)
    ops.use_fused(False)
    oracle = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                         with_telemetry=True, host=host)
    _assert_same(fused, oracle)


def test_fused_kernel_matches_jnp_ref():
    rng = np.random.default_rng(11)
    pools = {"warm": _mk_pool(rng, 5, 8, 4, [4, 2]),
             "cold": _mk_pool(rng, 5, 4, 4, [3, 1])}
    host = _mk_host(rng)
    q, rk, rv, rlen = _inputs(rng)
    ops.use_fused(True)
    fused = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                        with_telemetry=True, host=host)
    ops.use_pallas(False)
    jref = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                       with_telemetry=True, host=host)
    _assert_same(fused, jref)


def test_single_launch_independent_of_tier_count():
    rng = np.random.default_rng(0)
    q, rk, rv, rlen = _inputs(rng)
    for n in (1, 2, 4):
        pools = {f"t{i}": _mk_pool(rng, 4, (8, 4)[i % 2], 3, [3, 2])
                 for i in range(n)}
        ops.use_fused(True)
        ops.reset_launch_count()
        ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                    with_telemetry=True, host=_mk_host(rng))
        assert ops.launch_count() == 1, f"{n} tiers"
        ops.use_fused(False)
        ops.reset_launch_count()
        ops.tiered_decode_attention(q, pools, rk, rv, rlen)
        assert ops.launch_count() == n
        ops.use_fused(True)
    assert ops.decode_launches_per_step(n_pools=4) == 1
    ops.use_fused(False)
    assert ops.decode_launches_per_step(n_pools=4) == 4


def test_empty_pool_and_all_host_edges():
    rng = np.random.default_rng(3)
    q, rk, rv, rlen = _inputs(rng)
    host = _mk_host(rng)
    # Empty pool: a pool present but with zero valid pages everywhere.
    empty = _mk_pool(rng, 2, 8, 3, [0, 0])
    cases = [
        ({"warm": empty}, host),  # empty pool + host sentinels
        ({}, host),  # all pages host-resident: recent window only
        ({}, None),  # degenerate: recent window alone
    ]
    for pools, h in cases:
        ops.use_fused(True)
        fused = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                            with_telemetry=True, host=h)
        ops.use_fused(False)
        oracle = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                             with_telemetry=True, host=h)
        ops.use_fused(True)
        _assert_same(fused, oracle)
    # The empty pool contributes exactly zero hotness.
    out, hot = ops.tiered_decode_attention(
        q, {"warm": empty}, rk, rv, rlen, with_telemetry=True, host=host
    )
    assert float(np.abs(np.asarray(hot["warm"])).sum()) == 0.0
    assert float(np.asarray(hot["host"]).sum()) > 0.0


def test_host_mass_matches_ref_oracle():
    """The kernel's sentinel rows emit exactly ref.host_page_mass, rebased
    by the same merged (m, l) normalization as real page masses."""
    rng = np.random.default_rng(5)
    pools = {"warm": _mk_pool(rng, 4, 8, 3, [3, 2])}
    # One validated page_tokens per launch: the host sentinels must declare
    # the pools' page size (a mismatch raises — see test_class_major.py).
    host = _mk_host(rng, page_tokens=T)
    q, rk, rv, rlen = _inputs(rng)
    ops.use_fused(True)
    _, hot = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                         with_telemetry=True, host=host)
    # Rebuild the normalization from the jnp oracle's merged stats.
    out, m_tot, l_tot, masses = ref.fused_tiered_attention(
        q, pools, rk, rv, rlen, host=host
    )
    mass, base = ref.host_page_mass(
        q, host["summary"], host["table"], host["n"], host["page_tokens"]
    )
    np.testing.assert_allclose(np.asarray(masses["host"][0]), np.asarray(mass))
    expect = ops.page_hotness(mass, base, m_tot, l_tot)
    np.testing.assert_allclose(
        np.asarray(hot["host"]), np.asarray(expect), **TOL
    )
    # Invalid sentinel rows carry zero mass.
    nvalid = np.asarray(host["n"])
    hostm = np.asarray(hot["host"])
    for b in range(B):
        assert (hostm[b, nvalid[b]:] == 0.0).all()


def test_host_mass_flows_to_predictor_not_placement():
    """Engine route: the cache folds sentinel telemetry into
    manager.record_host_mass (prefetch candidates) while the placement-
    driving access counts — and therefore plans — are untouched."""
    from repro.configs.base import ModelConfig
    from repro.core.manager import ManagerConfig
    from repro.serving.kv_cache import HOST4, TieredKVCache

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      head_dim=16)

    def build():
        c = TieredKVCache(cfg, 1, 2, 8, 64, recent_window=16,
                          manager_cfg=ManagerConfig(policy="analytical",
                                                    alpha=0.5, window_steps=4),
                          warm_frac=1.0)
        rng = np.random.default_rng(0)
        coords = [(0, sl, pg) for sl in range(2) for pg in range(c.max_pages)]
        k = rng.normal(0, 1, (len(coords), 8, cfg.n_kv_heads, 16)).astype(np.float32)
        c.append_pages(coords, jnp.asarray(k), jnp.asarray(k * 0.3))
        # Push half the pages to the int4 host tier -> sentinels appear.
        host_rids = np.arange(c.n_regions)[::2]
        c.migrate_batch(host_rids, np.full(host_rids.size, HOST4, np.int64))
        return c, host_rids

    a, host_rids = build()
    st = a.state
    assert int(np.asarray(st.host_n).sum()) == host_rids.size
    telemetry = {
        "warm": np.full((a.la, a.bs, a.max_pages), 0.01),
        "cold": np.zeros((a.la, a.bs, a.max_pages)),
        "host": np.full((a.la, a.bs, a.max_pages), 0.05),
    }
    a.record_telemetry(telemetry)
    # Host mass reached the predictor accumulator for exactly the host rids...
    assert (a.manager.host_mass[host_rids] > 0).all()
    non_host = np.setdiff1d(np.arange(a.n_regions), host_rids)
    assert (a.manager.host_mass[non_host] == 0).all()
    assert a.quality_skipped_mass > 0
    # ...and the placement-driving counts saw none of it: plans match a
    # cache that never received the host key (oracle-identical placements).
    b, _ = build()
    b.record_telemetry({k: telemetry[k] for k in ("warm", "cold")})
    np.testing.assert_array_equal(
        a.manager.telemetry._accum, b.manager.telemetry._accum
    )
    plan_a, _ = a.end_window()
    plan_b, _ = b.end_window()
    np.testing.assert_array_equal(plan_a.regions, plan_b.regions)
    np.testing.assert_array_equal(plan_a.dst, plan_b.dst)
    np.testing.assert_array_equal(a.physical, b.physical)
    # Window close resets the within-window host-mass accumulator.
    assert (a.manager.host_mass == 0).all()


def test_host_mass_qualifies_prefetch_candidates():
    """A host page with in-engine would-have-touched mass becomes a
    prefetch candidate even when the PEBS-analogue trend never saw it."""
    from repro.core.manager import ManagerConfig, TierScapeManager
    from repro.core.tiers import default_tierset

    ts = default_tierset()
    n = 16
    mgr = TierScapeManager(ts, n, region_bytes=ts.block_bytes,
                           cfg=ManagerConfig(policy="analytical"))
    mgr.record_access_counts(np.zeros(n))
    mgr.close_telemetry()  # predictor needs one closed window
    eligible = np.zeros(n, bool)
    eligible[3] = True
    # No trend, no host mass -> no candidates (seed behavior preserved).
    assert mgr.prefetch_candidates(eligible, top_k=4, max_regions=4).size == 0
    host_mass = np.zeros(n)
    host_mass[3] = 50.0
    mgr.record_host_mass(host_mass)
    cand = mgr.prefetch_candidates(eligible, top_k=4, max_regions=4)
    assert 3 in cand
    mgr.close_telemetry()
    assert mgr.prefetch_candidates(eligible, top_k=4, max_regions=4).size == 0


def test_sentinel_tables_track_every_host_transition():
    """host_table/host_n/host_summary slots stay consistent through batch
    migration, per-page migration, async stage/commit and release."""
    from repro.configs.base import ModelConfig
    from repro.core.manager import ManagerConfig
    from repro.serving.kv_cache import COLD, HOST4, HOST8, TieredKVCache

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      head_dim=16)
    c = TieredKVCache(cfg, 2, 2, 8, 32, recent_window=16,
                      manager_cfg=ManagerConfig(policy="analytical",
                                                alpha=0.5, window_steps=4),
                      warm_frac=1.0)
    rng = np.random.default_rng(1)
    coords = [(la, sl, pg) for la in range(2) for sl in range(2)
              for pg in range(c.max_pages)]
    k = rng.normal(0, 1, (len(coords), 8, cfg.n_kv_heads, 16)).astype(np.float32)
    c.append_pages(coords, jnp.asarray(k), jnp.asarray(k * 0.3))

    def n_sentinels():
        return int(np.asarray(c.state.host_n).sum())

    def host_pages_live():
        return int((((c.physical == HOST4) | (c.physical == HOST8))
                    & c._page_exists).sum())

    assert n_sentinels() == host_pages_live() == 0
    rids = np.arange(c.n_regions)
    c.migrate_batch(rids[:6], np.full(6, HOST4, np.int64))
    assert n_sentinels() == host_pages_live() == 6
    assert (c._host_slot[rids[:6]] >= 0).all()
    # Host -> host retranscode keeps exactly one sentinel per page.
    c.migrate_batch(rids[:3], np.full(3, HOST8, np.int64))
    assert n_sentinels() == host_pages_live() == 6
    # Promotion back to a device pool retires the sentinel.
    c.migrate_batch(rids[:2], np.full(2, COLD, np.int64))
    assert n_sentinels() == host_pages_live() == 4
    assert (c._host_slot[rids[:2]] == -1).all()
    # Per-page oracle path.
    c.migrate(int(rids[2]), COLD)
    assert n_sentinels() == host_pages_live() == 3
    # Release frees a slot's sentinels with its pages.
    c.release_slot_pages(0)
    assert n_sentinels() == host_pages_live()
    assert (np.asarray(c.state.host_n)[:, 0] == 0).all()
    # Summary content: mean over T of the dequantized stored K payload.
    live = np.where(((c.physical == HOST4) | (c.physical == HOST8))
                    & c._page_exists)[0]
    r = int(live[0])
    layer, slot, _ = c.rid_coords(r)
    kp, ks, _, _ = c.host_pages[r]
    bits = 8 if int(c.physical[r]) == HOST8 else 4
    expect = np.asarray(ref.dequant_kv_page(jnp.asarray(kp), jnp.asarray(ks),
                                            bits)).mean(axis=0)
    got = np.asarray(c.state.host_summary[layer, int(c._host_slot[r])])
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)


def test_engine_fused_telemetry_live_and_launches_counted():
    """End-to-end: the engine's decode step now produces live warm/cold
    hotness plus host sentinel mass, and the dispatch proxy bills exactly
    n_layers launches per step (fused), not O(tiers)."""
    import jax

    from repro.configs.base import ModelConfig, TierScapeRunConfig
    from repro.models import Model
    from repro.serving import TieredEngine

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      head_dim=16)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = TieredEngine(model, params, batch_slots=2, page_tokens=8,
                       max_seq_len=96, recent_window=16,
                       ts=TierScapeRunConfig(enabled=True, policy="analytical",
                                             alpha=0.3, window_steps=6))
    rng = np.random.default_rng(0)
    for _ in range(2):
        eng.submit(rng.integers(1, cfg.vocab_size, 48), max_new_tokens=12)
    stats = eng.run(max_steps=64)
    assert stats.completed == 2
    # Live device-pool telemetry reached the manager (pre-PR the engine's
    # jnp path emitted all-zero hotness).
    assert float(eng.cache.manager.telemetry.history.sum()) > 0.0
    assert stats.attn_launches == eng.la * stats.steps
    assert eng.cache.decode_steps_recorded == stats.steps