"""Optimizers: AdamW reference behaviour, tiered/compressed Adam (the
paper's technique on training state), gradient compression with error
feedback."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import adamw, grad_compress, tiered_adam
from repro.optim.adamw import AdamWConfig


def _quad_problem(seed=0, dim=256):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (dim,))
    params = {"w": jnp.zeros((dim,)), "embed": jnp.zeros((dim,))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum((p["embed"] - target) ** 2)

    return params, loss


def test_adamw_descends():
    params, loss = _quad_problem()
    state = adamw.init(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, m = adamw.update(grads, state, params, cfg)
    assert float(loss(params)) < 0.2 * l0
    assert int(state["step"]) == 50


def test_adamw_grad_clip():
    grads = {"w": jnp.full((8,), 1e6)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1e5


def test_cosine_schedule_shape():
    fn = adamw.cosine_schedule(warmup=10, total=100)
    vals = [float(fn(jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert vals[0] == 0.0
    assert vals[1] == pytest.approx(0.5)
    assert vals[2] == pytest.approx(1.0)
    assert vals[2] > vals[3] > vals[4]
    assert vals[4] == pytest.approx(0.1, abs=1e-6)


@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_tiered_adam_tracks_adamw(codec):
    """Warm-tier moment codecs must land near the f32 optimum under DENSE
    updates (int8 uses a µ-law dynamic code, like 8-bit Adam)."""
    params, loss = _quad_problem()
    policy = {"w": "none", "embed": codec}
    tstate = tiered_adam.init(params, policy)
    fstate = adamw.init(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    tp, fp = params, params
    for _ in range(60):
        tg = jax.grad(loss)(tp)
        fg = jax.grad(loss)(fp)
        tp, tstate, _ = tiered_adam.update(tg, tstate, tp, cfg)
        fp, fstate, _ = adamw.update(fg, fstate, fp, cfg)
    lf, lt = float(loss(fp)), float(loss(tp))
    assert lt < max(4 * lf, 1e-2), (codec, lt, lf)


def test_tiered_adam_int4_cold_leaves():
    """int4 is the cold tier (deflate analogue): leaves whose gradients are
    mostly zero — the cold-embedding-row regime. It must still descend and
    end far below the starting loss."""
    params, loss = _quad_problem()
    policy = {"w": "none", "embed": "int4"}
    tstate = tiered_adam.init(params, policy)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    tp = params
    l0 = float(loss(tp))
    for i in range(80):
        g = jax.grad(loss)(tp)
        if i % 8 != 0:  # cold leaf: updates arrive rarely
            g = {"w": g["w"], "embed": jnp.zeros_like(g["embed"])}
        tp, tstate, _ = tiered_adam.update(g, tstate, tp, cfg)
    assert float(loss(tp)) < 0.2 * l0


def test_tiered_adam_moment_bytes_saved():
    params = {"embed": jnp.zeros((4096, 64)), "w": jnp.zeros((128,))}
    s_f32 = tiered_adam.init(params, {"embed": "none", "w": "none"})
    s_int8 = tiered_adam.init(params, {"embed": "int8", "w": "none"})
    s_int4 = tiered_adam.init(params, {"embed": "int4", "w": "none"})
    b_f32 = tiered_adam.moment_bytes(s_f32)
    b_8 = tiered_adam.moment_bytes(s_int8)
    b_4 = tiered_adam.moment_bytes(s_int4)
    assert b_8 < 0.30 * b_f32  # ~4x on the embed-dominated state
    assert b_4 < b_8


def test_tiered_adam_repack_migration():
    """Tier migration for optimizer state: decode old policy, encode new."""
    params, loss = _quad_problem()
    policy = {"w": "none", "embed": "none"}
    state = tiered_adam.init(params, policy)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    p = params
    for _ in range(10):
        g = jax.grad(loss)(p)
        p, state, _ = tiered_adam.update(g, state, p, cfg)
    new_policy = {"w": "none", "embed": "int8"}
    state2 = tiered_adam.repack(state, p, new_policy)
    assert dict(state2.policy)["embed"] == "int8"
    # Moments survive migration within quantization error.
    m_old = tiered_adam.decode_moment(
        jax.tree.leaves(state.m)[0], jax.tree.leaves(state.m_scales)[0], "none",
        params["embed"].shape)
    # embed is the first leaf alphabetically in this dict pytree
    m_new = tiered_adam.decode_moment(
        jax.tree.leaves(state2.m)[0], jax.tree.leaves(state2.m_scales)[0], "int8",
        params["embed"].shape)
    rel = float(jnp.linalg.norm(m_old - m_new) / (jnp.linalg.norm(m_old) + 1e-9))
    assert rel < 0.02


def test_grad_compress_roundtrip_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (1000,)), jnp.float32)
    xq, resid = grad_compress.compress_roundtrip(x)
    np.testing.assert_allclose(np.asarray(xq + resid), np.asarray(x), rtol=1e-6)
    # int8 group quantization: small relative error even before feedback.
    rel = float(jnp.linalg.norm(x - xq) / jnp.linalg.norm(x))
    assert rel < 0.01


def test_grad_compress_sgd_converges():
    """EF-compressed gradient descent matches uncompressed descent."""
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (512,))
    w_c = jnp.zeros((512,))
    w_u = jnp.zeros((512,))
    resid = jnp.zeros((512,))
    lr = 0.2
    for _ in range(80):
        g_c = 2 * (w_c - target)
        g_u = 2 * (w_u - target)
        gq, resid = grad_compress.compress_roundtrip(g_c + resid)
        w_c = w_c - lr * gq
        w_u = w_u - lr * g_u
    assert float(jnp.linalg.norm(w_c - target)) < 1e-2
    assert float(jnp.linalg.norm(w_c - w_u)) < 0.05


def test_grad_compress_wire_bytes():
    params = {"w": jnp.zeros((1024, 1024))}
    raw, comp = grad_compress.wire_bytes(params)
    assert comp < 0.3 * raw
