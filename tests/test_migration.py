"""Batched migration executor equivalence + plan-pricing parity.

The batched cohort executor (``TieredKVCache.migrate_batch``) must be an
exact drop-in for the per-page loop (``migrate`` per region, the seed
semantics): same physical placements, same logical pool contents keyed by
region, same page-table membership, same host-tier dicts — and the
vectorized ``TierScapeManager._plan`` must price exactly like the per-page
reference loop, including the same-codec fast path.

Payloads are compared bit-exactly. Scales are compared at float tolerance:
on the same-codec fast path the batched executor copies scales verbatim
while the per-page loop requantizes (an identity on payloads, but 1-2 ulp
of float noise on scales).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.manager import ManagerConfig, make_manager
from repro.serving.kv_cache import COLD, HOST4, HOST8, WARM, TieredKVCache

from proptest import cases, draw_int

CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
)


def make_cache(layers=2, slots=2, page_tokens=8, max_seq=64, warm_frac=0.5):
    return TieredKVCache(
        CFG, layers, slots, page_tokens, max_seq, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=0.5),
        warm_frac=warm_frac,
    )


def fill_cache(cache: TieredKVCache, rng: np.random.Generator, n_pages: int):
    """Append n_pages identical-content pages across (layer, slot, page)."""
    coords = [
        (la, sl, pg)
        for la in range(cache.la)
        for sl in range(cache.bs)
        for pg in range(cache.max_pages)
    ][:n_pages]
    kv, hd = CFG.n_kv_heads, CFG.head_dim_()
    k = rng.normal(0, 1, (len(coords), cache.pt, kv, hd)).astype(np.float32)
    v = rng.normal(0, 1, (len(coords), cache.pt, kv, hd)).astype(np.float32)
    cache.append_pages(coords, jnp.asarray(k), jnp.asarray(v))
    return coords


def logical_content(cache: TieredKVCache):
    """{rid: (placement, (k_pay, k_sc, v_pay, v_sc))} from wherever it lives."""
    st = cache.state
    out = {}
    for rid in np.where(cache._page_exists)[0]:
        rid = int(rid)
        loc = int(cache.physical[rid])
        layer, _, _ = cache.rid_coords(rid)
        ps = int(cache._pool_slot[rid])
        if loc in (WARM, COLD):
            # Payloads live in the shared codec-class buffers; slots are
            # global class rows.
            cls = cache._cls["warm" if loc == WARM else "cold"]
            item = (getattr(st, f"{cls}_k")[layer, ps],
                    getattr(st, f"{cls}_k_scales")[layer, ps],
                    getattr(st, f"{cls}_v")[layer, ps],
                    getattr(st, f"{cls}_v_scales")[layer, ps])
        else:
            item = cache.host_pages[rid]
        out[rid] = (loc, tuple(np.asarray(x) for x in item))
    return out


def check_table_invariants(cache: TieredKVCache):
    """Every pooled page appears exactly once in its (layer, slot) table row;
    rows contain nothing else; free lists are disjoint from live slots."""
    st = cache.state
    for pool, level in (("warm", WARM), ("cold", COLD)):
        table = np.asarray(getattr(st, f"{pool}_table"))
        nvec = np.asarray(getattr(st, f"{pool}_n"))
        want = {}
        for rid in np.where((cache.physical == level) & cache._page_exists)[0]:
            layer, slot, _ = cache.rid_coords(int(rid))
            want.setdefault((layer, slot), []).append(int(cache._pool_slot[rid]))
        for layer in range(cache.la):
            for slot in range(cache.bs):
                n = int(nvec[layer, slot])
                row = sorted(table[layer, slot, :n].tolist())
                assert row == sorted(want.get((layer, slot), [])), (pool, layer, slot)
        live = {int(cache._pool_slot[r])
                for r in np.where((cache.physical == level) & cache._page_exists)[0]}
        free = cache._free_warm if level == WARM else cache._free_cold
        assert not (set(free) & live), pool


def assert_same_state(a: TieredKVCache, b: TieredKVCache):
    np.testing.assert_array_equal(a.physical, b.physical)
    np.testing.assert_array_equal(a.manager.placement, b.manager.placement)
    np.testing.assert_array_equal(a._page_exists, b._page_exists)
    ca, cb = logical_content(a), logical_content(b)
    assert ca.keys() == cb.keys()
    for rid in ca:
        (loc_a, pa), (loc_b, pb) = ca[rid], cb[rid]
        assert loc_a == loc_b, rid
        np.testing.assert_array_equal(pa[0], pb[0], err_msg=f"k payload rid={rid}")
        np.testing.assert_array_equal(pa[2], pb[2], err_msg=f"v payload rid={rid}")
        np.testing.assert_allclose(pa[1], pb[1], rtol=1e-6, err_msg=f"k scales rid={rid}")
        np.testing.assert_allclose(pa[3], pb[3], rtol=1e-6, err_msg=f"v scales rid={rid}")
    assert set(a.host_pages.keys()) == set(b.host_pages.keys())
    check_table_invariants(a)
    check_table_invariants(b)


def random_plan(cache: TieredKVCache, rng: np.random.Generator):
    """A random feasible plan: subset of live pages, random new tiers, with
    WARM inflow bounded so no capacity pressure perturbs either executor."""
    live = np.where(cache._page_exists)[0]
    m = draw_int(rng, 1, len(live))
    rids = rng.choice(live, size=m, replace=False)
    dsts = np.array(
        [rng.choice([t for t in (WARM, COLD, HOST8, HOST4)
                     if t != cache.physical[r]]) for r in rids],
        np.int64,
    )
    budget = len(cache._free_warm) + int((cache.physical[rids] == WARM).sum())
    to_warm = np.where(dsts == WARM)[0]
    for i in to_warm[budget:]:
        dsts[i] = COLD
    keep = dsts != cache.physical[rids]
    return rids[keep], dsts[keep]


# ---------------------------------------------------------------------------
# executor equivalence
# ---------------------------------------------------------------------------


def test_batched_executor_matches_per_page_loop():
    for i, rng in cases(12):
        a, b = make_cache(), make_cache()
        n_pages = draw_int(rng, 4, a.n_regions)
        fill_seed = draw_int(rng, 0, 2**31 - 1)
        fill_cache(a, np.random.default_rng(fill_seed), n_pages)
        fill_cache(b, np.random.default_rng(fill_seed), n_pages)
        assert_same_state(a, b)
        for _ in range(draw_int(rng, 1, 3)):  # chained windows of migrations
            rids, dsts = random_plan(a, rng)
            for rid, dst in zip(rids, dsts):  # per-page oracle, plan order
                a.migrate(int(rid), int(dst))
            moved = b.migrate_batch(rids, dsts)
            assert moved == len(rids), i
            assert_same_state(a, b)


def test_batched_executor_skips_missing_and_noop_pages():
    rng = np.random.default_rng(0)
    c = make_cache()
    fill_cache(c, rng, 6)
    live = np.where(c._page_exists)[0]
    missing = np.where(~c._page_exists)[0][:2]
    rids = np.concatenate([live[:2], missing])
    dsts = np.array([c.physical[live[0]], COLD, WARM, WARM], np.int64)  # first = no-op
    moved = c.migrate_batch(rids, dsts)
    assert moved == 1  # only live[1] -> COLD actually moves
    check_table_invariants(c)


def test_batched_executor_dedups_repeated_rids_last_wins():
    """Repeated rids in one plan must not crash or double-free slots: the
    page lands at its LAST dst (where a sequential loop would leave it).
    Content is not compared against the sequential replay — the batch jumps
    straight to the final tier and so skips the loop's lossy intermediate
    int4 hop."""
    c = make_cache()
    fill_cache(c, np.random.default_rng(11), 8)
    r = int(np.where(c._page_exists)[0][0])
    warm_free_before = len(c._free_warm)
    moved = c.migrate_batch(
        np.array([r, r, r], np.int64), np.array([HOST4, COLD, HOST8], np.int64)
    )
    assert moved == 1
    assert int(c.physical[r]) == HOST8
    assert int(c.manager.placement[r]) == HOST8
    assert r in c.host_pages
    assert len(c._free_warm) == warm_free_before + 1  # freed exactly once
    check_table_invariants(c)


def test_batched_executor_spills_warm_overflow_to_cold():
    rng = np.random.default_rng(1)
    c = make_cache(warm_frac=0.25)  # warm pool: 8 slots of 32 pages
    fill_cache(c, rng, 24)  # 8 land warm, 16 spill cold at ingest
    cold = np.where((c.physical == COLD) & c._page_exists)[0]
    # Ask for more promotions than the warm pool can ever hold.
    moved = c.migrate_batch(cold, np.full(cold.size, WARM, np.int64))
    assert moved > 0
    assert (c.physical[c._page_exists] > 0).all()
    assert int((c.physical == WARM).sum()) <= 8
    # manager placement reflects where pages actually landed (spills included).
    np.testing.assert_array_equal(c.physical, c.manager.placement)
    check_table_invariants(c)


def test_end_window_reconciles_physical_with_plan():
    rng = np.random.default_rng(2)
    c = make_cache()
    fill_cache(c, rng, 16)
    for _ in range(3):
        counts = np.zeros(c.n_regions)
        live = np.where(c._page_exists)[0]
        counts[rng.choice(live, size=8, replace=False)] = rng.integers(1, 100, 8)
        c.manager.record_access_counts(counts)
        plan, moved = c.end_window()
        assert moved >= 0
        # Existing pages: desired == actual. (Non-existent regions keep the
        # policy's fantasy placement; the cost model only prices existing.)
        ex = c._page_exists
        np.testing.assert_array_equal(c.physical[ex], c.manager.placement[ex])
        assert not ((c.physical == 0) & ex).any()  # never "DRAM"
        check_table_invariants(c)


# ---------------------------------------------------------------------------
# dispatch accounting (the O(pages) -> O(cohorts) claim)
# ---------------------------------------------------------------------------


def test_batched_dispatches_at_least_5x_fewer_at_256_pages():
    a = make_cache(layers=4, slots=4, page_tokens=8, max_seq=128, warm_frac=1.0)
    b = make_cache(layers=4, slots=4, page_tokens=8, max_seq=128, warm_frac=1.0)
    assert a.n_regions == 256
    fill_cache(a, np.random.default_rng(7), 256)
    fill_cache(b, np.random.default_rng(7), 256)
    rids = np.where(a._page_exists)[0]
    dsts = np.where(np.arange(rids.size) % 2 == 0, COLD, HOST4).astype(np.int64)

    a.kernel_dispatches = 0
    for rid, dst in zip(rids, dsts):
        a.migrate(int(rid), int(dst))
    per_page = a.kernel_dispatches

    b.kernel_dispatches = 0
    b.migrate_batch(rids, dsts)
    batched = b.kernel_dispatches

    assert batched * 5 <= per_page, (batched, per_page)
    assert_same_state(a, b)


# ---------------------------------------------------------------------------
# vectorized plan pricing == per-page reference loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("config", ["6T-AM-0.5", "6T-WF-M", "2T-M"])
def test_plan_vectorized_matches_loop(config):
    for i, rng in cases(50):
        mgr = make_manager(config, 64)
        m = draw_int(rng, 0, 64)
        regions = rng.choice(64, size=m, replace=False)
        n_opts = mgr.tierset.n_tiers + 1
        src = rng.integers(0, n_opts, m)
        dst = (src + rng.integers(1, n_opts, m)) % n_opts  # always a real move
        vec = mgr._plan(regions, src, dst)
        ref = mgr._plan_loop(regions, src, dst)
        assert vec.bytes_moved == ref.bytes_moved, i
        assert vec.modeled_migration_s == pytest.approx(ref.modeled_migration_s, rel=1e-12), i
        if m:
            assert vec.n_cohorts == len({(int(s), int(d)) for s, d in zip(src, dst)}), i
        else:
            assert vec.n_cohorts == 0


def test_plan_same_codec_fast_path_priced_as_copy():
    """C5(int8-HBM) <-> C7(int8-host) share a codec: the plan must price the
    move as two media copies, strictly cheaper than a transcode route."""
    mgr = make_manager("6T-AM-0.5", 8)
    ts = mgr.tierset
    pairs = [
        (i + 1, j + 1)
        for i, a in enumerate(ts.tiers)
        for j, b in enumerate(ts.tiers)
        if i != j and a.codec_name == b.codec_name
    ]
    assert pairs, "selected tierset should contain at least one same-codec pair"
    for s, d in pairs:
        one = mgr._plan(np.array([0]), np.array([s]), np.array([d]))
        copy_s = (mgr._stored_bytes[s] + mgr._stored_bytes[d]) / 819e9
        assert one.modeled_migration_s == pytest.approx(float(copy_s))
        transcode_s = mgr._lat_region[s] + mgr._compress_lat[d]
        assert one.modeled_migration_s < transcode_s
