"""Hardware-compressed CXL tier: codec/kernel parity, the X1 tier spec,
compressibility-adaptive media (EWMA boundary-update contract), seeded
queue-replay determinism for every device preset, and async-vs-serial
kv-cache equivalence with the cxl_hw device bound to the host tiers."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import capacity, codecs, hw
from repro.core.codecs import CODECS
from repro.core.manager import ManagerConfig
from repro.core.tiers import (
    CXL_SELECTED_IDS,
    LINE_ALIGN,
    characterized,
    cxl_tierset,
    get as get_tier,
)
from repro.kernels import ref as kref
from repro.kernels.cxl_line import cxl_decode_pages, cxl_encode_pages
from repro.media.devices import (
    ADAPTIVE_DEVICES,
    DEFAULT_FOR_MEDIA,
    DEVICES,
    AdaptiveMediaDevice,
    adaptive_devices,
    get as get_device,
    make_queues,
)
from repro.serving.kv_cache import COLD, HOST4, HOST8, WARM, TieredKVCache

from proptest import cases, draw_choice, draw_int
from test_migration import CFG, assert_same_state, fill_cache


# ---------------------------------------------------------------------------
# tier spec + codec point
# ---------------------------------------------------------------------------


def test_x1_tier_spec_and_cxl_tierset():
    x1 = get_tier("X1")
    assert (x1.pool, x1.codec_name, x1.media) == ("line", "cxl_hw", "cxl")
    assert x1.device.name == "cxl_hw"
    # Extension tiers never leak into the paper's characterized table.
    assert all(t.tid != "X1" for t in characterized())
    assert len(characterized()) == 12
    # Line pool: nominal footprint is line-aligned, no software index.
    sb = x1.stored_bytes(2048)
    assert sb % LINE_ALIGN == 0
    assert 1.0 < x1.effective_ratio(2048) <= 2.0
    # 7T evaluation set: DRAM + 6 tiers, X1 ordered right after C1.
    ts = cxl_tierset(2048)
    assert tuple(t.tid for t in ts.tiers) == CXL_SELECTED_IDS
    assert ts.media_devices()[2].name == "cxl_hw"
    lats, ratios = ts.latencies_s(), ts.ratios()
    assert lats[0] == 0.0 and all(v > 0 for v in lats[1:])
    # Inline decode makes X1 faster than every host-media tier.
    host_lats = [
        lats[i + 1] for i, t in enumerate(ts.tiers) if t.media == "host"
    ]
    assert lats[2] < min(host_lats)
    assert all(r >= 1.0 for r in ratios)


def test_cxl_codec_roundtrip_and_line_ratio():
    codec = CODECS["cxl_hw"]
    assert codec.bits_per_elem == 8.0
    assert codec.group == codecs.GROUP["cxl_hw"] == 512
    # Near-zero decode cost is the hardware tier's defining property.
    assert codec.decode_ops_per_elem < CODECS["int8"].decode_ops_per_elem
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, 4 * codec.group).astype(np.float32)
    enc = codec.encode(jnp.asarray(x, jnp.bfloat16))
    dec = np.asarray(codec.decode(enc, x.shape, jnp.float32))
    # int8 quant: error bounded by half a codeword step per scale group.
    step = np.abs(x).reshape(-1, codec.group).max(axis=1) / 127.0
    err = np.abs(dec - np.asarray(jnp.asarray(x, jnp.bfloat16), np.float32))
    assert (err.reshape(-1, codec.group).max(axis=1) <= step + 1e-6).all()
    # Line ratio is data-dependent: unit gaussian saturates int8 codewords
    # (ratio ~1), small-magnitude data narrows every line (ratio = 2).
    assert codecs.cxl_line_ratio(enc.payload) == pytest.approx(1.0, abs=0.05)
    small = x * 1e-3
    small[:: codec.group] = 1.0  # pin each scale group's amax
    enc_s = codec.encode(jnp.asarray(small, jnp.bfloat16))
    assert codecs.cxl_line_ratio(enc_s.payload) > 1.5
    wire = codecs.cxl_wire_bytes(enc_s.payload, enc_s.scales)
    nominal = codec.compressed_bytes(small.size)
    assert wire < nominal


def test_cxl_kernel_parity_vs_ref_oracle():
    rng = np.random.default_rng(1)
    p, t, kv, hd = 3, 4, 2, 2 * kref.CXL_LINE_ELEMS
    pages = rng.normal(0, 1, (p, t, kv, hd)).astype(np.float32)
    # Page 0's second hardware line is tiny relative to the row amax, so its
    # codewords fit int4 range and the controller narrows it; page 1's tail
    # lines are all-zero (pad tail) and narrow too.
    pages[0, :, :, kref.CXL_LINE_ELEMS:] *= 1e-3
    pages[1, :, :, kref.CXL_LINE_ELEMS:] = 0.0
    x = jnp.asarray(pages, jnp.bfloat16)
    payload, scales, bits = cxl_encode_pages(x, interpret=True)
    rp, rs, rb = kref.cxl_encode_kv_page(x)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(rs), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(rb))
    assert set(np.unique(np.asarray(bits))) <= {4, 8}
    nb = np.asarray(bits)
    assert (nb[0, :, :, 1] == 4).all() and (nb[0, :, :, 0] == 8).all()
    assert (nb[1, :, :, 1] == 4).all()
    dec = cxl_decode_pages(payload, scales, interpret=True)
    ref_dec = kref.cxl_decode_kv_page(rp, rs)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_dec), rtol=1e-6)
    # Controller narrowing changes stored bytes only, never values: the
    # observed ratio over these pages exceeds 1 while decode stays exact.
    assert kref.cxl_page_line_ratio(bits) > 1.0


# ---------------------------------------------------------------------------
# media presets: hw.py constants + seeded replay determinism (every preset)
# ---------------------------------------------------------------------------


def test_presets_share_hw_constants():
    cxl = DEVICES["cxl"]
    assert cxl.read_bw == hw.CXL_LINK_READ_BW
    assert cxl.write_bw == hw.CXL_LINK_WRITE_BW
    assert cxl.fixed_latency_s == hw.CXL_FIXED_LATENCY_S
    assert cxl.queue_depth == hw.CXL_QUEUE_DEPTH
    # The hardware-compressed expander shares the same physical link.
    hwd = DEVICES["cxl_hw"]
    assert (hwd.read_bw, hwd.write_bw, hwd.fixed_latency_s, hwd.queue_depth) == (
        cxl.read_bw, cxl.write_bw, cxl.fixed_latency_s, cxl.queue_depth
    )
    nvme = DEVICES["nvme"]
    assert nvme.read_bw == hw.NVME_READ_BW
    assert nvme.write_bw == hw.NVME_WRITE_BW
    assert nvme.fixed_latency_s == hw.NVME_FIXED_LATENCY_S
    assert nvme.queue_depth == hw.NVME_QUEUE_DEPTH
    host = DEVICES["host_dram_pcie"]
    assert host.read_bw == hw.V5E.host_link_bw
    assert host.fixed_latency_s == hw.MEDIA_FIXED_US["host"] * 1e-6
    assert DEVICES["hbm"].read_bw == hw.V5E.hbm_bw
    assert DEFAULT_FOR_MEDIA["cxl"] == "cxl_hw"
    assert ADAPTIVE_DEVICES <= set(DEVICES)


def test_queue_replay_byte_identical_every_preset():
    """Seeded property: for every catalog preset — including the adaptive
    cxl_hw device with mid-window observes and boundary commits interleaved
    — two fresh queue sets replaying the same submission sequence produce
    byte-identical (start, done) schedules and cumulative accounting."""
    names = sorted(DEVICES)
    for i, rng in cases(24):
        name = draw_choice(rng, names)
        n_ops = draw_int(rng, 4, 24)
        seq = []
        now = 0.0
        for _ in range(n_ops):
            now += draw_int(rng, 0, 100) * 1e-6
            seq.append((
                draw_int(rng, 1, 1 << 22),  # bytes
                now,
                draw_int(rng, 0, 1) == 1,  # write
                draw_int(rng, 1, 4),  # ops
                draw_int(rng, 0, 3),  # adaptive action selector
            ))

        def run():
            q = make_queues([name])[name]
            out = []
            for n_bytes, t, write, ops, action in seq:
                out.append(q.submit(n_bytes, now=t, write=write, ops=ops))
                if isinstance(q.device, AdaptiveMediaDevice):
                    if action == 1:
                        q.device.observe(2.0 * n_bytes, float(n_bytes))
                    elif action == 2:
                        q.device.observe(2.0 * n_bytes, float(n_bytes))
                        q.device.commit_window()
            return out, (q.busy_s, q.queue_wait_s, q.bytes_total, q.ops)

        a, b = run(), run()
        assert a == b  # exact float equality: replay is bit-identical


# ---------------------------------------------------------------------------
# adaptive device: EWMA boundary-update contract
# ---------------------------------------------------------------------------


def test_adaptive_device_validation():
    base = get_device("cxl_hw")
    with pytest.raises(ValueError):
        AdaptiveMediaDevice(base, init_ratio=0.5)
    dev = AdaptiveMediaDevice(base)
    with pytest.raises(ValueError):
        dev.observe(-1.0, 0.0)
    # make_queues wraps adaptive entries fresh each call — committed state
    # never leaks between runs.
    q1 = make_queues(["cxl_hw", "nvme"])
    q2 = make_queues(["cxl_hw"])
    assert isinstance(q1["cxl_hw"].device, AdaptiveMediaDevice)
    assert q1["cxl_hw"].device is not q2["cxl_hw"].device
    assert not isinstance(q1["nvme"].device, AdaptiveMediaDevice)
    assert set(adaptive_devices(q1)) == {"cxl_hw"}


def test_observe_is_pure_until_commit_window():
    """Mid-window observes must not move any service time; the EWMA folds
    exactly once, at the boundary."""
    dev = adaptive_devices(make_queues(["cxl_hw"]))["cxl_hw"]
    n = 1 << 20
    before = (dev.service_time_s(n), dev.service_time_s(n, write=True),
              dev.batch_service_time_s(n, ops=3), dev.read_bw, dev.ratio)
    for _ in range(5):
        dev.observe(2e6, 1e6)  # ratio-2 data, five mid-window observations
    after = (dev.service_time_s(n), dev.service_time_s(n, write=True),
             dev.batch_service_time_s(n, ops=3), dev.read_bw, dev.ratio)
    assert before == after  # bit-identical: observation is pure accumulation
    committed = dev.commit_window()
    # EWMA fold: 0.75 * 1.0 + 0.25 * 2.0.
    assert committed == pytest.approx(1.25)
    assert dev.read_bw == pytest.approx(get_device("cxl_hw").read_bw * 1.25)
    assert dev.service_time_s(n) < before[0]
    # An empty window leaves the committed ratio untouched.
    assert dev.commit_window() == committed
    # Incompressible observations can only pull the ratio back toward 1,
    # never below it.
    for _ in range(50):
        dev.observe(1e6, 4e6)
        dev.commit_window()
    assert dev.ratio >= 1.0


# ---------------------------------------------------------------------------
# async == serial with cxl_hw host tiers, at 2/3/4-tier migration spans
# ---------------------------------------------------------------------------


def make_cxl_cache(async_migration=False):
    return TieredKVCache(
        CFG, 2, 2, 8, 64, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=0.5, window_steps=4),
        warm_frac=0.5, async_migration=async_migration, ring_slots=8,
        host_media_device="cxl_hw",
    )


def test_async_matches_serial_oracle_with_cxl_tiers():
    """With the adaptive cxl_hw device bound to the host tiers, the async
    pipeline must stay bit-identical to the serial oracle across migration
    spans of 2, 3 and 4 tiers — the ratio-EWMA contract (observe mid-window
    is pure; commits happen after the drain at the boundary in both modes)
    is exactly what makes this hold."""
    spans = {2: (HOST8, HOST4), 3: (COLD, HOST8, HOST4),
             4: (WARM, COLD, HOST8, HOST4)}
    for i, rng in cases(6):
        tiers = spans[draw_choice(rng, sorted(spans))]
        serial, asyn = make_cxl_cache(), make_cxl_cache(async_migration=True)
        n_pages = draw_int(rng, 6, serial.n_regions)
        fill_seed = draw_int(rng, 0, 2**31 - 1)
        fill_cache(serial, np.random.default_rng(fill_seed), n_pages)
        fill_cache(asyn, np.random.default_rng(fill_seed), n_pages)
        for _ in range(draw_int(rng, 1, 3)):
            live = np.where(serial._page_exists)[0]
            m = draw_int(rng, 1, len(live))
            rids = rng.choice(live, size=m, replace=False)
            dsts = np.array(
                [rng.choice([t for t in tiers if t != serial.physical[r]]
                            or [tiers[0]]) for r in rids], np.int64)
            serial.migrate_batch(rids, dsts)
            queued = asyn.pipeline.submit(asyn.plan_cohorts(rids, dsts))
            ticks = 0
            while asyn.pipeline.busy:
                asyn.pipeline.tick()
                ticks += 1
                assert ticks < 10 * queued + 50, "pipeline wedged"
            assert_same_state(serial, asyn)


def test_window_boundary_ratio_updates_mode_independent():
    """Full end_window path: adaptive-ratio observations are fed after the
    drain in both modes, so placements, measured ratios and the committed
    device ratio all match between serial and async runs."""

    def run(async_migration):
        cache = make_cxl_cache(async_migration=async_migration)
        rng = np.random.default_rng(7)
        coords = [(la, sl, pg) for la in range(cache.la)
                  for sl in range(cache.bs) for pg in range(cache.max_pages)][:20]
        kv, hd = CFG.n_kv_heads, CFG.head_dim_()
        k = rng.normal(0, 1, (len(coords), cache.pt, kv, hd)).astype(np.float32)
        k[10:] = 0.0  # pad-tail pages: the compressible half
        cache.append_pages(coords, jnp.asarray(k), jnp.asarray(k.copy()))
        for w in range(4):
            counts = np.zeros(cache.n_regions)
            counts[: 6 + 2 * w] = np.linspace(9.0, 1.0, 6 + 2 * w)
            cache.manager.record_access_counts(counts)
            cache.end_window()
            while cache.pipeline.busy:
                cache.pipeline.tick()
        dev = adaptive_devices(cache.media_queues)["cxl_hw"]
        return (cache.physical.copy(), dev.ratio,
                dict(cache.manager.media_ratio),
                cache.manager.measured_ratios.copy())

    ph_s, ratio_s, mr_s, meas_s = run(False)
    ph_a, ratio_a, mr_a, meas_a = run(True)
    np.testing.assert_array_equal(ph_s, ph_a)
    assert ratio_s == ratio_a  # bit-identical EWMA trajectory
    assert mr_s == mr_a
    np.testing.assert_array_equal(meas_s, meas_a)
    # The KV pages are real data, so the device actually learned something.
    assert ratio_s > 1.0


# ---------------------------------------------------------------------------
# capacity planner: cxl family + server spec
# ---------------------------------------------------------------------------


def test_capacity_cxl_server_and_search_grid():
    spec = capacity.get_server("v5e-cxlhw")
    assert spec.cxl_hw_gb > 0
    base = capacity.get_server("v5e-base")
    # Raw expander media is priced at the CXL $/GB, on top of the base BOM.
    assert spec.purchase_usd() > base.purchase_usd()
    cap = spec.capacity_vector()
    assert "mem:cxl_hw" in cap and "bw:cxl_hw" in cap
    assert "mem:cxl_hw" not in base.capacity_vector()
    grid = capacity.cxl_search_grid()
    names = [c.name for c in grid]
    assert names[: len(capacity.default_search_grid())] == [
        c.name for c in capacity.default_search_grid()
    ]
    cxl_cfgs = [c for c in grid if c.family == "cxl"]
    assert len(cxl_cfgs) == 6
    assert all(c.name.startswith("cxl-a") for c in cxl_cfgs)
