"""Codec-class-major pool storage: shared class buffers, global-row
addressing, zero-concat fused operands, and same-class table-edit migration.

Covers the class-major contract end to end:

  * 3- and 4-pool deployments whose same-class pools alias ONE class buffer
    match the per-pool launch oracle on outputs and normalized hotness with
    ZERO per-step concat copy-bytes;
  * host-only and single-class launches (the other codec class is empty —
    its 1-row dummy buffer must be unaddressable);
  * one validated ``page_tokens`` per fused launch — mixed page sizes raise
    instead of silently mis-scaling sentinel mass;
  * ``SlotAllocator.free`` raises on unknown/double frees, and
    ``exchange_slots`` conserves capacity while enforcing dst quota;
  * same-class migration is a pure table edit (rows stay put, no transcode
    dispatch, no media bytes) on both the blocking executor and the async
    marker path, which stays bit-identical to the serial oracle;
  * a seeded property test that no sequence of migrations/releases ever
    aliases two live pages onto one global class row.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.manager import ManagerConfig
from repro.core.pools import ClassPartition, SlotAllocator, exchange_slots
from repro.kernels import ops, ref
from repro.serving.kv_cache import COLD, HOST4, HOST8, WARM, TieredKVCache

from proptest import cases, draw_int
from test_migration import CFG, check_table_invariants, fill_cache

B, H, KV, HD, T, R = 2, 8, 2, 32, 8, 6
TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(autouse=True)
def _restore_ops_toggles():
    yield
    ops.use_pallas(True)
    ops.use_fused(True)


def _class_pools(bits_seq, rng, rows_per_pool=6, mp=4):
    """Class-major pools: one shared buffer per codec width, each pool
    owning a contiguous global-row range (the ``TieredKVCache`` layout)."""
    buf = {}
    for bits in sorted(set(bits_seq)):
        rows = rows_per_pool * bits_seq.count(bits)
        pages = jnp.asarray(rng.normal(0, 1, (rows, T, KV, HD)), jnp.bfloat16)
        kp, ks = ref.quant_kv_page(pages, bits)
        vp, vs = ref.quant_kv_page(pages * 0.5, bits)
        buf[bits] = dict(k_pages=kp, k_scales=ks, v_pages=vp, v_scales=vs)
    pools, base = {}, {b: 0 for b in buf}
    for i, bits in enumerate(bits_seq):
        table = jnp.asarray(
            base[bits] + rng.integers(0, rows_per_pool, (B, mp)), jnp.int32
        )
        base[bits] += rows_per_pool
        pools[f"t{i}"] = dict(
            **buf[bits], page_table=table,
            n_pages=jnp.asarray(rng.integers(1, mp + 1, B), jnp.int32),
            bits=bits,
        )
    return pools


def _mk_host(rng, hs=5, mp=3, page_tokens=T):
    return dict(
        summary=jnp.asarray(rng.normal(0, 1, (hs, KV, HD)), jnp.float32),
        table=jnp.asarray(rng.integers(0, hs, (B, mp)), jnp.int32),
        n=jnp.asarray([2, 3], jnp.int32), page_tokens=page_tokens,
    )


def _inputs(rng):
    q = jnp.asarray(rng.normal(0, 1, (B, H, HD)), jnp.float32)
    rk = jnp.asarray(rng.normal(0, 1, (B, R, KV, HD)), jnp.bfloat16)
    rv = jnp.asarray(rng.normal(0, 1, (B, R, KV, HD)), jnp.bfloat16)
    return q, rk, rv, jnp.asarray([R, R // 2], jnp.int32)


def _assert_same(res_a, res_b):
    out_a, hot_a = res_a
    out_b, hot_b = res_b
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), **TOL)
    assert set(hot_a) == set(hot_b)
    for k in hot_a:
        np.testing.assert_allclose(
            np.asarray(hot_a[k]), np.asarray(hot_b[k]), err_msg=k, **TOL
        )


# ---------------------------------------------------------------------------
# fused launch over shared class buffers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "bits_seq",
    [(8, 8, 8), (8, 8, 4), (4, 4, 4), (8, 8, 4, 4), (8, 8, 8, 8)],
)
def test_same_class_pools_fused_matches_oracle_zero_copy(bits_seq):
    """3/4-pool deployments with shared class buffers: fused == per-pool
    oracle and operand assembly concatenates NOTHING."""
    rng = np.random.default_rng(13)
    pools = _class_pools(tuple(bits_seq), rng)
    host = _mk_host(rng)
    q, rk, rv, rlen = _inputs(rng)

    ops.use_fused(True)
    ops.reset_launch_count()
    ops.reset_copy_bytes()
    fused = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                        with_telemetry=True, host=host)
    assert ops.launch_count() == 1
    assert ops.concat_copy_bytes() == 0, "class-major layout must not concat"

    ops.use_fused(False)
    oracle = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                         with_telemetry=True, host=host)
    _assert_same(fused, oracle)


def test_single_class_and_host_only_launches():
    """One codec class populated (the other class's dummy buffer must stay
    unaddressed), and the host-only / recent-only degenerate launches."""
    rng = np.random.default_rng(17)
    q, rk, rv, rlen = _inputs(rng)
    host = _mk_host(rng)
    for pools, h in [
        (_class_pools((8, 8, 8), rng), host),  # int4 class empty
        (_class_pools((4, 4), rng), host),  # int8 class empty
        ({}, host),  # host-only
        ({}, None),  # recent-only
    ]:
        ops.use_fused(True)
        ops.reset_copy_bytes()
        fused = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                            with_telemetry=True, host=h)
        assert ops.concat_copy_bytes() == 0
        ops.use_fused(False)
        oracle = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                             with_telemetry=True, host=h)
        ops.use_fused(True)
        _assert_same(fused, oracle)


def test_stale_rows_cannot_address_empty_class_dummy():
    """A stale table entry past the valid prefix may carry any slot value —
    including one aliasing row 0 of the EMPTY int4 class's dummy buffer.
    ``TIER_INVALID`` masking (the single enforcement point) must keep it
    out of the launch: outputs match an oracle that never saw the row."""
    rng = np.random.default_rng(19)
    pools = _class_pools((8, 8), rng, mp=4)
    # Poison every out-of-prefix entry with row 0 (the dummy-aliasing slot)
    # and an in-range-looking value; n_pages masks them.
    for p in pools.values():
        tbl = np.asarray(p["page_table"]).copy()
        n = np.asarray(p["n_pages"])
        for b in range(B):
            tbl[b, n[b]:] = 0
        p["page_table"] = jnp.asarray(tbl)
    q, rk, rv, rlen = _inputs(rng)
    ops.use_fused(True)
    fused = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                        with_telemetry=True)
    ops.use_fused(False)
    oracle = ops.tiered_decode_attention(q, pools, rk, rv, rlen,
                                         with_telemetry=True)
    _assert_same(fused, oracle)
    # Stale entries contribute exactly zero hotness.
    _, hot = fused
    for name, p in pools.items():
        n = np.asarray(p["n_pages"])
        h = np.asarray(hot[name])
        for b in range(B):
            assert (h[b, n[b]:] == 0.0).all()


def test_valid_row_out_of_class_bounds_raises():
    """A VALID table entry addressing past the class buffer is a real bug
    (stale slot with a live tier code) and the eager bounds guard names it."""
    rng = np.random.default_rng(23)
    pools = _class_pools((8, 8), rng)
    bad = np.asarray(pools["t0"]["page_table"]).copy()
    bad[0, 0] = 10_000  # far outside the shared int8 buffer
    pools["t0"]["page_table"] = jnp.asarray(bad)
    q, rk, rv, rlen = _inputs(rng)
    ops.use_fused(True)
    with pytest.raises(IndexError, match="class row"):
        ops.tiered_decode_attention(q, pools, rk, rv, rlen, with_telemetry=True)


def test_mixed_page_tokens_raises():
    """One validated page_tokens per fused launch — a mismatched pool or
    host sentinel page size raises instead of mis-scaling sentinel mass."""
    rng = np.random.default_rng(29)
    q, rk, rv, rlen = _inputs(rng)
    pools = _class_pools((8, 4), rng)
    # Pool with a different page shape.
    wrong = _class_pools((4,), np.random.default_rng(1), rows_per_pool=3)["t0"]
    wrong["k_pages"] = jnp.zeros((3, 2 * T, KV, HD // 2), jnp.uint8)
    for use_pallas in (True, False):
        ops.use_pallas(use_pallas)
        ops.use_fused(True)
        with pytest.raises(ValueError, match="mixed page_tokens"):
            ops.tiered_decode_attention(
                q, {**pools, "bad": wrong}, rk, rv, rlen, with_telemetry=True
            )
        # Host sentinels declaring a different page size.
        with pytest.raises(ValueError, match="mixed page_tokens"):
            ops.tiered_decode_attention(
                q, pools, rk, rv, rlen, with_telemetry=True,
                host=_mk_host(rng, page_tokens=2 * T),
            )
    ops.use_pallas(True)


# ---------------------------------------------------------------------------
# allocator hard contract
# ---------------------------------------------------------------------------


def test_slot_allocator_free_raises_on_unknown_and_double_free():
    a = SlotAllocator(4, base=10)
    s = a.alloc(block_id=1)
    assert 10 <= s < 14
    a.free(s)
    with pytest.raises(KeyError, match="unowned"):
        a.free(s)  # double free
    with pytest.raises(KeyError, match="unowned"):
        a.free(99)  # never allocated


def test_exchange_slots_conserves_capacity_and_enforces_quota():
    src = SlotAllocator(3, base=0)
    dst = SlotAllocator(3, tenant_quota={"a": 1}, base=3)
    s = src.alloc(block_id=7)
    with pytest.raises(ValueError):
        exchange_slots(src, dst, s, 7)  # quota'd dst needs a tenant
    got = exchange_slots(src, dst, s, 7, tenant="a")
    assert got == s  # the page's global row is unchanged
    assert dst._owner[s] == 7 and s not in src._owner
    # Free + owned conserved on both sides.
    assert len(src._free) + len(src._owner) == 3
    assert len(dst._free) + len(dst._owner) == 3
    assert dst.used_by("a") == 1
    s2 = src.alloc(block_id=8)
    with pytest.raises(MemoryError, match="quota"):
        exchange_slots(src, dst, s2, 8, tenant="a")
    with pytest.raises(KeyError, match="not owned"):
        exchange_slots(src, dst, 999, 9, tenant="a")


def test_class_partition_layout():
    part = ClassPartition([("warm", 8, 5), ("cold", 8, 7)])
    assert part.base("warm") == 0 and part.base("cold") == 5
    assert part.class_rows(8) == 12
    assert part.class_rows(4) == 1  # empty class still gets a dummy row
    mixed = ClassPartition([("warm", 8, 5), ("cold", 4, 7)])
    assert mixed.base("cold") == 0  # separate class, separate row space
    with pytest.raises(ValueError):
        ClassPartition([("warm", 8, 5), ("warm", 8, 5)])


# ---------------------------------------------------------------------------
# same-class migration = table edits
# ---------------------------------------------------------------------------


def make88(async_migration=False, prefetch=False, warm_frac=0.5):
    return TieredKVCache(
        CFG, 2, 2, 8, 64, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=0.5),
        warm_frac=warm_frac, async_migration=async_migration,
        prefetch=prefetch, pool_bits={"warm": 8, "cold": 8},
    )


def _class_rows_unique(cache):
    """No two live device pages may share a global class-buffer row."""
    for bits in (8, 4):
        rows = []
        for pool, level in (("warm", WARM), ("cold", COLD)):
            if cache._pool_bits[pool] != bits:
                continue
            live = np.where((cache.physical == level) & cache._page_exists)[0]
            rows.extend(int(cache._pool_slot[r]) for r in live)
        assert len(rows) == len(set(rows)), f"aliased class-{bits} rows"
    # Allocator books stay conserved and disjoint.
    wa, ca = cache._alloc["warm"], cache._alloc["cold"]
    assert len(wa._free) + len(wa._owner) == wa.capacity
    assert len(ca._free) + len(ca._owner) == ca.capacity
    if cache._pool_bits["warm"] == cache._pool_bits["cold"]:
        both = set(wa._free) | set(wa._owner) | set(ca._free) | set(ca._owner)
        assert len(both) == wa.capacity + ca.capacity


def test_same_class_blocking_move_is_pure_table_edit():
    c = make88()
    coords = fill_cache(c, np.random.default_rng(0), 24)
    rids = np.array([c.rid(*x) for x in coords[:8]], np.int64)
    ps = c._pool_slot[rids].copy()
    la = rids // (c.bs * c.max_pages)
    payload = np.asarray(c.state.c8_k)[la, ps].copy()
    kd = c.kernel_dispatches
    c.migrate_batch(rids, np.full(rids.size, COLD, np.int64))
    check_table_invariants(c)
    _class_rows_unique(c)
    assert (c.physical[rids] == COLD).all()
    np.testing.assert_array_equal(c._pool_slot[rids], ps)  # rows stayed put
    assert c.kernel_dispatches == kd  # no transcode dispatch
    np.testing.assert_array_equal(np.asarray(c.state.c8_k)[la, ps], payload)
    # ...and back up, still by table edit.
    c.migrate_batch(rids, np.full(rids.size, WARM, np.int64))
    check_table_invariants(c)
    np.testing.assert_array_equal(c._pool_slot[rids], ps)
    assert c.kernel_dispatches == kd


def test_async_same_class_matches_serial_and_moves_zero_bytes():
    """The marker path through stage/transcode/commit: bit-identical to the
    serial oracle, zero media bytes for the table-edit cohorts."""
    from test_migration import assert_same_state

    ca, cb = make88(async_migration=True), make88(async_migration=False)
    for c in (ca, cb):
        fill_cache(c, np.random.default_rng(3), 24)
    live = np.where(ca._page_exists)[0]
    # Same-class device cohort first: pure table edits, ZERO media bytes.
    dev_rids = live[:6]
    bytes0 = dict(ca.pipeline.media_bytes())
    for c in (ca, cb):
        c.pipeline.submit(
            c.plan_cohorts(dev_rids.copy(), np.full(6, COLD, np.int64))
        )
        if c.pipeline.busy:
            c.pipeline.drain()
    assert_same_state(ca, cb)
    _class_rows_unique(ca)
    delta = {k: v - bytes0[k] for k, v in ca.pipeline.media_bytes().items()}
    assert all(v == 0 for v in delta.values()), delta
    # Host swap-out is a real spill and pays for its bytes.
    host_rids = live[6:10]
    for c in (ca, cb):
        c.pipeline.submit(
            c.plan_cohorts(host_rids.copy(), np.full(4, HOST4, np.int64))
        )
        if c.pipeline.busy:
            c.pipeline.drain()
    assert_same_state(ca, cb)
    delta = {k: v - bytes0[k] for k, v in ca.pipeline.media_bytes().items()}
    assert delta["host_dram_pcie"] > 0
    # Promotions back (host -> device crosses codecs and pays; the
    # same-class leg still edits tables only).
    for c in (ca, cb):
        c.pipeline.submit(
            c.plan_cohorts(live[:10].copy(), np.full(10, WARM, np.int64))
        )
        if c.pipeline.busy:
            c.pipeline.drain()
    assert_same_state(ca, cb)
    _class_rows_unique(ca)


def test_release_and_prefetch_claim_under_class_addressing():
    """Prefetch claim -> promotion commit scatters into the class buffer;
    release under class addressing frees global rows exactly once."""
    c = make88(async_migration=True, prefetch=True, warm_frac=1.0)
    fill_cache(c, np.random.default_rng(5), 24)
    live = np.where(c._page_exists)[0]
    host = live[12:]
    c.migrate_batch(host, np.full(host.size, HOST4, np.int64))
    _class_rows_unique(c)
    # Warm the predictor toward the host pages, tick the speculative path.
    base = np.zeros(c.n_regions)
    base[live[:12]] = 5.0
    c.manager.record_access_counts(base)
    c.manager.close_telemetry()
    rising = np.zeros(c.n_regions)
    rising[host] = 50.0
    c.manager.record_host_mass(rising)
    for _ in range(8):
        c.prefetch_tick()
    assert c.pipeline.prefetch_staged > 0
    # Boundary promotes the held pages: claims commit into the c8 buffer.
    c.manager.placement[host] = HOST4
    cohorts = c.plan_cohorts(host, np.full(host.size, WARM, np.int64))
    prestaged = {}
    for crids, s, _d in cohorts:
        prestaged.update(c.pipeline.claim_prefetched(crids, s))
    assert c.pipeline.prefetch_hits > 0
    c.pipeline.discard_speculative()
    c.pipeline.submit(cohorts, prestaged=prestaged or None)
    if c.pipeline.busy:
        c.pipeline.drain()
    check_table_invariants(c)
    _class_rows_unique(c)
    assert (c.physical[host] == WARM).all()
    # Release both batch slots: every global row returns exactly once.
    c.release_slot_pages(0)
    c.release_slot_pages(1)
    _class_rows_unique(c)
    assert not c._page_exists.any()
    assert len(c._free_warm) == c._alloc["warm"].capacity
    assert len(c._free_cold) == c._alloc["cold"].capacity


def test_table_edits_never_alias_class_rows_property():
    """Seeded property test: random migration/release sequences on a
    same-class deployment never alias two live pages onto one class row."""
    for i, rng in cases(8):
        async_mode = bool(i % 2)
        c = make88(async_migration=async_mode)
        n_pages = draw_int(rng, 8, 24)
        fill_cache(c, rng, n_pages)
        _class_rows_unique(c)
        for _ in range(draw_int(rng, 3, 6)):
            live = np.where(c._page_exists)[0]
            if live.size == 0:
                break
            k = draw_int(rng, 1, max(live.size // 2, 1))
            rids = rng.choice(live, size=k, replace=False).astype(np.int64)
            dsts = rng.choice(
                [WARM, COLD, HOST8, HOST4], size=k, replace=True
            ).astype(np.int64)
            if async_mode:
                c.pipeline.submit(c.plan_cohorts(rids, dsts))
                if c.pipeline.busy:
                    c.pipeline.drain()
            else:
                c.migrate_batch(rids, dsts)
            check_table_invariants(c)
            _class_rows_unique(c)
        if draw_int(rng, 0, 1):
            c.release_slot_pages(draw_int(rng, 0, c.bs - 1))
            _class_rows_unique(c)


def test_default_split_unchanged_by_class_major_layout():
    """The (8, 4) default: both allocators base at 0, class buffers have
    the per-pool shapes, and the engine's tier ids are the classic ones."""
    from test_migration import make_cache

    c = make_cache()
    assert c._alloc["warm"].base == 0 and c._alloc["cold"].base == 0
    assert c._cls == {"warm": "c8", "cold": "c4"}
    assert c.state.c8_k.shape[1] == c._alloc["warm"].capacity
    assert c.state.c4_k.shape[1] == c._alloc["cold"].capacity
    ids = [t.tid for t in c.manager.tierset.tiers]
    assert ids == ["C5", "C9", "C7", "C10"]
    c88 = make88()
    ids88 = [t.tid for t in c88.manager.tierset.tiers]
    assert ids88 == ["C5", "C6", "C7", "C10"]
    # Same-class pools stack into one class buffer.
    assert (
        c88.state.c8_k.shape[1]
        == c88._alloc["warm"].capacity + c88._alloc["cold"].capacity
    )
    assert c88.state.c4_k.shape[1] == 1  # empty class: dummy row only
    assert c88._alloc["cold"].base == c88._alloc["warm"].capacity
