"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs one forward/train step on CPU, asserting
output shapes and finite values. Decode paths are checked for consistency
with the parallel forward."""

import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
from repro.models import Model
from repro.models.inputs import make_train_batch
from repro.optim import adamw

ARCHS = configs.arch_ids()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_train_batch(cfg, batch=2, seq=32)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    batch = make_train_batch(cfg, batch=2, seq=32)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt, om = adamw.update(grads, opt, params, adamw.AdamWConfig(lr=1e-3))
        return params, opt, loss

    p1, o1, loss1 = step(params, opt, batch)
    p2, o2, loss2 = step(p1, o1, batch)
    assert bool(jnp.isfinite(loss1)) and bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss1), "two steps on the same batch must descend"


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if configs.get_smoke(a).is_decoder]
)
def test_smoke_decode_matches_forward(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_train_batch(cfg, batch=B, seq=S)
    if cfg.frontend == "vision":
        batch.pop("embeds", None)
        batch.pop("embeds_mask", None)
    logits_full, _ = model.forward(params, {k: v for k, v in batch.items()})
    state = model.init_cache(B, S + 2)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(S):
        lg, state = step(params, batch["tokens"][:, i : i + 1], state)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    err = float(
        jnp.max(jnp.abs(logits_full.astype(jnp.float32) - logits_dec.astype(jnp.float32)))
    )
    tol = 1.6 if cfg.family == "moe" else 0.15  # MoE: capacity drops differ
    assert err < tol, (arch, err)


def test_all_archs_have_full_and_smoke_configs():
    assert len(ARCHS) == 10
    for arch in ARCHS:
        full, smoke = configs.get(arch), configs.get_smoke(arch)
        assert full.family == smoke.family
        assert full.param_count() > smoke.param_count()


def test_full_config_values_match_assignment():
    """The exact published configs from the assignment table."""
    c = configs.get("command_r_35b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        40, 8192, 64, 8, 22528, 256000)
    c = configs.get("qwen3_32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        64, 5120, 64, 8, 25600, 151936)
    assert c.qk_norm
    c = configs.get("internlm2_20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        48, 6144, 48, 8, 16384, 92544)
    c = configs.get("qwen1_5_4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        40, 2560, 20, 20, 6912, 151936)
    assert c.qkv_bias
    c = configs.get("qwen3_moe_235b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab_size) == (
        94, 4096, 64, 4, 151936)
    assert (c.moe.n_experts, c.moe.experts_per_token, c.moe.d_ff_expert) == (128, 8, 1536)
    c = configs.get("dbrx_132b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab_size) == (
        40, 6144, 48, 8, 100352)
    assert (c.moe.n_experts, c.moe.experts_per_token) == (16, 4)
    c = configs.get("mamba2_780m")
    assert (c.n_layers, c.d_model, c.vocab_size, c.ssm.d_state) == (48, 1536, 50280, 128)
    c = configs.get("zamba2_1_2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size, c.ssm.d_state) == (
        38, 2048, 32, 8192, 32000, 64)
    c = configs.get("qwen2_vl_72b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        80, 8192, 64, 8, 29568, 152064)
    assert c.mrope
    c = configs.get("hubert_xlarge")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (
        48, 1280, 16, 5120, 504)
    assert not c.causal


def test_cell_skips_match_design():
    # Encoder-only: no decode shapes. Full attention: no long_500k.
    assert configs.cells_for("hubert_xlarge") == ["train_4k", "prefill_32k"]
    assert "long_500k" in configs.cells_for("mamba2_780m")
    assert "long_500k" in configs.cells_for("zamba2_1_2b")
    for arch in ("command_r_35b", "qwen3_32b", "qwen3_moe_235b", "qwen2_vl_72b"):
        assert "long_500k" in configs.skipped_cells_for(arch)
    total = sum(len(configs.cells_for(a)) for a in ARCHS)
    assert total == 31  # 10 train + 10 prefill + 9 decode + 2 long


def test_param_counts_near_published():
    """Sanity: computed N is within ~20% of the arch's nameplate size."""
    expect = {
        "command_r_35b": 35e9,
        "qwen3_32b": 32e9,
        "internlm2_20b": 20e9,
        "qwen1_5_4b": 4e9,
        "qwen3_moe_235b": 235e9,
        "dbrx_132b": 132e9,
        "mamba2_780m": 0.78e9,
        "zamba2_1_2b": 1.2e9,
        "qwen2_vl_72b": 72e9,
        "hubert_xlarge": 1.0e9,
    }
    for arch, n in expect.items():
        got = configs.get(arch).param_count()
        assert 0.7 * n < got < 1.4 * n, (arch, got, n)
