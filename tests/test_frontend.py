"""Serving frontend: traces, admission, routing, continuous scheduling, and
the preemption-to-host-tier contract.

The two headline invariants (ISSUE acceptance criteria):

  * a preempted-then-resumed request produces BIT-IDENTICAL output tokens
    to an uninterrupted run, with zero re-prefilled tokens — parked pages
    demote to their same-codec host tier (raw media copy, no transcode) and
    swap back in bit-exactly, even into a DIFFERENT batch slot;
  * the preemption demotion bills through exactly the same media-queue /
    kernel-dispatch accounting as a plain demotion cohort of the same pages.

Plus: scheduler-measured decode demand flows through
``BudgetArbiter.record_scheduled_demand`` into ``fleet_report()`` and the
``CapacityPlanner`` prices against it (not the synthetic telemetry sum).
"""

import math

import numpy as np
import pytest

import jax

import repro.configs as configs
from repro.configs.base import TierScapeRunConfig
from repro.frontend import (
    ADMIT,
    QUEUE,
    REFUSE,
    AdmissionController,
    ContinuousScheduler,
    DEFAULT_CLASSES,
    ReplicaRouter,
    TraceConfig,
    digest,
    generate,
)
from repro.frontend.traces import ArrivalEvent, check as trace_check
from repro.models import Model
from repro.serving import TieredEngine
from repro.serving.kv_cache import HOST4, HOST8, WARM, COLD


# ---------------------------------------------------------------------------
# Traces (pure numpy)
# ---------------------------------------------------------------------------


def test_trace_determinism_all_kinds():
    assert trace_check(seeds=(0, 5)) == 0


def test_trace_burst_pins_sla_and_raises_rate():
    cfg = TraceConfig(kind="burst", steps=96, rate=0.2, seed=1,
                      burst_every=32, burst_len=8, burst_mult=10.0, burst_sla=1)
    ev = generate(cfg)
    in_burst = [e for e in ev if (e.step % 32) < 8]
    out_burst = [e for e in ev if (e.step % 32) >= 8]
    assert len(in_burst) > len(out_burst)  # 10x rate over 1/4 of the steps
    assert all(e.sla == 1 for e in in_burst)


def test_trace_tenant_skew_flip():
    cfg = TraceConfig(kind="poisson", steps=200, rate=1.0, seed=2,
                      tenant_mix=(0.9, 0.1), tenant_flip_step=100)
    ev = generate(cfg)
    early = [e.tenant for e in ev if e.step < 100]
    late = [e.tenant for e in ev if e.step >= 100]
    assert np.mean(early) < 0.3 and np.mean(late) > 0.7


def test_trace_prompt_materialization_is_stable():
    cfg = TraceConfig(steps=16, rate=1.0, seed=4)
    a, b = generate(cfg), generate(cfg)
    assert digest(a) == digest(b)
    for x, y in zip(a[:5], b[:5]):
        assert np.array_equal(x.prompt(256), y.prompt(256))
        assert x.prompt(256).min() >= 1 and x.prompt(256).max() < 256


# ---------------------------------------------------------------------------
# Admission + router (pure)
# ---------------------------------------------------------------------------


def _event(sla=0, session=0, prompt=16, gen=8, seq=0):
    return ArrivalEvent(step=0, seq=seq, tenant=0, sla=sla, session=session,
                        prompt_len=prompt, max_new_tokens=gen, prompt_seed=1)


def test_admission_budget_and_queue_caps():
    ctl = AdmissionController(DEFAULT_CLASSES)
    kw = dict(capacity_tokens=1000, outstanding_tokens=0,
              headroom_tokens=1000, free_slot=True, queued_of_class=0)
    assert ctl.decide(_event(sla=0), **kw) == ADMIT
    # Over the batch class's 0.75 budget share -> refuse (load shed).
    assert ctl.decide(
        _event(sla=0), **{**kw, "outstanding_tokens": 740}) == REFUSE
    # Interactive (budget_frac=1.0) still admits at the same fill.
    assert ctl.decide(
        _event(sla=1), **{**kw, "outstanding_tokens": 740}) == ADMIT
    # Queue cap refuses regardless of budget.
    assert ctl.decide(
        _event(sla=1), **{**kw, "queued_of_class": 16}) == REFUSE
    # Under budget but no slot / no device headroom -> queue (backpressure).
    assert ctl.decide(_event(sla=0), **{**kw, "free_slot": False}) == QUEUE
    assert ctl.decide(_event(sla=0), **{**kw, "headroom_tokens": 3}) == QUEUE


def test_router_least_outstanding_with_session_affinity():
    r = ReplicaRouter(3)
    assert r.route(_event(session=7), [100, 40, 60]) == 1
    # Same session while live -> sticky, even though replica 2 is lighter.
    assert r.route(_event(session=7), [100, 90, 10]) == 1
    # Different session -> least outstanding; ties break to lowest index.
    assert r.route(_event(session=8), [50, 90, 50]) == 0
    # Session 7 drains fully -> affinity releases.
    r.note_done(_event(session=7))
    r.note_done(_event(session=7))
    assert r.route(_event(session=7), [100, 90, 10]) == 2


# ---------------------------------------------------------------------------
# Engine hooks (smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.get_smoke("qwen1_5_4b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(smoke_model, batch_slots=2, window_steps=10_000, **kw):
    cfg, model, params = smoke_model
    ts = TierScapeRunConfig(enabled=True, policy="analytical",
                            window_steps=window_steps)
    return TieredEngine(model, params, batch_slots=batch_slots, page_tokens=8,
                        max_seq_len=128, recent_window=16, ts=ts, **kw)


def test_request_rids_are_monotonic_across_queue_churn(smoke_model):
    """The satellite fix: rid=len(queue) collided once requests left the
    queue; rids must be unique for the engine's lifetime."""
    eng = _engine(smoke_model)
    rng = np.random.default_rng(0)
    p = rng.integers(1, 256, 8).astype(np.int32)
    a = eng.submit(p, 4)
    b = eng.submit(p, 4)
    eng.queue.clear()  # requests left the queue (as slot placement does)
    c = eng.submit(p, 4)
    d = eng.make_request(p, 4)
    rids = [a.rid, b.rid, c.rid, d.rid]
    assert rids == [0, 1, 2, 3]
    assert len(set(rids)) == 4


def test_preempt_resume_bit_identical_zero_reprefill(smoke_model):
    """Preempted-then-resumed (into a DIFFERENT slot, with another request
    churning the pools in between) == uninterrupted run, token for token;
    zero re-prefilled tokens; pages restored from the host tier."""
    cfg, _, _ = smoke_model
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
    other_prompt = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)

    ea = _engine(smoke_model)
    ra = ea.make_request(prompt, 20)
    ea.start_request(0, ra)
    while not ra.done:
        ea.step()

    eb = _engine(smoke_model)
    rb = eb.make_request(prompt, 20)
    eb.start_request(0, rb)
    for _ in range(5):
        eb.step()
    pre = eb.preempt_slot(0)
    # Parked pages live on host tiers only, with device restore targets.
    assert len(pre.parked.pages) > 0
    assert all(pg.host_level in (HOST8, HOST4) for pg in pre.parked.pages)
    assert any(pg.restore_level in (WARM, COLD) for pg in pre.parked.pages)
    # Churn the pools while parked: another request uses the vacated slot.
    other = eb.make_request(other_prompt, 6)
    eb.start_request(0, other)
    while not other.done:
        eb.step()
    eb.resume_into(1, pre)  # cross-slot restore
    while not rb.done:
        eb.step()
    stats = eb.finish()

    assert rb.out_tokens == ra.out_tokens
    assert stats.re_prefill_tokens == 0
    assert stats.preemptions == 1 and stats.resumes == 1
    assert stats.resumed_pages == len(pre.parked.pages)


def test_preemption_bills_like_plain_demotion(smoke_model):
    """``demote_slot_to_host`` must charge the media queues and the kernel
    dispatch counter exactly like a plain pipeline demotion of the same
    pages to the same destinations."""
    cfg, _, _ = smoke_model
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, cfg.vocab_size, 32).astype(np.int32)

    def billing(cache):
        return {
            name: (q.bytes_total, q.ops, round(q.busy_s, 12))
            for name, q in cache.media_queues.items()
        }

    engines, snaps = [], []
    for mode in ("plain", "preempt"):
        eng = _engine(smoke_model)
        req = eng.make_request(prompt, 4)
        eng.start_request(0, req)
        cache = eng.cache
        before = billing(cache)
        disp_before = cache.kernel_dispatches
        if mode == "plain":
            rids = cache.slot_rids(0)
            dev = rids[np.isin(cache.physical[rids], (WARM, COLD))]
            bits = np.array([cache._bits[int(s)] for s in cache.physical[dev]])
            dsts = np.where(bits == 8, HOST8, HOST4).astype(np.int64)
            cache.pipeline.submit(cache.plan_cohorts(dev, dsts))
            cache.pipeline.drain()
        else:
            levels = eng.cache.demote_slot_to_host(0)
            assert levels and all(v in (WARM, COLD) for v in levels.values())
        after = billing(cache)
        delta = {
            n: tuple(np.subtract(after[n], before[n])) for n in after
        }
        snaps.append((delta, cache.kernel_dispatches - disp_before))
        engines.append(eng)

    assert snaps[0] == snaps[1]
    # Same-codec demotion: raw copy, real bytes on the host swap device.
    moved_bytes = snaps[1][0]["host_dram_pcie"][0]
    assert moved_bytes > 0
    # Pages ended up host-resident in both runs, identically placed.
    a, b = engines[0].cache, engines[1].cache
    assert np.array_equal(a.physical, b.physical)
    assert bool(np.isin(a.physical[a.slot_rids(0)], (HOST8, HOST4)).all())


def test_park_restore_table_invariants(smoke_model):
    """After park the slot is empty everywhere (tables, allocators, host
    store); after restore the placements equal the pre-preemption state."""
    cfg, _, _ = smoke_model
    rng = np.random.default_rng(13)
    prompt = rng.integers(1, cfg.vocab_size, 40).astype(np.int32)
    eng = _engine(smoke_model)
    req = eng.make_request(prompt, 4)
    eng.start_request(0, req)
    cache = eng.cache
    rids_before = cache.slot_rids(0)
    phys_before = cache.physical[rids_before].copy()
    assert rids_before.size > 0

    pre = eng.preempt_slot(0)
    assert cache.slot_rids(0).size == 0
    assert not any(
        int(r) in cache.host_pages for r in rids_before
    )
    st = cache.state
    assert int(np.asarray(st.warm_n)[:, 0].sum()) == 0
    assert int(np.asarray(st.cold_n)[:, 0].sum()) == 0
    assert int(np.asarray(st.host_n)[:, 0].sum()) == 0
    assert int(st.recent_len[0]) == 0 and int(st.total_len[0]) == 0

    eng.resume_into(0, pre)
    rids_after = cache.slot_rids(0)
    assert np.array_equal(rids_after, rids_before)
    assert np.array_equal(cache.physical[rids_after], phys_before)
    st = cache.state
    assert int(st.total_len[0]) == int(eng.slot_len[0])


def test_try_submit_refuses_over_budget(smoke_model):
    eng = _engine(smoke_model)
    cap = eng.token_capacity()
    assert cap > 0
    ok = eng.try_submit(np.ones(8, np.int32), 8)
    assert ok is not None
    huge = eng.try_submit(np.ones(16, np.int32), cap)
    assert huge is None
    # The refused request never entered the queue.
    assert len(eng.queue) == 1


# ---------------------------------------------------------------------------
# Scheduler end-to-end
# ---------------------------------------------------------------------------


def test_scheduler_burst_preempts_and_resumes(smoke_model):
    cfg, _, _ = smoke_model
    tc = TraceConfig(kind="burst", steps=60, rate=0.10, seed=3,
                     sla_mix=(0.85, 0.15), burst_every=24, burst_len=4,
                     burst_mult=8.0, burst_sla=1, prompt_len=(10, 18),
                     new_tokens=(8, 14), n_tenants=2, tenant_mix=(0.8, 0.2),
                     tenant_flip_step=30)
    events = generate(tc)
    engines = [_engine(smoke_model, window_steps=16) for _ in range(2)]
    sched = ContinuousScheduler(engines, events, cfg.vocab_size,
                                prefill_chunk_tokens=8)
    stats = sched.run(max_steps=600)

    assert stats.preemptions >= 1 and stats.resumes >= 1
    assert stats.re_prefill_tokens == 0
    assert stats.resumed_pages >= 1
    assert len(stats.done()) + stats.refused == len(events)
    # Every completed request got exactly its requested tokens, one per
    # virtual step (TBT >= 1; preemption gaps stretch but never duplicate).
    for rec in stats.done():
        assert len(rec.token_steps) == rec.event.max_new_tokens
        assert (rec.tbt() >= 1).all()
        # Chunked prefill: first token lands exactly chunks-1 steps after
        # placement (one chunk per step, interleaved with decode).
        chunks = max(math.ceil(rec.event.prompt_len / 8), 1)
        assert rec.first_token_step - rec.place_step == chunks - 1
    # Demand windows account for every decoded token.
    assert sum(sum(w.values()) for w in stats.demand_windows) == stats.decoded_tokens
    s = stats.summary()
    assert s["interactive"]["completed"] >= 1
    assert s["batch"]["completed"] >= 1


# ---------------------------------------------------------------------------
# Scheduled demand -> arbiter -> planner
# ---------------------------------------------------------------------------


def test_scheduled_demand_flows_to_fleet_report_and_planner():
    from repro.core import capacity, simulator
    from repro.core.arbiter import TenantSpec
    from repro.frontend.scheduler import FrontendStats

    def workloads():
        return [
            simulator.skew_flip(n_regions=128, accesses_hot=50_000,
                                accesses_cold=5_000, flip_window=4,
                                hot_first=True, name="early"),
            simulator.skew_flip(n_regions=128, accesses_hot=50_000,
                                accesses_cold=5_000, flip_window=4,
                                hot_first=False, name="late"),
        ]

    specs = [TenantSpec("early", sla_weight=1.0),
             TenantSpec("late", sla_weight=1.0)]
    cfg = capacity.PlannerConfig("6t", alpha=0.5, fast_fraction=0.5)
    arb = capacity.build_arbiter(cfg, specs, 128)
    simulator.simulate_multitenant(workloads(), arb, windows=8,
                                   warmup_windows=2, seed=7, prefetch=False)
    synthetic = arb.fleet_report(last_windows=6).tenant_demand_accesses

    # Scheduler-measured decode demand (as FrontendStats would feed it).
    stats = FrontendStats(records=[], classes=DEFAULT_CLASSES)
    stats.demand_windows = [{0: 120.0, 1: 30.0}, {0: 80.0, 1: 50.0},
                            {0: 100.0}]
    fed = stats.feed_arbiter(arb, ("early", "late"))
    assert fed == 3

    report = arb.fleet_report(last_windows=6)
    assert report.tenant_demand_accesses == (100.0, 80.0 / 3)
    assert report.tenant_demand_accesses != synthetic

    planner = capacity.CapacityPlanner(capacity.get_server("v5e-base"),
                                       fleet_scale=64)
    point = planner.evaluate(cfg.name, report)
    assert point.servers >= 1 and 0.0 <= point.savings_pct <= 100.0

    with pytest.raises(KeyError):
        arb.record_scheduled_demand({"nobody": 1.0})
