"""Runtime: sharding rules, train/serve step builders on a 1-device mesh,
roofline HLO analyzer."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.configs.base import ParallelConfig
from repro.launch.mesh import make_abstract_mesh, make_mesh
from repro.models import Model
from repro.models.inputs import make_train_batch
from repro.optim import adamw
from repro.roofline import hlo_stats
from repro.runtime import sharding as shr
from repro.runtime import train as train_rt


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def test_param_specs_structure_matches_params():
    cfg = configs.get_smoke("qwen3_32b")
    model = Model(cfg)
    params = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    specs = shr.param_specs(params, cfg, _mesh11(), ParallelConfig())
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) == \
        jax.tree.structure(params)


def test_param_specs_divisibility_respected():
    """Every spec must divide its dimension on the production mesh shape."""
    # AbstractMesh: spec logic only needs axis sizes, not real devices.
    mesh = make_abstract_mesh((2, 4), ("data", "model"))
    for arch in configs.arch_ids():
        cfg = configs.get(arch)
        model = Model(cfg)
        params = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        for parallel in (ParallelConfig(fsdp=False), ParallelConfig(fsdp=True)):
            specs = shr.param_specs(params, cfg, mesh, parallel)

            def check(path, leaf, spec):
                for dim, names in zip(leaf.shape, spec):
                    if names is None:
                        continue
                    ns = names if isinstance(names, tuple) else (names,)
                    size = int(np.prod([mesh.shape[n] for n in ns]))
                    assert dim % size == 0, (arch, path, leaf.shape, spec)

            jax.tree_util.tree_map_with_path(
                lambda p, l, s: check(p, l, s), params, specs,
            )


def test_batch_axes_divisibility():
    mesh = make_abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    assert shr.batch_axes_for(mesh, 8) == ("pod", "data")
    assert shr.batch_axes_for(mesh, 2) == ("pod",)
    assert shr.batch_axes_for(mesh, 1) == ()
    assert shr.batch_axes_for(mesh, 3) == ()


def test_train_step_runs_on_one_device():
    cfg = configs.get_smoke("internlm2_20b")
    mesh = _mesh11()
    model = Model(cfg, ParallelConfig())
    batch = make_train_batch(cfg, batch=2, seq=32)
    step = train_rt.make_train_step(
        model, adamw.AdamWConfig(lr=1e-3), mesh, ParallelConfig(grad_accum=2),
        batch_example=batch,
    )
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    with mesh:
        fn = step.jitted(donate=False)
        p1, o1, m1 = fn(params, opt, batch)
        p2, o2, m2 = fn(p1, o1, batch)
    assert int(o2["step"]) == 2
    assert np.isfinite(float(m2["grad_norm"]))


def test_grad_accum_matches_full_batch():
    """accum=2 over a batch == one step on the whole batch (linearity)."""
    cfg = configs.get_smoke("command_r_35b")
    mesh = _mesh11()
    model = Model(cfg, ParallelConfig())
    batch = make_train_batch(cfg, batch=4, seq=16)
    params = model.init(jax.random.PRNGKey(0))

    outs = {}
    for accum in (1, 2):
        step = train_rt.make_train_step(
            model, adamw.AdamWConfig(lr=1e-3), mesh, ParallelConfig(grad_accum=accum),
            batch_example=batch,
        )
        opt = adamw.init(params)
        with mesh:
            p1, _, _ = step.jitted(donate=False)(params, opt, batch)
        outs[accum] = p1
    flat1 = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                             for x in jax.tree.leaves(outs[1])])
    flat2 = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                             for x in jax.tree.leaves(outs[2])])
    # Same direction & magnitude (not bitwise: loss-normalization order differs).
    cos = jnp.dot(flat1, flat2) / (jnp.linalg.norm(flat1) * jnp.linalg.norm(flat2))
    assert float(cos) > 0.99


# ---------------------------------------------------------------------------
# HLO static analyzer
# ---------------------------------------------------------------------------


def test_hlo_stats_counts_loop_trips():
    """A scanned matmul must report trips x the per-iteration flops."""
    n, trips = 128, 7

    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=trips)
        return h

    w = jnp.zeros((n, n), jnp.float32)
    x = jnp.zeros((4, n), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    st = hlo_stats.analyze(compiled.as_text())
    expect = 2 * 4 * n * n * trips
    assert st.flops == pytest.approx(expect, rel=0.01), (st.flops, expect)


def test_hlo_stats_nested_loops():
    n, outer, inner = 64, 3, 5

    def f(w, x):
        def outer_body(h, _):
            def inner_body(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner_body, h, None, length=inner)
            return g, None
        h, _ = jax.lax.scan(outer_body, x, None, length=outer)
        return h

    w = jnp.zeros((n, n), jnp.float32)
    x = jnp.zeros((2, n), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    st = hlo_stats.analyze(compiled.as_text())
    expect = 2 * 2 * n * n * outer * inner
    assert st.flops == pytest.approx(expect, rel=0.01)


def test_hlo_stats_unlooped_matmul():
    def f(a, b):
        return a @ b

    a = jnp.zeros((32, 64), jnp.float32)
    b = jnp.zeros((64, 16), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    st = hlo_stats.analyze(compiled.as_text())
    assert st.flops == pytest.approx(2 * 32 * 64 * 16, rel=0.01)
