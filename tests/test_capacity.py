"""Fleet capacity planner + TCO-model edge cases.

Property-based tests (seeded-numpy case sweeps, see tests/proptest.py) pin:
  * the Eq. 9-12 ordering tco_min <= tco_nt <= tco_max over random
    placements and measured ratios, and Eq. 2's budget monotone in alpha,
  * the zero-region / empty-fleet degenerate cases return 0.0 savings
    (not a division by zero),
  * ServerSpec amortization decomposes into its cost components and the
    bin-packer's server count stays within its load bounds,
  * the planner sweep is deterministic: the same grid on the same seed
    emits byte-identical frontier JSON, and the frontier is Pareto.
"""

import numpy as np
import pytest

from repro.core import capacity, tco
from repro.core.capacity import (
    BW,
    DECODE,
    GIB,
    MEM,
    CapacityPlanner,
    FrontierPoint,
    PlannerConfig,
    ServerSpec,
    get_server,
)
from repro.core.tiers import default_tierset

from proptest import cases, draw_float, draw_int


# ---------------------------------------------------------------------------
# Eq. 9-12 ordering + Eq. 2 budget (property sweeps)
# ---------------------------------------------------------------------------


def test_tco_ordering_random_placements():
    ts = default_tierset(2048)
    for i, rng in cases(40):
        n = draw_int(rng, 1, 512)
        region_bytes = draw_int(rng, 1, 64) * 4096
        placement = rng.integers(0, ts.n_tiers + 1, size=n)
        ratios = None
        if draw_int(rng, 0, 1):
            # Measured compressibility >= 1.0: media never inflates data.
            ratios = [draw_float(rng, 1.0, 40.0) for _ in range(ts.n_tiers)]
        mn = tco.tco_min(ts, n, region_bytes, ratios)
        mx = tco.tco_max(n, region_bytes)
        nt = tco.tco_nt(ts, placement, region_bytes, ratios)
        assert mn <= nt + 1e-9, (i, mn, nt)
        assert nt <= mx + 1e-9, (i, nt, mx)
        s = tco.savings_pct(ts, placement, region_bytes, ratios)
        assert -1e-9 <= s <= 100.0 + 1e-9, (i, s)


def test_budget_monotone_in_alpha():
    ts = default_tierset(2048)
    for i, rng in cases(25):
        n = draw_int(rng, 1, 256)
        region_bytes = draw_int(rng, 1, 64) * 4096
        alphas = sorted(draw_float(rng, 0.0, 1.0) for _ in range(4))
        budgets = [tco.budget(ts, n, region_bytes, a) for a in alphas]
        assert all(b0 <= b1 + 1e-9 for b0, b1 in zip(budgets, budgets[1:])), (
            i, alphas, budgets,
        )
        assert abs(budgets[0] - tco.budget(ts, n, region_bytes, alphas[0])) == 0.0
    # Endpoints: alpha=0 -> tco_min, alpha=1 -> tco_max.
    assert tco.budget(ts, 64, 4096, 0.0) == pytest.approx(tco.tco_min(ts, 64, 4096))
    assert tco.budget(ts, 64, 4096, 1.0) == pytest.approx(tco.tco_max(64, 4096))


def test_zero_region_and_empty_fleet_save_nothing():
    ts = default_tierset(2048)
    empty = np.zeros(0, dtype=np.int64)
    assert tco.savings_pct(ts, empty, 4096) == 0.0
    assert tco.fleet_tco_usd([]) == 0.0
    assert tco.fleet_savings_pct([]) == 0.0
    assert tco.fleet_savings_pct(iter([])) == 0.0  # generator, not just list


# ---------------------------------------------------------------------------
# ServerSpec cost model
# ---------------------------------------------------------------------------


def test_server_amortized_cost_components():
    s = get_server("v5e-base")
    purchase = s.purchase_usd()
    years = 3.0
    total = s.amortized_usd(years)
    expected = (
        purchase
        + s.deployment_usd
        + s.annual_maintenance_pct / 100.0 * purchase * years
        + s.rack_usd_per_year * years
        + s.power_kw * 24.0 * 365.0 * years * s.usd_per_kwh
    )
    assert total == pytest.approx(expected)
    # Owning longer always costs more; purchase is a floor.
    assert s.amortized_usd(5.0) > total > purchase
    with pytest.raises(ValueError):
        s.amortized_usd(0.0)


def test_server_catalog_capacity_vectors():
    base = get_server("v5e-base").capacity_vector()
    assert base[MEM + "hbm"] == 16.0 * GIB
    assert base[MEM + "host_dram_pcie"] == 512.0 * GIB
    assert MEM + "cxl" not in base  # no CXL attach on the base spec
    cxl = get_server("v5e-cxl").capacity_vector()
    assert cxl[MEM + "cxl"] == 1024.0 * GIB and BW + "cxl" in cxl
    with pytest.raises(KeyError):
        get_server("nope")


# ---------------------------------------------------------------------------
# Bin-packing bounds
# ---------------------------------------------------------------------------


def test_pack_bounds_random_demands():
    """FFD server count is sandwiched by the volume lower bound and the
    one-server-per-shard upper bound, and oversized tenants are sharded."""
    server = ServerSpec("t", hbm_gb=1.0, host_dram_gb=4.0,
                        decode_accesses_per_window=1e6)
    planner = CapacityPlanner(server, fleet_scale=1)
    cap = server.capacity_vector()
    for i, rng in cases(30):
        demands = []
        for _ in range(draw_int(rng, 1, 12)):
            demands.append({
                MEM + "hbm": draw_float(rng, 0.0, 2.5) * cap[MEM + "hbm"],
                DECODE: draw_float(rng, 0.0, 1.5) * cap[DECODE],
            })
        servers = planner.pack(demands)
        lower = max(
            int(np.ceil(sum(d[k] for d in demands) / cap[k]))
            for k in (MEM + "hbm", DECODE)
        )
        shards = sum(
            max(int(np.ceil(max(v / cap[k] for k, v in d.items()))), 1)
            for d in demands
        )
        assert lower <= servers <= shards, (i, lower, servers, shards)


def test_pack_shards_oversized_tenant():
    server = ServerSpec("t", hbm_gb=1.0, host_dram_gb=1.0)
    planner = CapacityPlanner(server, fleet_scale=1)
    # 3.5 servers' worth of HBM in one tenant -> 4 shards fit in 4 servers.
    assert planner.pack([{MEM + "hbm": 3.5 * GIB}]) == 4
    assert planner.pack([{MEM + "hbm": 0.25 * GIB} for _ in range(8)]) == 2
    with pytest.raises(ValueError):
        planner.pack([{BW + "nvme": 1.0}])  # no NVMe on this spec


# ---------------------------------------------------------------------------
# Frontier geometry
# ---------------------------------------------------------------------------


def _pt(name, savings, p99, usd=100.0):
    return FrontierPoint(config=name, servers=1, fleet_usd=usd,
                         memory_tco_usd=0.0, savings_pct=savings,
                         p50_penalty_s=p99 / 2, p99_penalty_s=p99,
                         perf_per_dollar=1.0)


def test_pareto_frontier_properties():
    for i, rng in cases(30):
        pts = [
            _pt(f"c{j}", draw_float(rng, 0.0, 80.0), draw_float(rng, 0.0, 10.0),
                usd=draw_float(rng, 10.0, 100.0))
            for j in range(draw_int(rng, 1, 16))
        ]
        front = CapacityPlanner.pareto_frontier(pts)
        assert front, i
        # Sorted by latency, savings strictly increasing.
        for a, b in zip(front, front[1:]):
            assert a.p99_penalty_s <= b.p99_penalty_s + 1e-12
            assert b.savings_pct > a.savings_pct
        # No dropped point dominates a frontier point.
        for p in pts:
            for f in front:
                assert not (
                    p.savings_pct > f.savings_pct + 1e-9
                    and p.p99_penalty_s < f.p99_penalty_s - 1e-9
                ), (i, p, f)


def test_dominance_margin():
    base = _pt("2t", savings=20.0, p99=1.0)
    front = [_pt("a", 30.0, 0.5), _pt("b", 50.0, 2.0)]
    # Only "a" is within the latency tolerance; margin is vs it.
    m = CapacityPlanner.dominance_margin_pct(front, base)
    assert m == pytest.approx(10.0)
    assert CapacityPlanner.dominance_margin_pct([_pt("c", 90.0, 99.0)], base) == -np.inf


# ---------------------------------------------------------------------------
# End-to-end planner determinism (small sweep through the live simulator)
# ---------------------------------------------------------------------------


def _tiny_sweep():
    from repro.core import simulator
    from repro.core.arbiter import TenantSpec

    def workloads():
        return [
            simulator.skew_flip(n_regions=128, accesses_hot=50_000,
                                accesses_cold=5_000, flip_window=4,
                                hot_first=True, name="early"),
            simulator.skew_flip(n_regions=128, accesses_hot=50_000,
                                accesses_cold=5_000, flip_window=4,
                                hot_first=False, name="late"),
        ]

    specs = [TenantSpec("early", sla_weight=1.0),
             TenantSpec("late", sla_weight=1.0)]
    planner = CapacityPlanner(get_server("v5e-base"), fleet_scale=64)
    grid = [PlannerConfig("2t", fast_fraction=0.5),
            PlannerConfig("6t", alpha=0.5, fast_fraction=0.5),
            PlannerConfig("split", alpha=0.5, fast_fraction=0.5)]
    return capacity.sweep_frontier(workloads, specs, planner, configs=grid,
                                   windows=8, warmup_windows=2, seed=7)


def test_planner_sweep_deterministic_and_well_formed():
    a = _tiny_sweep()
    b = _tiny_sweep()
    assert capacity.frontier_json(a) == capacity.frontier_json(b)
    assert [p["config"] for p in a["points"]] == [
        "2t-f0.50", "6t-a0.50-f0.50", "split84-a0.50-f0.50",
    ]
    for p in a["points"]:
        assert p["servers"] >= 1
        assert p["fleet_usd"] > 0
        assert p["p50_penalty_s"] <= p["p99_penalty_s"] + 1e-12
    assert a["monotone"] is True
    assert a["baseline_2t"]["config"] == "2t-f0.50"
    # The frontier is a subset of the evaluated points.
    names = {p["config"] for p in a["points"]}
    assert all(p["config"] in names for p in a["frontier"])


def test_fleet_report_consistent_with_planner_inputs():
    cfg = PlannerConfig("6t", alpha=0.5, fast_fraction=0.5)
    from repro.core import simulator
    from repro.core.arbiter import TenantSpec

    def workloads():
        return [
            simulator.skew_flip(n_regions=128, accesses_hot=50_000,
                                accesses_cold=5_000, flip_window=4,
                                hot_first=True, name="early"),
            simulator.skew_flip(n_regions=128, accesses_hot=50_000,
                                accesses_cold=5_000, flip_window=4,
                                hot_first=False, name="late"),
        ]

    specs = [TenantSpec("early", sla_weight=1.0),
             TenantSpec("late", sla_weight=1.0)]
    report = capacity.simulate_and_report(cfg, workloads, specs, windows=8,
                                          warmup_windows=2, seed=7)
    assert report.windows == 6
    assert report.tenant_names == ("early", "late")
    assert report.per_window_penalty_s.shape == (6,)
    for t in range(2):
        assert report.tenant_footprint_bytes[t] == 128 * 2 * 1024 * 1024
        resident = sum(report.tenant_bytes_by_device[t].values())
        # Compressed tiers shrink bytes: resident <= uncompressed footprint.
        assert 0 < resident <= report.tenant_footprint_bytes[t] + 1e-6
        assert report.tenant_demand_accesses[t] > 0
    assert 0.0 <= report.budget_feasible_frac <= 1.0
    # The planner consumes it without error and prices a sane point.
    planner = CapacityPlanner(get_server("v5e-base"), fleet_scale=64)
    point = planner.evaluate(cfg.name, report)
    assert point.servers >= 1 and 0.0 <= point.savings_pct <= 100.0
