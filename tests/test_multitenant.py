"""Multi-tenant tiersets: BudgetArbiter invariants, shared-pool accounting,
tenant isolation in the serving cache, and the vectorized telemetry fold.

Pinned invariants (the arbiter's contract):
  * allotted budgets sum exactly to the global budget when SLA floors fit,
  * per-tier usage across tenants never exceeds the shared pool capacity,
  * allocations are deterministic under fixed seeds,
  * a starved tenant keeps at least its SLA-floor budget,
  * no tenant reads another tenant's pages (slot ownership is the boundary).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import simulator
from repro.core.arbiter import BudgetArbiter, TenantSpec
from repro.core.manager import ManagerConfig, make_manager
from repro.core.pools import SlotAllocator, TenantLedger
from repro.serving.kv_cache import COLD, HOST4, HOST8, WARM, TieredKVCache

N = 256
ACC = 50_000


def hot_cold_workloads(n=N):
    return [
        simulator.gaussian_kv(n_regions=n, accesses_per_window=ACC,
                              sigma_frac=0.08, name="hot"),
        simulator.uniform_scan(n_regions=n, accesses_per_window=ACC // 10,
                               compute_s_per_window=1.0, name="cold"),
    ]


def two_tenant_arbiter(weights=(1.0, 1.0), floors=(0.0, 0.0), caps=None,
                       config="6T-AM-0.5", n=N, alpha=0.5):
    specs = [TenantSpec("a", sla_weight=weights[0], alpha_floor=floors[0]),
             TenantSpec("b", sla_weight=weights[1], alpha_floor=floors[1])]
    managers = [make_manager(config, n, seed=t) for t in range(2)]
    return BudgetArbiter(specs, managers, alpha=alpha, tier_capacity_regions=caps)


# ---------------------------------------------------------------------------
# budget waterfilling
# ---------------------------------------------------------------------------


def test_budgets_sum_to_global_budget():
    arb = two_tenant_arbiter()
    simulator.simulate_multitenant(hot_cold_workloads(), arb, windows=6, seed=0)
    for ws in arb.history:
        assert ws.budget_feasible
        total = sum(ts.budget_usd for ts in ws.tenants)
        assert total == pytest.approx(ws.global_budget_usd, rel=1e-9)
        # Committed spend never exceeds the allotment (analytical tenants).
        for ts in ws.tenants:
            assert ts.spent_usd <= ts.budget_usd * (1 + 1e-9)


def test_ledger_tracks_usage_within_capacity():
    n_opts = 6  # DRAM + 5 selected tiers
    caps = np.full(n_opts, 2.0 * N)
    caps[0] = N  # fast tier can hold only half the fleet
    arb = two_tenant_arbiter(caps=caps)
    simulator.simulate_multitenant(hot_cold_workloads(), arb, windows=6, seed=0)
    ledger = arb.ledger
    # Every tenant's regions are fully accounted, and no tier over capacity.
    for name, m in zip(("a", "b"), arb.managers):
        assert ledger.tenant_usage(name).sum() == m.n_regions
    assert not ledger.oversubscribed().any()
    assert (ledger.usage.sum(axis=0) <= caps).all()


def test_capacity_reconcile_enforces_fast_tier_cap():
    caps = np.full(6, np.inf)
    caps[0] = N // 4  # tight fleet-wide fast-tier capacity
    arb = two_tenant_arbiter(caps=caps, alpha=0.9)  # alpha->perf: wants DRAM
    simulator.simulate_multitenant(hot_cold_workloads(), arb, windows=6, seed=0)
    for ws in arb.history:
        assert sum(ts.fast_regions for ts in ws.tenants) <= N // 4


def test_capacity_reconcile_prefers_above_floor_victims():
    """Identical workloads, tight shared fast tier: the capacity pass must
    take its victims from the unfloored tenant first, so the floored tenant
    keeps more fast-tier residency and higher spend."""
    def wls():
        return [
            simulator.gaussian_kv(n_regions=N, accesses_per_window=ACC,
                                  sigma_frac=0.1, name="w1"),
            simulator.gaussian_kv(n_regions=N, accesses_per_window=ACC,
                                  sigma_frac=0.1, name="w2"),
        ]
    caps = np.full(6, np.inf)
    caps[0] = N // 3
    arb = two_tenant_arbiter(floors=(0.0, 0.6), caps=caps, alpha=0.9)
    res = simulator.simulate_multitenant(wls(), arb, windows=6, seed=0)
    unfloored, floored = res.tenants
    for ws in arb.history:
        assert sum(ts.fast_regions for ts in ws.tenants) <= N // 3
    assert floored.mean_fast_regions > unfloored.mean_fast_regions
    for ws in arb.history:
        assert ws.tenants[1].spent_usd > ws.tenants[0].spent_usd


def test_capacity_overflow_spills_upward_when_deep_tiers_full():
    """When the constrained tier is the deepest one, overflow must spill
    into faster tiers (total capacity holds the fleet) instead of raising."""
    caps = np.array([2.0 * N, N / 2])  # 2T tierset, tight compressed tier
    arb = two_tenant_arbiter(config="2T-M", caps=caps)
    # All-cold traffic: waterfall pushes every region into tier 1.
    idle = [
        simulator.Workload("idle%d" % t, N, 10, 1.0,
                           lambda w, rng: np.zeros(N))
        for t in range(2)
    ]
    simulator.simulate_multitenant(idle, arb, windows=4, seed=0)
    assert (arb.ledger.usage.sum(axis=0) <= caps).all()
    assert not arb.ledger.oversubscribed().any()


def test_arbiter_rejects_infeasible_capacity():
    caps = np.full(6, 10.0)  # cannot hold 2*N regions anywhere
    with pytest.raises(ValueError):
        two_tenant_arbiter(caps=caps)


def test_arbiter_deterministic_under_fixed_seed():
    runs = []
    for _ in range(2):
        arb = two_tenant_arbiter()
        simulator.simulate_multitenant(hot_cold_workloads(), arb, windows=5, seed=3)
        runs.append(arb)
    for wa, wb in zip(runs[0].history, runs[1].history):
        for ta, tb in zip(wa.tenants, wb.tenants):
            assert ta.budget_usd == tb.budget_usd
            assert ta.spent_usd == tb.spent_usd
            assert ta.fast_regions == tb.fast_regions
    for ma, mb in zip(runs[0].managers, runs[1].managers):
        np.testing.assert_array_equal(ma.placement, mb.placement)


def test_starved_tenant_meets_sla_floor():
    """Tenant b is starved (traffic dwarfed by a's) but holds alpha_floor=0.4:
    the waterfill must stop demoting it at its floor every window."""
    wls = [
        simulator.gaussian_kv(n_regions=N, accesses_per_window=ACC * 4,
                              sigma_frac=0.05, name="noisy"),
        simulator.uniform_scan(n_regions=N, accesses_per_window=ACC // 50,
                               compute_s_per_window=1.0, name="starved"),
    ]
    # alpha=0.1: deep fleet-wide demotion pressure, so the floor must bind.
    arb = two_tenant_arbiter(floors=(0.0, 0.4), alpha=0.1)
    simulator.simulate_multitenant(wls, arb, windows=6, seed=0)
    floorless = two_tenant_arbiter(floors=(0.0, 0.0), alpha=0.1)
    simulator.simulate_multitenant(wls, floorless, windows=6, seed=0)
    bound = 0
    for ws, ws0 in zip(arb.history, floorless.history):
        starved = ws.tenants[1]
        assert starved.budget_usd >= starved.sla_floor_usd * (1 - 1e-9)
        if ws0.tenants[1].budget_usd < starved.sla_floor_usd:
            # Without the floor the waterfill demotes the starved tenant
            # below it; with the floor its allotment is frozen at/above.
            bound += 1
    assert bound > 0, "scenario never exercised the SLA floor"


def test_sla_weight_shifts_fast_tier():
    """Identical workloads; the high-SLA tenant keeps more fast tier."""
    def wls():
        return [
            simulator.gaussian_kv(n_regions=N, accesses_per_window=ACC,
                                  sigma_frac=0.1, name="w1"),
            simulator.gaussian_kv(n_regions=N, accesses_per_window=ACC,
                                  sigma_frac=0.1, name="w2"),
        ]
    arb = two_tenant_arbiter(weights=(4.0, 1.0))
    res = simulator.simulate_multitenant(wls(), arb, windows=8, seed=0)
    heavy, light = res.tenants
    assert heavy.mean_fast_regions >= light.mean_fast_regions
    assert heavy.mean_budget_usd > light.mean_budget_usd


def test_hot_tenant_wins_fast_tier_and_aggregate_within_5pct():
    """The acceptance scenario: the arbiter trades fast-tier budget toward
    the hotter tenant while aggregate TCO savings stay within 5% of the
    single-tenant (one manager over both footprints) baseline."""
    wls = hot_cold_workloads()
    arb = two_tenant_arbiter()
    res = simulator.simulate_multitenant(wls, arb, windows=10, seed=0)
    hot, cold = res.tenants
    assert hot.mean_fast_regions > cold.mean_fast_regions + N // 10

    single = make_manager("6T-AM-0.5", 2 * N, seed=0)
    baseline = simulator.simulate_single_tenant_baseline(
        wls, single, windows=10, warmup_windows=2, seed=0
    )
    assert abs(res.fleet_savings_pct - baseline) <= 5.0


def test_waterfall_tenants_share_arbiter():
    """Non-analytical tenants plan by threshold; the arbiter still bounds
    them through capacity reconciliation."""
    caps = np.full(2, np.inf)  # 2T tierset: DRAM + one tier
    caps[0] = N // 2
    arb = two_tenant_arbiter(config="2T-M", caps=caps)
    res = simulator.simulate_multitenant(hot_cold_workloads(), arb, windows=6, seed=0)
    for ws in arb.history:
        assert sum(ts.fast_regions for ts in ws.tenants) <= N // 2
    assert res.windows == 6


# ---------------------------------------------------------------------------
# shared-pool accounting primitives
# ---------------------------------------------------------------------------


def test_slot_allocator_tenant_quota():
    sa = SlotAllocator(8, tenant_quota={"a": 3, "b": 5})
    for i in range(3):
        sa.alloc(i, tenant="a")
    with pytest.raises(MemoryError):
        sa.alloc(99, tenant="a")
    assert sa.used_by("a") == 3
    slots = [sa.alloc(10 + i, tenant="b") for i in range(5)]
    assert sa.used == 8
    sa.free(slots[0])
    assert sa.used_by("b") == 4
    sa.alloc(20, tenant="b")  # freed headroom is reusable
    with pytest.raises(ValueError):
        SlotAllocator(4, tenant_quota={"a": 3, "b": 3})


def test_tenant_ledger_reservations():
    ledger = TenantLedger(["a", "b"], np.array([4.0, 8.0]))
    ledger.set_usage("a", np.array([2, 3]))
    ledger.set_usage("b", np.array([1, 2]))
    assert ledger.headroom(0) == 1
    assert ledger.reserve("a", 0, 1)
    assert not ledger.reserve("b", 0, 1)  # capacity exhausted by reservation
    ledger.release("a", 0, 1)
    assert ledger.reserve("b", 0, 1)
    assert not ledger.oversubscribed().any()
    ledger.set_usage("b", np.array([4, 2]))
    assert ledger.oversubscribed()[0]


# ---------------------------------------------------------------------------
# serving cache: tenant isolation + vectorized telemetry fold
# ---------------------------------------------------------------------------

CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
)


def make_cache(layers=2, slots=4, page_tokens=8, max_seq=64, warm_frac=0.5):
    return TieredKVCache(
        CFG, layers, slots, page_tokens, max_seq, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=0.5),
        warm_frac=warm_frac,
    )


def fill_cache(cache, rng, n_pages):
    coords = [
        (la, sl, pg)
        for la in range(cache.la)
        for sl in range(cache.bs)
        for pg in range(cache.max_pages)
    ][:n_pages]
    kv, hd = CFG.n_kv_heads, CFG.head_dim_()
    k = rng.normal(0, 1, (len(coords), cache.pt, kv, hd)).astype(np.float32)
    v = rng.normal(0, 1, (len(coords), cache.pt, kv, hd)).astype(np.float32)
    cache.append_pages(coords, jnp.asarray(k), jnp.asarray(v))
    return coords


def test_tenant_masks_partition_cache_pages():
    c = make_cache()
    for slot, tenant in enumerate((0, 0, 1, 1)):
        c.set_slot_tenant(slot, tenant)
    fill_cache(c, np.random.default_rng(0), 24)
    m0, m1 = c.tenant_mask(0), c.tenant_mask(1)
    assert not (m0 & m1).any()
    assert (m0 | m1).all()
    # Per-tenant TCO decomposes the total exactly.
    assert c.tco_usd() == pytest.approx(c.tco_usd(0) + c.tco_usd(1))


def test_no_cross_tenant_page_reads():
    """Device page tables are the read path of the decode kernel: every table
    row (layer, slot) must only reference pool slots holding that slot's own
    pages — so a tenant's kernel reads can never touch another tenant's."""
    c = make_cache()
    for slot, tenant in enumerate((0, 1, 0, 1)):
        c.set_slot_tenant(slot, tenant)
    rng = np.random.default_rng(1)
    fill_cache(c, rng, 32)
    # Shuffle pages across tiers to stress slot bookkeeping.
    live = np.where(c._page_exists)[0]
    dsts = np.array([rng.choice([WARM, COLD, HOST8, HOST4]) for _ in live])
    c.migrate_batch(live, dsts)
    st = c.state
    for pool, level in (("warm", WARM), ("cold", COLD)):
        table = np.asarray(getattr(st, f"{pool}_table"))
        nvec = np.asarray(getattr(st, f"{pool}_n"))
        owner = {}
        for rid in np.where((c.physical == level) & c._page_exists)[0]:
            layer, slot, _ = c.rid_coords(int(rid))
            owner[(layer, int(c._pool_slot[rid]))] = slot
        for layer in range(c.la):
            for slot in range(c.bs):
                for j in range(int(nvec[layer, slot])):
                    ps = int(table[layer, slot, j])
                    assert owner[(layer, ps)] == slot, (
                        f"slot {slot} table references tenant "
                        f"{c.slot_tenant[owner[(layer, ps)]]}'s page"
                    )
    # Host-pool pages are keyed by rid; rid->slot->tenant is injective.
    for rid in c.host_pages:
        assert c._page_exists[rid]


def test_fold_telemetry_vectorized_matches_loop():
    for seed in range(5):
        rng = np.random.default_rng(seed)
        c = make_cache()
        fill_cache(c, rng, int(rng.integers(4, c.n_regions + 1)))
        # Mix placements so both pools and host tiers are populated.
        live = np.where(c._page_exists)[0]
        dsts = np.array([rng.choice([WARM, COLD, HOST8, HOST4]) for _ in live])
        c.migrate_batch(live, dsts)
        st = c.state
        telemetry = {
            pool: rng.random(np.asarray(getattr(st, f"{pool}_table")).shape)
            for pool in ("warm", "cold")
        }
        np.testing.assert_allclose(
            c._fold_telemetry(telemetry),
            c._fold_telemetry_loop(telemetry),
            rtol=1e-12,
        )


def test_record_telemetry_feeds_manager():
    c = make_cache()
    fill_cache(c, np.random.default_rng(2), 16)
    st = c.state
    telemetry = {
        pool: np.random.default_rng(3).random(
            np.asarray(getattr(st, f"{pool}_table")).shape)
        for pool in ("warm", "cold")
    }
    c.record_telemetry(telemetry)
    assert c.manager.telemetry._accum.sum() > 0


# ---------------------------------------------------------------------------
# engine: one engine, interleaved tenant traffic
# ---------------------------------------------------------------------------


def test_engine_serves_interleaved_tenants():
    import jax

    import repro.configs as configs
    from repro.configs.base import TierScapeRunConfig
    from repro.models import Model
    from repro.serving import TieredEngine

    cfg = configs.get_smoke("qwen1_5_4b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = TieredEngine(
        model, params, batch_slots=2, page_tokens=8, max_seq_len=128,
        recent_window=16,
        ts=TierScapeRunConfig(enabled=True, policy="analytical", alpha=0.3,
                              window_steps=6),
    )
    rng = np.random.default_rng(0)
    for tenant in (0, 1, 0, 1):  # requests > slots: slot reuse re-tags tenants
        eng.submit(rng.integers(1, cfg.vocab_size, 24), max_new_tokens=10,
                   tenant=tenant)
    stats = eng.run(max_steps=80)
    assert stats.completed == 4
    assert stats.completed_by_tenant == {0: 2, 1: 2}
    # Per-tenant TCO was snapshotted while both tenants were live.
    assert stats.tco_savings_by_tenant
    assert set(stats.tco_savings_by_tenant) <= {0, 1}
