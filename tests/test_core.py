"""TierScape core: codecs, tiers, TCO model, waterfall, analytical solver.

Property-based tests (seeded-numpy case sweeps, see tests/proptest.py) pin
the system's invariants:
  * codec roundtrip error bounds and monotone ratio/latency orderings,
  * waterfall aging/refault laws,
  * the analytical placement always meets its budget when feasible and is
    near-optimal vs the exact DP.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import analytical, codecs, tco, tiers
from repro.core.manager import make_manager
from repro.core.waterfall import WaterfallConfig, waterfall_step

from proptest import cases, draw_float, draw_int


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

CODEC_ERR_BOUND = {"none": 0.01, "fp8": 0.05, "int8": 0.02, "int4": 0.2, "int2": 0.9}


@pytest.mark.parametrize("name", ["none", "fp8", "int8", "int4", "int2"])
def test_codec_roundtrip_error(name):
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,), jnp.float32)
    err = float(codecs.roundtrip_error(name, x))
    assert err <= CODEC_ERR_BOUND[name], (name, err)


def test_codec_ratio_ordering():
    n = 4096
    r = {k: codecs.CODECS[k].ratio(n) for k in codecs.CODECS}
    assert r["none"] == 1.0
    assert r["fp8"] >= 1.9
    assert r["int2"] > r["int4"] > r["int8"]


def test_codec_roundtrip_randomized():
    for i, rng in cases(50):
        seed = draw_int(rng, 0, 2**31 - 1)
        x = jax.random.normal(jax.random.PRNGKey(seed), (512,), jnp.float32) * (seed % 7 + 1)
        err = float(codecs.roundtrip_error("int8", x))
        assert err <= 0.02, (i, seed, err)


def test_codec_zero_input():
    x = jnp.zeros((1024,), jnp.float32)
    for name in codecs.CODECS:
        enc = codecs.CODECS[name].encode(x)
        out = codecs.CODECS[name].decode(enc, x.shape, jnp.float32)
        assert bool(jnp.all(out == 0)), name


# ---------------------------------------------------------------------------
# tiers / cost model
# ---------------------------------------------------------------------------


def test_tier_registry_structure():
    cs = tiers.characterized()
    assert len(cs) == 12
    sel = tiers.selected()
    assert len(sel) == 5
    # best-performance tier and best-TCO tier anchors (paper §4.2).
    region = 1024 * 1024
    lats = [t.access_latency_s(region) for t in sel]
    usd = [t.usd_per_source_byte(region) for t in sel]
    assert lats[0] == min(lats), "T1 must be the lowest-latency tier"
    assert usd[-1] == min(usd), "T5 must be the best-TCO tier"


def test_packed_denser_than_slab():
    n = 1024 * 1024
    assert tiers.get("C6").effective_ratio(n) > tiers.get("C5").effective_ratio(n)


def test_host_media_slower_and_cheaper():
    n = 1024 * 1024
    hb, ho = tiers.get("C9"), tiers.get("C10")
    assert ho.access_latency_s(n) > hb.access_latency_s(n)
    assert ho.usd_per_source_byte(n) < hb.usd_per_source_byte(n)


def test_slab_ratio_capped_at_2x():
    n = 1024 * 1024
    for tid in ("C1", "C2", "C5", "C8"):
        assert tiers.get(tid).effective_ratio(n) <= 2.0


def test_tco_model_eq9_to_12():
    ts = tiers.default_tierset()
    region_bytes = 2 * 1024 * 1024
    n = 100
    mx = tco.tco_max(n, region_bytes)
    mn = tco.tco_min(ts, n, region_bytes)
    assert 0 < mn < mx
    placement = np.zeros(n, dtype=np.int64)
    assert tco.tco_nt(ts, placement, region_bytes) == pytest.approx(mx)
    placement[:] = ts.n_tiers  # everything in the last tier
    assert tco.tco_nt(ts, placement, region_bytes) <= mx
    # budget interpolates: alpha=1 -> max, alpha=0 -> min.
    assert tco.budget(ts, n, region_bytes, 1.0) == pytest.approx(mx)
    assert tco.budget(ts, n, region_bytes, 0.0) == pytest.approx(mn)


# ---------------------------------------------------------------------------
# waterfall
# ---------------------------------------------------------------------------


def test_waterfall_laws():
    for i, rng in cases(60):
        n_regions = draw_int(rng, 1, 400)
        n_tiers = draw_int(rng, 1, 5)
        h_th = draw_float(rng, 1.0, 100.0)
        placement = rng.integers(0, n_tiers + 1, n_regions)
        hotness = rng.exponential(h_th, n_regions)
        faults = rng.uniform(0, 1, n_regions) * (placement > 0)
        cfg = WaterfallConfig(hotness_threshold=h_th)
        new = waterfall_step(placement, hotness, faults, n_tiers, cfg)
        # Law 1: placements stay in range.
        assert new.min() >= 0 and new.max() <= n_tiers, i
        # Law 2: refaulted regions restart from DRAM.
        refaulted = (placement > 0) & (faults >= cfg.refault_fraction)
        assert (new[refaulted] == 0).all(), i
        # Law 3: untouched compressed regions age exactly one tier (clamped).
        untouched = (placement > 0) & (hotness <= 0) & ~refaulted
        assert (new[untouched] == np.minimum(placement[untouched] + 1, n_tiers)).all(), i
        # Law 4: cold DRAM regions are evicted to tier 1.
        evict = (placement == 0) & (hotness < h_th)
        assert (new[evict] == 1).all(), i
        # Law 5: hot DRAM regions stay.
        stay = (placement == 0) & (hotness >= h_th)
        assert (new[stay] == 0).all(), i


def test_waterfall_converges_cold_pages_to_last_tier():
    n, n_tiers = 64, 5
    placement = np.zeros(n, dtype=np.int64)
    cfg = WaterfallConfig(hotness_threshold=1.0)
    for _ in range(n_tiers + 1):
        placement = waterfall_step(
            placement, np.zeros(n), np.zeros(n), n_tiers, cfg
        )
    assert (placement == n_tiers).all()


# ---------------------------------------------------------------------------
# analytical model (MCKP)
# ---------------------------------------------------------------------------


def _options():
    ts = tiers.default_tierset()
    region_bytes = 2 * 1024 * 1024
    costs = tco.usd_per_region(ts, region_bytes)
    lats = np.array([0.0] + [t.access_latency_s(region_bytes // 2) for t in ts.tiers])
    return ts, region_bytes, costs, lats


def test_analytical_respects_budget():
    for i, rng in cases(50):
        ts, region_bytes, costs, lats = _options()
        n = draw_int(rng, 2, 60)
        alpha = draw_float(rng, 0.05, 0.95)
        hot = rng.exponential(100, n) * (rng.uniform(size=n) > 0.3)
        budget = tco.budget(ts, n, region_bytes, alpha)
        sol = analytical.solve_greedy(hot, costs, lats, budget)
        assert sol.feasible, i
        assert sol.cost <= budget * (1 + 1e-9), i
        # Placement indices are valid options.
        assert sol.placement.min() >= 0 and sol.placement.max() <= ts.n_tiers, i


def test_analytical_greedy_near_exact():
    for i, rng in cases(50):
        ts, region_bytes, costs, lats = _options()
        n = draw_int(rng, 2, 16)
        alpha = draw_float(rng, 0.1, 0.9)
        hot = rng.exponential(100, n)
        budget = tco.budget(ts, n, region_bytes, alpha)
        g = analytical.solve_greedy(hot, costs, lats, budget)
        e = analytical.solve_exact_dp(hot, costs, lats, budget, grid=3000)
        if e.feasible:
            # LP-greedy is optimal up to one region's edge; allow that slack.
            slack = float(hot.max()) * float(lats.max())
            assert g.penalty <= e.penalty + slack + 1e-12, i


def test_analytical_alpha_monotone():
    ts, region_bytes, costs, lats = _options()
    rng = np.random.default_rng(0)
    hot = rng.exponential(100, 512)
    pens, costs_out = [], []
    for alpha in (0.9, 0.5, 0.1):
        b = tco.budget(ts, 512, region_bytes, alpha)
        sol = analytical.solve_greedy(hot, costs, lats, b)
        pens.append(sol.penalty)
        costs_out.append(sol.cost)
    assert pens[0] <= pens[1] <= pens[2]  # lower alpha -> more penalty
    assert costs_out[0] >= costs_out[1] >= costs_out[2]  # and lower cost


def test_cold_regions_to_cheapest_tier():
    ts, region_bytes, costs, lats = _options()
    hot = np.zeros(32)
    b = tco.budget(ts, 32, region_bytes, 0.0)
    sol = analytical.solve_greedy(hot, costs, lats, b)
    assert (sol.placement == int(np.argmin(costs))).all()


# ---------------------------------------------------------------------------
# manager presets
# ---------------------------------------------------------------------------


def test_manager_presets_build():
    for name in ("2T-C", "2T-M", "2T-A", "6T-WF-M", "6T-AM-0.5"):
        m = make_manager(name, 128)
        assert m.n_regions == 128


def test_manager_config_name_parsing():
    """Regression: pin make_manager's config-name grammar (paper §7.1)."""
    thresholds = {"C": 50.0, "M": 100.0, "A": 250.0}
    for level in ("C", "M", "A"):
        m = make_manager(f"2T-{level}", 32)
        assert m.cfg.policy == "2t"
        assert m.cfg.hotness_threshold == thresholds[level]
        assert m.tierset.n_tiers == 1  # DRAM + the single production tier

        m = make_manager(f"6T-WF-{level}", 32)
        assert m.cfg.policy == "waterfall"
        assert m.cfg.hotness_threshold == thresholds[level]
        assert m.tierset.n_tiers == 5

    for alpha in ("0.9", "0.5", "0.1"):
        m = make_manager(f"6T-AM-{alpha}", 32)
        assert m.cfg.policy == "analytical"
        assert m.cfg.alpha == pytest.approx(float(alpha))
        assert m.tierset.n_tiers == 5

    # Case-insensitive (names are upper-cased before parsing).
    assert make_manager("6t-wf-m", 32).cfg.policy == "waterfall"

    # Custom thresholds flow through.
    m = make_manager("2T-C", 32, thresholds={"C": 7.0, "M": 9.0, "A": 11.0})
    assert m.cfg.hotness_threshold == 7.0


@pytest.mark.parametrize("bad", ["", "7T-WF-M", "2X-C", "waterfall", "6T-AM-"])
def test_manager_unknown_config_rejected(bad):
    with pytest.raises(ValueError):
        make_manager(bad, 16)


def test_manager_window_stats_accumulate():
    m = make_manager("6T-AM-0.5", 64)
    rng = np.random.default_rng(0)
    for _ in range(3):
        m.record_access_counts(rng.integers(0, 50, 64).astype(np.float64))
        m.end_window()
    assert len(m.history) == 3
    assert m.history[-1].placement_hist.sum() == 64
