"""Speculative prefetch/readahead on the media pipeline: ring credit
classes, the warming-page predictor, zero-read commits that stay
bit-identical to the no-prefetch oracle, deterministic cancellation of
mispredicted cohorts, invalidation on page release, the simulator's
prefetch replay, the arbiter's speculative-bandwidth billing, and the
async_migration default flip."""

import numpy as np
import pytest


from repro.configs.base import TierScapeRunConfig
from repro.core import simulator
from repro.core.arbiter import BudgetArbiter, TenantSpec
from repro.core.manager import ManagerConfig, make_manager
from repro.media.ringbuf import PinnedRing
from repro.serving.kv_cache import HOST4, TieredKVCache

from test_migration import CFG, assert_same_state, check_table_invariants, fill_cache


def make_cache(prefetch=False, ring_slots=64, warm_frac=1.0, alpha=0.5):
    return TieredKVCache(
        CFG, 2, 2, 8, 64, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=alpha),
        warm_frac=warm_frac, async_migration=True, ring_slots=ring_slots,
        prefetch=prefetch, prefetch_max_pages=16,
    )


def _demote_half_to_host(c, fill_seed=5, n_pages=24):
    """Fill an all-warm cache and demote the second half to the int4 host
    tier; returns (device_rids, host_rids)."""
    fill_cache(c, np.random.default_rng(fill_seed), n_pages)
    live = np.where(c._page_exists)[0]
    host = live[n_pages // 2:]
    c.migrate_batch(host, np.full(host.size, HOST4, np.int64))
    return live[: n_pages // 2], host


# ---------------------------------------------------------------------------
# ring: speculative credit class
# ---------------------------------------------------------------------------


def test_ring_speculative_class_capped_and_never_backpressures():
    # 16 slots: low=2, high=8, speculative slice=4.
    r = PinnedRing(16, 8)
    s = r.try_acquire(4, speculative=True)
    assert s is not None and r.spec_held_slots == 4
    assert r.free_slots + r.held_slots == 16
    # Slice cap: a fifth speculative slot is refused without backpressure.
    assert r.try_acquire(1, speculative=True) is None
    assert not r.backpressured
    # Demand is untouched by speculative holds.
    d = r.try_acquire(6)
    assert d is not None and not r.backpressured
    r.release(s)
    assert r.spec_held_slots == 0
    # 10 free: granting 3 would drop free below the high watermark (8) —
    # refused; granting 2 lands exactly at it — allowed.
    assert r.try_acquire(3, speculative=True) is None
    assert r.try_acquire(2, speculative=True) is not None
    assert r.spec_rejects >= 2
    r.release(d)


def test_ring_speculative_refused_under_backpressure():
    r = PinnedRing(8, 8)  # low=1, high=4, spec=2
    d = r.try_acquire(7)  # 1 free -> backpressured
    assert d is not None and r.backpressured
    assert r.try_acquire(1, speculative=True) is None
    r.release(d)
    assert not r.backpressured


# ---------------------------------------------------------------------------
# predictor
# ---------------------------------------------------------------------------


def test_predictor_flags_rising_regions_mid_window():
    mgr = make_manager("6T-AM-0.5", 16)
    eligible = np.ones(16, bool)
    # No closed window yet: nothing to rise from.
    assert mgr.prefetch_candidates(eligible, top_k=4, max_regions=8).size == 0
    base = np.zeros(16)
    base[3], base[5] = 10.0, 50.0
    mgr.record_access_counts(base)
    mgr.close_telemetry()
    # Accumulating window: region 3 rises (10 -> 30), region 5 falls.
    cur = np.zeros(16)
    cur[3], cur[5] = 30.0, 20.0
    mgr.record_access_counts(cur)
    cand = mgr.prefetch_candidates(eligible, top_k=4, max_regions=8)
    assert 3 in cand and 5 not in cand
    # Pure read: identical repeated calls, no placement perturbation.
    again = mgr.prefetch_candidates(eligible, top_k=4, max_regions=8)
    np.testing.assert_array_equal(cand, again)
    assert (mgr.placement == 0).all()
    # Eligibility mask is honored.
    not3 = eligible.copy()
    not3[3] = False
    assert 3 not in mgr.prefetch_candidates(not3, top_k=4, max_regions=8)


# ---------------------------------------------------------------------------
# hit path: staged pages commit without a boundary source read
# ---------------------------------------------------------------------------


def _steady_counts(c, device, host, hot_device=500.0, hot_host=0.0):
    counts = np.zeros(c.n_regions)
    counts[device] = hot_device
    counts[host] = hot_host
    return counts


def _window(c, counts, ticks=8):
    """One profile window the way the engine drives it: telemetry
    accumulates, idle decode steps run speculation, the boundary plans."""
    c.manager.record_access_counts(counts)
    for _ in range(ticks):
        if c.pipeline.busy:
            c.pipeline.tick()
        else:
            c.prefetch_tick()
    c.end_window()
    c.drain_migrations()


def test_prefetch_hit_skips_boundary_read_and_matches_oracle():
    spec, oracle = make_cache(prefetch=True), make_cache(prefetch=False)
    for c in (spec, oracle):
        device, host = _demote_half_to_host(c)
    # Window 0: steady state (device hot, host cold) — placement stable.
    for c in (spec, oracle):
        _window(c, _steady_counts(c, device, host))
    assert spec.pipeline.prefetch_staged == 0  # nothing was rising
    # Window 1: the host set warms up sharply; the predictor stages it
    # mid-window, the boundary promotes it, the staged bytes are claimed.
    for c in (spec, oracle):
        _window(c, _steady_counts(c, device, host, hot_host=800.0))
    assert spec.pipeline.prefetch_staged == len(host)
    assert spec.pipeline.prefetch_hits == len(host)
    assert spec.pipeline.prefetch_misses == 0
    # Promotions really happened, identically in both runs.
    assert (spec.physical[host] != HOST4).all()
    assert_same_state(spec, oracle)
    # The oracle paid the host read at the boundary; prefetch did not.
    assert oracle.pipeline.demand_swapin_s > 0
    assert spec.pipeline.demand_swapin_s < oracle.pipeline.demand_swapin_s
    # The speculative read is still billed: same bytes, different timing.
    assert spec.pipeline.prefetch_bytes > 0
    assert spec.staging_ring.free_slots == spec.staging_ring.n_slots
    check_table_invariants(spec)


def test_prefetch_media_billing_excluded_from_contention_feedback():
    """Speculative reads inflate the device queues (the TCO report) but not
    the media-pressure feedback that shapes placement — otherwise prefetch
    runs would plan differently from the oracle."""
    spec, oracle = make_cache(prefetch=True), make_cache(prefetch=False)
    for c in (spec, oracle):
        device, host = _demote_half_to_host(c)
        _window(c, _steady_counts(c, device, host))
        _window(c, _steady_counts(c, device, host, hot_host=800.0))
    assert spec.pipeline.prefetch_hits > 0
    host_dev = "host_dram_pcie"
    assert spec.pipeline.prefetch_read_s > 0
    # Every staged page was claimed, so its busy share was handed back to
    # the demand side and the residual speculative exclusion nets to zero.
    assert spec.pipeline.prefetch_busy_by_device.get(host_dev, 0.0) == pytest.approx(
        0.0, abs=1e-15
    )
    # Executed busy time includes the speculative read (total host read
    # volume is the same work, just moved earlier in the window)...
    assert spec.media_queues[host_dev].busy_s == pytest.approx(
        oracle.media_queues[host_dev].busy_s, rel=1e-9
    )
    # ...and the manager's placement-shaping pressure matches the oracle:
    # claimed reads are demand work shifted earlier (their busy share is
    # handed back), so only mispredicted reads stay out of the feedback.
    assert set(spec.manager.media_pressure) == set(oracle.manager.media_pressure)
    for dev, rho in oracle.manager.media_pressure.items():
        assert spec.manager.media_pressure[dev] == pytest.approx(rho, rel=1e-9, abs=1e-15)


# ---------------------------------------------------------------------------
# cancellation: mispredicted cohorts are discarded deterministically
# ---------------------------------------------------------------------------


def _run_mispredict_scenario():
    """Stage a speculative cohort the boundary plan then contradicts (the
    staged pages stay cold; only device pages are in the plan's interest).
    Returns the cache for inspection."""
    c = make_cache(prefetch=True)
    device, host = _demote_half_to_host(c)
    _window(c, _steady_counts(c, device, host))
    # Machinery-level mispredict: stage host pages the plan will not touch.
    target = host[:6]
    queued = c.pipeline.submit_prefetch([(target, HOST4)])
    assert queued == 6
    for _ in range(4):
        c.prefetch_tick()
    assert c.pipeline.prefetch_staged == 6
    assert set(c.pipeline.speculative_rids()) == set(int(r) for r in target)
    held_before = c.staging_ring.held_slots
    assert held_before >= 6
    # Shadow copies: sources still resident and readable.
    assert all(int(r) in c.host_pages for r in target)
    # Boundary: device pages stay hot, staged pages stay cold -> the plan
    # contradicts the speculation and the cohort is discarded.
    _window(c, _steady_counts(c, device, host))
    return c, target


def test_mispredicted_prefetch_discarded_and_credits_returned():
    c, target = _run_mispredict_scenario()
    assert c.pipeline.prefetch_misses == 6
    assert c.pipeline.prefetch_hits == 0
    assert not c.pipeline.speculative_rids()
    # Every ring credit came back.
    assert c.staging_ring.free_slots == c.staging_ring.n_slots
    # The mispredicted pages never moved and their payloads are intact.
    assert (c.physical[target] == HOST4).all()
    assert all(int(r) in c.host_pages for r in target)
    check_table_invariants(c)
    # The wasted speculative bandwidth stays billed (mispredictions show
    # up in the report; they do not disappear).
    assert c.pipeline.prefetch_bytes > 0
    assert c.pipeline.prefetch_busy_by_device.get("host_dram_pcie", 0.0) > 0


def test_mispredict_is_deterministic_and_placement_neutral():
    a, _ = _run_mispredict_scenario()
    b, _ = _run_mispredict_scenario()
    assert_same_state(a, b)
    assert a.pipeline.prefetch_misses == b.pipeline.prefetch_misses
    assert a.pipeline.prefetch_bytes == b.pipeline.prefetch_bytes
    # And the whole scenario with prefetch disabled lands identical pages.
    c = make_cache(prefetch=False)
    device, host = _demote_half_to_host(c)
    _window(c, _steady_counts(c, device, host))
    _window(c, _steady_counts(c, device, host))
    assert_same_state(a, c)


def test_release_slot_pages_invalidates_staged_prefetch():
    c = make_cache(prefetch=True)
    device, host = _demote_half_to_host(c)
    _window(c, _steady_counts(c, device, host))
    slot1 = host[((host // c.max_pages) % c.bs) == 1]
    assert slot1.size > 0
    c.pipeline.submit_prefetch([(slot1, HOST4)])
    for _ in range(4):
        c.prefetch_tick()
    assert c.pipeline.prefetch_staged == slot1.size
    c.release_slot_pages(1)
    # Stale shadow copies were cancelled, credits returned, index clean.
    assert c.pipeline.prefetch_cancelled >= slot1.size
    assert not (set(int(r) for r in slot1) & c.pipeline.speculative_rids())
    assert c.staging_ring.free_slots == c.staging_ring.n_slots
    assert not any(int(r) in c.host_pages for r in slot1)


# ---------------------------------------------------------------------------
# engine integration (prefetch enabled end-to-end)
# ---------------------------------------------------------------------------


def test_engine_runs_with_prefetch_enabled():
    import jax

    from repro.configs.base import ModelConfig
    from repro.models import Model
    from repro.serving import TieredEngine

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = TieredEngine(
        model, params, batch_slots=2, page_tokens=8, max_seq_len=64,
        recent_window=16,
        ts=TierScapeRunConfig(enabled=True, policy="analytical",
                              window_steps=4, async_migration=True,
                              prefetch=True),
    )
    rng = np.random.default_rng(9)
    for _ in range(2):
        eng.submit(rng.integers(1, cfg.vocab_size, 48), max_new_tokens=12)
    stats = eng.run(max_steps=200)
    assert stats.completed == 2
    assert stats.migrations > 0
    assert not eng.cache.pipeline.busy
    # Speculation left no residue: all ring credits are home.
    assert not eng.cache.pipeline.speculative_rids()
    assert stats.prefetch_staged == stats.prefetch_hits + stats.prefetch_misses


# ---------------------------------------------------------------------------
# simulator replay
# ---------------------------------------------------------------------------


def test_simulator_prefetch_reduces_slowdown_and_bills_bytes():
    def run(prefetch):
        wl = simulator.gaussian_kv(
            n_regions=256, accesses_per_window=20_000, drift_frac=0.05
        )
        m = make_manager("6T-AM-0.5", 256)
        return simulator.simulate(wl, m, windows=10, seed=1, prefetch=prefetch)

    base = run(False)
    pre = run(True)
    assert base.prefetch_hits == 0 and base.prefetch_bytes == 0
    assert pre.prefetch_hits > 0
    assert pre.prefetch_bytes > 0
    # Hits hide first-touch fault latency...
    assert pre.slowdown_pct < base.slowdown_pct
    # ...but never fork the placement trajectory: fault bookkeeping, plans
    # and TCO are bit-identical to the prefetch-free run.
    np.testing.assert_array_equal(pre.placement_hists, base.placement_hists)
    np.testing.assert_array_equal(pre.fault_hists, base.fault_hists)
    assert pre.tco_savings_pct == base.tco_savings_pct
    # Speculative traffic lands on the shared media queues on top of the
    # (identical) demand migration traffic.
    assert sum(pre.media_bytes_by_device.values()) == pytest.approx(
        sum(base.media_bytes_by_device.values()) + pre.prefetch_bytes
    )
    # Deterministic replay.
    again = run(True)
    assert again.prefetch_hits == pre.prefetch_hits
    assert again.prefetch_misses == pre.prefetch_misses
    assert again.prefetch_bytes == pre.prefetch_bytes


def test_simulate_multitenant_prefetch_reports_spec_bytes_to_arbiter():
    def build():
        managers = [make_manager("6T-AM-0.5", 128, seed=t) for t in range(2)]
        arb = BudgetArbiter(
            [TenantSpec("a", sla_weight=2.0), TenantSpec("b")], managers, alpha=0.5
        )
        wls = [
            simulator.gaussian_kv(
                n_regions=128, accesses_per_window=10_000, drift_frac=0.05
            )
            for _ in range(2)
        ]
        return wls, arb

    wls, arb = build()
    r = simulator.simulate_multitenant(wls, arb, windows=8, prefetch=True)
    assert r.prefetch_hits > 0
    assert r.prefetch_bytes > 0
    # The arbiter was told about the fleet's speculative traffic.
    assert any(ws.speculative_bytes_by_device for ws in arb.history)
    total_reported = sum(
        b for ws in arb.history for b in ws.speculative_bytes_by_device.values()
    )
    assert total_reported == pytest.approx(r.prefetch_bytes)
    # Placement-neutral here too: the prefetch-free fleet commits the same
    # placements window for window.
    wls0, arb0 = build()
    r0 = simulator.simulate_multitenant(wls0, arb0, windows=8, prefetch=False)
    for ws, ws0 in zip(arb.history, arb0.history):
        for ts, ts0 in zip(ws.tenants, ws0.tenants):
            assert ts.fast_regions == ts0.fast_regions
            assert ts.spent_usd == ts0.spent_usd


# ---------------------------------------------------------------------------
# arbiter: speculative bytes consume the shared bandwidth budget
# ---------------------------------------------------------------------------


def _drive_arbiter(budget, spec_bytes=None, windows=3, n_regions=64):
    managers = [make_manager("6T-AM-0.5", n_regions) for _ in range(2)]
    arb = BudgetArbiter(
        [TenantSpec("a", sla_weight=2.0), TenantSpec("b")],
        managers, alpha=0.5, media_bw_budget_bytes=budget,
    )
    rng = np.random.default_rng(0)
    for _ in range(windows):
        for m in managers:
            counts = np.zeros(n_regions)
            hot = rng.choice(n_regions, size=8, replace=False)
            counts[hot] = rng.integers(100, 1000, 8)
            m.record_access_counts(counts)
        if spec_bytes:
            arb.record_speculative_bytes(spec_bytes)
        arb.end_window()
    return arb


def test_arbiter_speculative_bytes_consume_bandwidth_budget():
    free = _drive_arbiter(budget=None)
    peak = max(
        ws.media_bytes_by_device.get("host_dram_pcie", 0) for ws in free.history
    )
    assert peak > 0
    # Budget sized to the unconstrained peak: no demand move is deferred.
    roomy = _drive_arbiter(budget={"host_dram_pcie": peak * 1.01})
    assert all(ws.deferred_migrations == 0 for ws in roomy.history)
    # Same budget, but speculation ate 90% of it mid-window: demand moves
    # touching the device must now be deferred, and the stats say why.
    spec = _drive_arbiter(
        budget={"host_dram_pcie": peak * 1.01},
        spec_bytes={"host_dram_pcie": peak * 0.9},
    )
    assert any(ws.deferred_migrations > 0 for ws in spec.history)
    for ws in spec.history:
        assert ws.speculative_bytes_by_device == {"host_dram_pcie": peak * 0.9}


# ---------------------------------------------------------------------------
# config: async default flipped (ROADMAP soak item)
# ---------------------------------------------------------------------------


def test_async_migration_defaults_true_with_env_escape(monkeypatch):
    monkeypatch.delenv("REPRO_ASYNC_MIGRATION", raising=False)
    assert TierScapeRunConfig().async_migration is True
    monkeypatch.setenv("REPRO_ASYNC_MIGRATION", "0")
    assert TierScapeRunConfig().async_migration is False
    monkeypatch.setenv("REPRO_ASYNC_MIGRATION", "1")
    assert TierScapeRunConfig().async_migration is True
    c = make_cache(prefetch=True)
    assert c.prefetch_enabled


def test_prefetch_defaults_true_with_env_escape(monkeypatch):
    # Prefetch defaults on now that the fused decode kernel feeds the
    # predictor in-engine; REPRO_PREFETCH=0 is the escape hatch.
    monkeypatch.delenv("REPRO_PREFETCH", raising=False)
    assert TierScapeRunConfig().prefetch is True
    monkeypatch.setenv("REPRO_PREFETCH", "0")
    assert TierScapeRunConfig().prefetch is False
    monkeypatch.setenv("REPRO_PREFETCH", "1")
    assert TierScapeRunConfig().prefetch is True
