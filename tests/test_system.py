"""End-to-end system behaviour: the paper's headline claims reproduced on
the window simulator, plus the tiered serving engine running a real
(smoke-scale) model."""

import numpy as np
import pytest

import jax

import repro.configs as configs
from repro.configs.base import TierScapeRunConfig
from repro.core import simulator
from repro.core.manager import make_manager
from repro.core.telemetry import PEBSNoise
from repro.models import Model
from repro.serving import TieredEngine

THRESHOLDS = {"C": 50.0, "M": 200.0, "A": 800.0}


def _run(cfg_name, wl, windows=16, seed=1, pebs=None):
    m = make_manager(cfg_name, wl.n_regions, thresholds=THRESHOLDS, pebs=pebs)
    return simulator.simulate(wl, m, windows=windows, seed=seed)


@pytest.fixture(scope="module")
def gauss():
    return simulator.gaussian_kv(n_regions=2048, accesses_per_window=500_000)


def test_ntier_dominates_2tier_at_same_threshold(gauss):
    """Paper §7.3: 6T-WF saves more TCO than 2T at similar or better perf."""
    for level in ("M", "A"):
        r2 = _run(f"2T-{level}", gauss)
        r6 = _run(f"6T-WF-{level}", gauss)
        assert r6.tco_savings_pct > r2.tco_savings_pct + 5
        assert r6.slowdown_pct <= r2.slowdown_pct * 1.25


def test_analytical_alpha_tradeoff(gauss):
    """alpha: 1 -> perf, 0 -> TCO (paper §5.2 knob semantics)."""
    r9 = _run("6T-AM-0.9", gauss)
    r5 = _run("6T-AM-0.5", gauss)
    r1 = _run("6T-AM-0.1", gauss)
    assert r9.tco_savings_pct <= r5.tco_savings_pct <= r1.tco_savings_pct
    assert r9.slowdown_pct <= r5.slowdown_pct + 1e-6
    assert r5.slowdown_pct <= r1.slowdown_pct + 1e-6


def test_tail_latency_ntier_beats_2tier(gauss):
    """Paper §7.6: 6T p99 <= 2T p99 at equal aggressiveness."""
    r2 = _run("2T-A", gauss)
    r6 = _run("6T-WF-A", gauss)
    assert r6.p99_access_us <= r2.p99_access_us + 1e-9


def test_daemon_tax_single_digit(gauss):
    """Paper §7.7: TS-Daemon tax 1.2-7%."""
    for cfg in ("6T-WF-M", "6T-AM-0.5"):
        r = _run(cfg, gauss)
        assert r.daemon_tax_pct < 10.0


def test_waterfall_tolerates_pebs_noise(gauss):
    """Paper §5.1: waterfall is robust to profiling inaccuracy."""
    clean = _run("6T-WF-M", gauss)
    noisy = _run("6T-WF-M", gauss, pebs=PEBSNoise(sample_rate=0.02, misattribution=0.05))
    assert abs(noisy.tco_savings_pct - clean.tco_savings_pct) < 10
    assert noisy.slowdown_pct < clean.slowdown_pct * 2 + 2.0


def test_placement_distribution_shifts_with_aggressiveness(gauss):
    rc = _run("6T-WF-C", gauss)
    ra = _run("6T-WF-A", gauss)
    # Aggressive keeps less in DRAM (placement 0).
    dram_c = rc.placement_hists[-1][0]
    dram_a = ra.placement_hists[-1][0]
    assert dram_a < dram_c


def test_all_paper_workloads_run():
    for wl in simulator.PAPER_WORKLOADS():
        wl_small = simulator.gaussian_kv(n_regions=256, accesses_per_window=20_000,
                                         name=wl.name)
        r = _run("6T-AM-0.5", wl_small, windows=6)
        assert r.windows == 6


# ---------------------------------------------------------------------------
# Tiered serving engine on a real model (smoke scale)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["zamba2_1_2b", "qwen3_32b"])
def test_engine_end_to_end(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = TieredEngine(
        model, params, batch_slots=2, page_tokens=8, max_seq_len=128,
        recent_window=16,
        ts=TierScapeRunConfig(enabled=True, policy="analytical", alpha=0.3, window_steps=6),
    )
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(1, cfg.vocab_size, 24), max_new_tokens=16)
            for _ in range(2)]
    stats = eng.run(max_steps=40)
    assert stats.completed == 2
    assert all(len(r.out_tokens) >= 16 for r in reqs)
    assert stats.windows >= 1
    assert stats.migrations >= 0


def test_engine_generates_same_tokens_as_dense_reference():
    """Tiered KV decoding must track the dense-cache reference closely
    (warm int8 pages dominate early; divergence only from quantization)."""
    import jax.numpy as jnp

    cfg = configs.get_smoke("qwen1_5_4b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, 24)

    # Dense reference.
    state = model.init_cache(1, 64)
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    logits, state = model.prefill(params, batch, state)
    ref_tokens = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(7):
        lg, state = model.decode_step(params, jnp.asarray([[ref_tokens[-1]]], jnp.int32), state)
        ref_tokens.append(int(jnp.argmax(lg[0, 0])))

    eng = TieredEngine(model, params, batch_slots=1, page_tokens=8, max_seq_len=64,
                       recent_window=16,
                       ts=TierScapeRunConfig(enabled=True, window_steps=32))
    req = eng.submit(prompt, max_new_tokens=8)
    eng.run(max_steps=16)
    matches = sum(a == b for a, b in zip(req.out_tokens, ref_tokens))
    assert matches >= 6, (req.out_tokens, ref_tokens)
