"""Backing-media subsystem: ring-buffer invariants, device-queue accounting,
async-pipeline vs serial-oracle equivalence, non-blocking window boundaries,
tenant pool quotas, per-slot sequence lengths, and the arbiter's shared
bandwidth budget."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import ModelConfig, TierScapeRunConfig
from repro.core.arbiter import BudgetArbiter, TenantSpec
from repro.core.manager import ManagerConfig, make_manager
from repro.media.devices import DEVICES, MediaQueue, get as get_device
from repro.media.ringbuf import PinnedRing
from repro.serving.kv_cache import COLD, HOST4, HOST8, WARM, TieredKVCache

from proptest import cases, draw_int
from test_migration import CFG, assert_same_state, check_table_invariants, fill_cache


def make_cache(async_migration=False, tenant_quota=None, ring_slots=64,
               layers=2, slots=2, page_tokens=8, max_seq=64, warm_frac=0.5):
    return TieredKVCache(
        CFG, layers, slots, page_tokens, max_seq, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=0.5),
        warm_frac=warm_frac, tenant_quota=tenant_quota,
        async_migration=async_migration, ring_slots=ring_slots,
    )


# ---------------------------------------------------------------------------
# pinned ring buffer: credit/watermark invariants
# ---------------------------------------------------------------------------


def test_ring_conserves_slots_and_rejects_double_release():
    r = PinnedRing(8, 16)
    got = r.try_acquire(3)
    assert got is not None and len(got) == 3
    assert r.free_slots + r.held_slots == 8
    r.release(got)
    assert r.free_slots == 8
    with pytest.raises(ValueError):
        r.release(got)  # already released


def test_ring_watermark_hysteresis():
    # 8 slots, low=1 (floor 0.125*8), high=4: draining to <=1 free engages
    # backpressure; it clears only once >=4 slots are free again.
    r = PinnedRing(8, 16, low_watermark=0.125, high_watermark=0.5)
    a = r.try_acquire(4)
    b = r.try_acquire(3)  # 1 free -> at the low watermark
    assert a is not None and b is not None
    assert r.backpressured
    assert r.try_acquire(1) is None  # stalled despite a free slot
    r.release(b[:2])  # 3 free: still below the high watermark
    assert r.backpressured and r.try_acquire(1) is None
    r.release(b[2:])  # 4 free: hysteresis clears
    assert not r.backpressured
    assert r.try_acquire(1) is not None


def test_ring_oversized_acquire_stalls_and_data_roundtrips():
    r = PinnedRing(4, 8)
    assert r.try_acquire(5) is None  # never satisfiable this instant
    r.backpressured = False  # reset for the data check
    s = r.try_acquire(2)
    payload = bytes(range(8))
    r.stage(s[0], payload)
    assert r.read(s[0]) == payload
    with pytest.raises(ValueError):
        r.stage(s[1], bytes(9))  # exceeds slot_bytes


# ---------------------------------------------------------------------------
# media devices: cost model + deterministic queue contention
# ---------------------------------------------------------------------------


def test_device_catalog_and_service_times():
    assert {"hbm", "host_dram_pcie", "cxl", "nvme"} <= set(DEVICES)
    host = get_device("host_dram_pcie")
    # Service time = fixed + bytes/bw, monotone in bytes.
    assert host.service_time_s(0) == pytest.approx(host.fixed_latency_s)
    assert host.service_time_s(1 << 20) > host.service_time_s(1 << 10)
    # HBM is strictly the faster medium for any transfer.
    hbm = get_device("hbm")
    assert hbm.service_time_s(1 << 20) < host.service_time_s(1 << 20)
    with pytest.raises(KeyError):
        get_device("tape")


def test_queue_depth_contention_and_determinism():
    nvme = get_device("nvme")
    q1 = MediaQueue(get_device("host_dram_pcie"))  # depth 4
    # Submitting more transfers than the queue depth at the same instant
    # makes the excess wait behind the earliest-finishing channel.
    for _ in range(4):
        q1.submit(1 << 20, now=0.0)
    assert q1.queue_wait_s == 0.0
    _, done = q1.submit(1 << 20, now=0.0)
    assert q1.queue_wait_s > 0.0
    assert done > nvme.fixed_latency_s  # finished strictly after its wait

    # Determinism: identical submission sequences -> identical accounting.
    def run():
        q = MediaQueue(get_device("cxl"))
        for i in range(10):
            q.submit((i + 1) * 4096, now=i * 1e-5, write=i % 2 == 0)
        return q.busy_s, q.queue_wait_s, q.bytes_total
    assert run() == run()


# ---------------------------------------------------------------------------
# async pipeline vs serial oracle: bit-identical final placement + content
# ---------------------------------------------------------------------------


def test_async_pipeline_matches_serial_oracle():
    for i, rng in cases(8):
        serial, asyn = make_cache(), make_cache(async_migration=True, ring_slots=8)
        n_pages = draw_int(rng, 6, serial.n_regions)
        fill_seed = draw_int(rng, 0, 2**31 - 1)
        fill_cache(serial, np.random.default_rng(fill_seed), n_pages)
        fill_cache(asyn, np.random.default_rng(fill_seed), n_pages)
        for _ in range(draw_int(rng, 1, 3)):
            live = np.where(serial._page_exists)[0]
            m = draw_int(rng, 1, len(live))
            rids = rng.choice(live, size=m, replace=False)
            dsts = np.array(
                [rng.choice([t for t in (WARM, COLD, HOST8, HOST4)
                             if t != serial.physical[r]]) for r in rids], np.int64)
            serial.migrate_batch(rids, dsts)
            queued = asyn.pipeline.submit(asyn.plan_cohorts(rids, dsts))
            ticks = 0
            while asyn.pipeline.busy:
                asyn.pipeline.tick()
                ticks += 1
                assert ticks < 10 * queued + 50, "pipeline wedged"
            assert_same_state(serial, asyn)


def test_pipeline_survives_tiny_ring_and_credit_starvation():
    """A 4-slot ring forces 2-page chunking; a competing credit holder
    (another tierset's migration stream sharing the staging arena) starves
    the stage phase, which must stall — never drop — and resume once the
    credits come back. Result bit-matches the oracle."""
    serial, asyn = make_cache(warm_frac=1.0), make_cache(
        async_migration=True, ring_slots=4, warm_frac=1.0)
    fill_cache(serial, np.random.default_rng(5), 24)
    fill_cache(asyn, np.random.default_rng(5), 24)
    rids = np.where(serial._page_exists)[0]
    dsts = np.where(np.arange(rids.size) % 2 == 0, HOST8, HOST4).astype(np.int64)
    serial.migrate_batch(rids, dsts)

    hold = asyn.staging_ring.try_acquire(3)  # competing producer
    asyn.pipeline.submit(asyn.plan_cohorts(rids, dsts))
    for _ in range(5):
        assert not asyn.pipeline.tick()  # starved: no phase can progress
    assert asyn.staging_ring.stalls > 0
    assert asyn.pipeline.pages_moved == 0
    asyn.staging_ring.release(hold)  # credits return; hysteresis clears
    while asyn.pipeline.busy:
        asyn.pipeline.tick()
    assert_same_state(serial, asyn)
    assert asyn.pipeline.cohorts_done >= 12  # chunked into 2-page cohorts


def test_window_boundary_is_non_blocking_with_inflight_cohort():
    """end_window in async mode returns with cohorts still in flight;
    telemetry keeps folding, appends keep landing, and the eventual drain
    reconciles desired placement with physical reality."""
    c = make_cache(async_migration=True, ring_slots=8, warm_frac=1.0)
    rng = np.random.default_rng(7)
    fill_cache(c, rng, 24)
    counts = np.zeros(c.n_regions)
    live = np.where(c._page_exists)[0]
    counts[live[:4]] = 1000.0  # 4 hot pages; the rest should sink tiers
    c.manager.record_access_counts(counts)
    plan, queued = c.end_window()
    assert queued > 0
    assert c.pipeline.busy, "boundary should not have blocked"
    from repro.serving.kv_cache import INFLIGHT
    c.pipeline.tick()  # first decode step stages the head cohort
    assert (c.physical == INFLIGHT).any()
    # Mid-flight work: telemetry folds (in-flight pages excluded)...
    c.record_telemetry({
        "warm": jnp.zeros((c.la, c.bs, c.max_pages)),
        "cold": jnp.zeros((c.la, c.bs, c.max_pages)),
    })
    # ...and decode-step ticks retire migration phases.
    ticks = 0
    while c.pipeline.busy:
        c.pipeline.tick()
        ticks += 1
        assert ticks < 200
    assert ticks > 1  # genuinely spread over multiple steps
    assert not (c.physical == INFLIGHT).any()
    ex = c._page_exists
    np.testing.assert_array_equal(c.physical[ex], c.manager.placement[ex])
    check_table_invariants(c)
    # The serial oracle (same seeds, async off) lands identical placements.
    s = make_cache(async_migration=False, ring_slots=8, warm_frac=1.0)
    fill_cache(s, np.random.default_rng(7), 24)
    s.manager.record_access_counts(counts.copy())
    s.end_window()
    np.testing.assert_array_equal(c.physical, s.physical)


def test_media_accounting_deterministic_and_reported():
    """Same scenario twice -> identical per-device charges; the window TCO
    report (WindowStats) carries the per-device bytes/seconds."""
    def run():
        c = make_cache(async_migration=True, ring_slots=8, warm_frac=1.0)
        fill_cache(c, np.random.default_rng(3), 24)
        counts = np.zeros(c.n_regions)
        counts[np.where(c._page_exists)[0][:4]] = 500.0
        c.manager.record_access_counts(counts)
        c.end_window()
        c.drain_migrations()
        ws = c.manager.history[-1]
        return ws.media_bytes_by_device, ws.media_s_by_device, c.pipeline.media_busy_s()
    a, b = run(), run()
    assert a == b
    bytes_by_dev, s_by_dev, executed = a
    assert bytes_by_dev, "window TCO report should carge media traffic"
    assert any(v > 0 for v in bytes_by_dev.values())
    assert set(bytes_by_dev) == set(s_by_dev)
    assert any(v > 0 for v in executed.values())
    # Host-bound demotions must bill the PCIe swap device specifically.
    assert executed.get("host_dram_pcie", 0.0) > 0.0


def test_contention_pressure_inflates_planning_latencies():
    mgr = make_manager("6T-AM-0.5", 32)
    base = mgr.contended_latencies_s().copy()
    mgr.note_media_charges({"host_dram_pcie": 10.0}, window_s=10.0)  # rho=1
    inflated = mgr.contended_latencies_s()
    host_idx = [i for i, n in enumerate(mgr._dev_names) if n == "host_dram_pcie"]
    hbm_idx = [i for i, n in enumerate(mgr._dev_names) if n == "hbm"]
    assert host_idx and hbm_idx
    assert all(inflated[i] > base[i] for i in host_idx)
    assert all(inflated[i] == base[i] for i in hbm_idx)


# ---------------------------------------------------------------------------
# tenant quotas on the serving cache's device pools
# ---------------------------------------------------------------------------


def test_tenant_quota_caps_warm_residency_on_append():
    # Warm pool has 8 slots; tenant 0 may hold 3, tenant 1 the rest.
    c = make_cache(warm_frac=0.25, tenant_quota={"warm": {0: 3, 1: 5}})
    assert c._alloc["warm"].capacity == 8
    c.set_slot_tenant(0, 0)
    c.set_slot_tenant(1, 1)
    rng = np.random.default_rng(0)
    kv, hd = CFG.n_kv_heads, CFG.head_dim_()
    # Tenant 0 floods slot 0 with pages: only 3 may sit warm.
    entries = [(la, 0, pg) for la in range(c.la) for pg in range(6)]
    k = rng.normal(0, 1, (len(entries), c.pt, kv, hd)).astype(np.float32)
    c.append_pages(entries, jnp.asarray(k), jnp.asarray(k * 0.3))
    t0_warm = int(((c.physical == WARM) & c._page_exists & c.tenant_mask(0)).sum())
    assert t0_warm == 3
    assert c._alloc["warm"].used_by(0) == 3
    # Tenant 1 still gets warm slots — tenant 0 could not exhaust the pool.
    entries1 = [(la, 1, pg) for la in range(c.la) for pg in range(2)]
    k1 = rng.normal(0, 1, (len(entries1), c.pt, kv, hd)).astype(np.float32)
    c.append_pages(entries1, jnp.asarray(k1), jnp.asarray(k1 * 0.3))
    t1_warm = int(((c.physical == WARM) & c._page_exists & c.tenant_mask(1)).sum())
    assert t1_warm == 4
    check_table_invariants(c)


def test_tenant_quota_bounds_promotions_in_migrate_batch():
    c = make_cache(warm_frac=0.5, tenant_quota={"warm": {0: 2, 1: 14}})
    c.set_slot_tenant(0, 0)
    c.set_slot_tenant(1, 1)
    fill_cache(c, np.random.default_rng(2), 20)
    # Push everything cold, then ask for mass promotion of tenant 0's pages.
    live = np.where(c._page_exists)[0]
    c.migrate_batch(live, np.full(live.size, COLD, np.int64))
    mine = np.where(c._page_exists & c.tenant_mask(0))[0]
    c.migrate_batch(mine, np.full(mine.size, WARM, np.int64))
    t0_warm = int(((c.physical == WARM) & c.tenant_mask(0) & c._page_exists).sum())
    assert t0_warm <= 2  # quota held; overflow spilled back to cold
    assert int(((c.physical == COLD) & c.tenant_mask(0) & c._page_exists).sum()) > 0
    check_table_invariants(c)
    # Pool-level accounting agrees with the placement vector.
    assert c._alloc["warm"].used_by(0) == t0_warm


def test_cold_quota_batch_demotion_spills_to_host():
    """A batched WARM->COLD demotion for a tenant at its cold quota must
    spill the overflow to the int4 host tier (like the per-page path), not
    blow up mid-cohort with a quota-exhausted alloc."""
    c = make_cache(warm_frac=1.0, tenant_quota={"cold": {0: 2, 1: 30}})
    c.set_slot_tenant(0, 0)
    c.set_slot_tenant(1, 0)
    fill_cache(c, np.random.default_rng(6), 16)  # all land warm
    live = np.where(c._page_exists)[0]
    moved = c.migrate_batch(live, np.full(live.size, COLD, np.int64))
    assert moved == live.size
    assert int(((c.physical == COLD) & c._page_exists).sum()) == 2
    assert int(((c.physical == HOST4) & c._page_exists).sum()) == live.size - 2
    assert c._alloc["cold"].used_by(0) == 2
    check_table_invariants(c)


def test_quota_requires_known_tenant():
    c = make_cache(tenant_quota={"warm": {1: 4}})
    c.set_slot_tenant(0, 0)  # tenant 0 has no quota entry
    with pytest.raises(KeyError):
        c.append_page(0, 0, 0,
                      jnp.zeros((c.pt, CFG.n_kv_heads, CFG.head_dim_())),
                      jnp.zeros((c.pt, CFG.n_kv_heads, CFG.head_dim_())))


# ---------------------------------------------------------------------------
# per-slot sequence lengths in the tiered engine
# ---------------------------------------------------------------------------


def _tiny_model():
    import jax
    from repro.models import Model

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run_engine(model, params, prompts, batch_slots, window_steps=1000,
                max_new=6, async_migration=False):
    from repro.serving import TieredEngine

    eng = TieredEngine(
        model, params, batch_slots=batch_slots, page_tokens=8, max_seq_len=64,
        recent_window=16,
        ts=TierScapeRunConfig(enabled=True, policy="analytical",
                              window_steps=window_steps,
                              async_migration=async_migration),
    )
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    stats = eng.run(max_steps=200)
    return eng, reqs, stats


def test_engine_serves_unequal_prompt_lengths():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(4)
    pa = rng.integers(1, cfg.vocab_size, 21)
    pb = rng.integers(1, cfg.vocab_size, 9)
    # Batched run with unequal lengths (no migration windows: window huge).
    eng, (ra, rb), stats = _run_engine(model, params, [pa, pb], batch_slots=2)
    assert stats.completed == 2
    assert len(ra.out_tokens) >= 6 and len(rb.out_tokens) >= 6
    # Per-slot positions: each request decodes exactly like a solo run of
    # the same prompt (rows are independent through attention + pools).
    _, (sa,), _ = _run_engine(model, params, [pa], batch_slots=1)
    _, (sb,), _ = _run_engine(model, params, [pb], batch_slots=1)
    assert ra.out_tokens == sa.out_tokens, "long prompt diverged from solo run"
    assert rb.out_tokens == sb.out_tokens, "short prompt diverged from solo run"


def test_engine_overlaps_migration_with_decode():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab_size, 48) for _ in range(2)]
    eng, _, stats = _run_engine(
        model, params, prompts, batch_slots=2, window_steps=4, max_new=12,
        async_migration=True,
    )
    assert stats.completed == 2
    assert stats.migrations > 0
    assert stats.overlapped_steps > 0, "no decode step retired during migration"
    assert not eng.cache.pipeline.busy  # run() drains stragglers


# ---------------------------------------------------------------------------
# arbiter: per-device bandwidth as a shared, rationed resource
# ---------------------------------------------------------------------------


def _arbiter(budget=None, windows=3, n_regions=64, seed=0):
    managers = [make_manager("6T-AM-0.5", n_regions) for _ in range(2)]
    arb = BudgetArbiter(
        [TenantSpec("a", sla_weight=2.0), TenantSpec("b")],
        managers, alpha=0.5, media_bw_budget_bytes=budget,
    )
    rng = np.random.default_rng(seed)
    for w in range(windows):
        for m in managers:
            counts = np.zeros(n_regions)
            hot = rng.choice(n_regions, size=8, replace=False)
            counts[hot] = rng.integers(100, 1000, 8)
            m.record_access_counts(counts)
        arb.end_window()
    return arb


def test_arbiter_defers_moves_when_device_bandwidth_saturates():
    free = _arbiter(budget=None)
    assert all(ws.deferred_migrations == 0 for ws in free.history)
    traffic = [ws.media_bytes_by_device for ws in free.history]
    assert any(t.get("host_dram_pcie", 0) > 0 for t in traffic)
    # Give the PCIe link a budget far below the unconstrained traffic.
    peak = max(t.get("host_dram_pcie", 0) for t in traffic)
    capped = _arbiter(budget={"host_dram_pcie": peak / 8})
    assert any(ws.deferred_migrations > 0 for ws in capped.history)
    for ws in capped.history:
        assert ws.media_bytes_by_device.get("host_dram_pcie", 0.0) <= peak / 8 + 1e-9


def test_simulator_replays_media_queues():
    from repro.core import simulator

    wl = simulator.gaussian_kv(n_regions=256, accesses_per_window=20_000)
    m = make_manager("6T-AM-0.5", 256)
    r = simulator.simulate(wl, m, windows=6, seed=1)
    assert r.media_bytes_by_device, "simulator should replay media traffic"
    assert sum(r.media_bytes_by_device.values()) > 0
    assert all(v >= 0 for v in r.media_busy_s_by_device.values())
    # Determinism of the replay.
    m2 = make_manager("6T-AM-0.5", 256)
    r2 = simulator.simulate(wl, m2, windows=6, seed=1)
    assert r.media_bytes_by_device == r2.media_bytes_by_device
    assert r.media_busy_s_by_device == r2.media_busy_s_by_device
