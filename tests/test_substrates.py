"""Substrate layers: data pipeline, checkpointing, elastic runtime."""

import os
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, HostLoader, synthetic_corpus
from repro.runtime import elastic


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_corpus_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, num_shards=2, shard_id=0)
    a = synthetic_corpus(cfg, step=3)
    b = synthetic_corpus(cfg, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    cfg1 = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, num_shards=2, shard_id=1)
    c = synthetic_corpus(cfg1, step=3)
    assert not np.array_equal(a["tokens"], c["tokens"])  # shards differ
    assert a["tokens"].shape == (4, 64)  # global 8 / 2 shards
    assert (a["targets"][:, :-1] == a["tokens"][:, 1:]).all()  # shifted targets
    assert a["tokens"].max() < 1000


def test_loader_prefetch_and_close():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, prefetch=2)
    loader = HostLoader(cfg)
    b1 = next(loader)
    b2 = next(loader)
    assert b1["tokens"].shape == (4, 16)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    loader.close()


def test_loader_straggler_mitigation():
    """A stalled producer must not stall the consumer."""
    calls = {"n": 0}

    def slow_make(cfg, step):
        calls["n"] += 1
        if step >= 2:
            time.sleep(5.0)  # straggler
        return synthetic_corpus(cfg, step)

    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2, prefetch=1,
                     straggler_timeout_s=0.5)
    loader = HostLoader(cfg, make_batch=slow_make)
    got = [next(loader) for _ in range(5)]
    assert len(got) == 5
    assert loader.straggler_events >= 1  # at least one skip-and-log
    loader.close()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "opt": {"m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))},
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(42, state)
    step, restored = mgr.restore(state)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=False)
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2  # GC keeps 2
    assert mgr.latest_step() == 4


def test_checkpoint_atomicity_torn_write(tmp_path):
    """A .tmp directory (crash mid-write) must be invisible to restore."""
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(1, state)
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "params.npz").write_bytes(b"garbage")
    step, _ = mgr.restore(state)
    assert step == 1


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(1, state)
    p = tmp_path / "step_00000001" / "params.npz"
    data = bytearray(p.read_bytes())
    data[len(data) // 2] ^= 0xFF
    p.write_bytes(bytes(data))
    with pytest.raises(Exception):
        mgr.restore(state)


# ---------------------------------------------------------------------------
# elastic runtime
# ---------------------------------------------------------------------------


def test_plan_remesh_keeps_tp_sheds_dp():
    plan = elastic.plan_remesh(
        n_devices=256, model_parallel=16, global_batch=256, microbatch_per_replica=16
    )
    assert plan.shape == (16, 16)
    survivors = elastic.plan_remesh(
        n_devices=192, model_parallel=16, global_batch=256, microbatch_per_replica=16
    )
    assert survivors.shape == (12, 16)
    assert survivors.grad_accum >= plan.grad_accum  # preserve global batch


def test_plan_remesh_refuses_below_tp():
    with pytest.raises(ValueError):
        elastic.plan_remesh(8, model_parallel=16, global_batch=64, microbatch_per_replica=1)


def test_elastic_runner_failure_restore_resume():
    """Inject a failure; the runner must remesh, restore, and converge."""
    saved = {}

    def build_step(plan):
        def step(state, batch):
            return {"x": state["x"] + batch}
        return step

    def save_fn(step, state):
        saved["ckpt"] = (step, {"x": state["x"]})

    restores = []

    def restore_fn():
        step, st = saved["ckpt"]
        restores.append(step)
        return step, dict(st)

    failed = {"done": False}

    def fail_hook(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            return 192  # 64 devices lost
        return None

    runner = elastic.ElasticRunner(
        build_step, save_fn, restore_fn,
        initial_plan=elastic.plan_remesh(256, 16, 256, 16),
        checkpoint_every=2,
        fail_hook=fail_hook,
        model_parallel=16,
        global_batch=256,
        microbatch_per_replica=16,
    )
    batches = iter(range(1, 1000))
    final_step, state = runner.run({"x": 0}, batches, n_steps=10)
    assert final_step == 10
    assert len(runner.remesh_events) == 1
    old_plan, new_plan = runner.remesh_events[0][1], runner.remesh_events[0][2]
    assert new_plan.n_devices == 192
    assert restores and restores[0] <= 7  # resumed from a pre-failure checkpoint
