"""Seeded-numpy property-test harness (the hypothesis replacement).

``cases(n)`` yields ``n`` independent, deterministically-seeded generators;
each test draws its own inputs from its case rng with the ``draw_*``
helpers. Failures print the case index + root seed so a case replays as
``rng = case_rng(root, i)``.

No external dependency: tier-1 must collect and pass on a bare
jax+numpy+pytest environment.
"""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def case_rng(root: int, i: int) -> np.random.Generator:
    """The i-th case generator of a run rooted at ``root``."""
    return np.random.default_rng(np.random.SeedSequence([root, i]))


def cases(n: int = 50, root: int = 0) -> Iterator[Tuple[int, np.random.Generator]]:
    """Yield (case_index, rng) for n independent random cases."""
    for i in range(n):
        yield i, case_rng(root, i)


def draw_int(rng: np.random.Generator, lo: int, hi: int) -> int:
    """Uniform integer in [lo, hi] (inclusive, hypothesis-style)."""
    return int(rng.integers(lo, hi + 1))


def draw_float(rng: np.random.Generator, lo: float, hi: float) -> float:
    """Uniform float in [lo, hi]."""
    return float(rng.uniform(lo, hi))


def draw_log_float(rng: np.random.Generator, lo: float, hi: float) -> float:
    """Log-uniform float in [lo, hi] (scale-type parameters)."""
    return float(np.exp(rng.uniform(np.log(lo), np.log(hi))))


def draw_choice(rng: np.random.Generator, options):
    """One element of ``options``."""
    return options[int(rng.integers(0, len(options)))]
