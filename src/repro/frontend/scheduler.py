"""Continuous-batching scheduler over one or more ``TieredEngine`` replicas.

Virtual time: one scheduler step = one decode step on every replica with
active slots. Each step the scheduler

  1. admits arrivals (token-budget admission over live engine headroom;
     refuse or queue instead of OOM),
  2. places queued work — highest SLA weight first, FIFO within a class;
     when the routed replica is full, a strictly-heavier arrival preempts
     the lightest preemptible victim: the victim slot's device pages demote
     through the media pipeline to the host tier (``preempt_slot``) and the
     request re-enters the queue WITH its pages parked,
  3. advances chunked prefills (one chunk per slot per step, interleaved
     with other slots' decode; the model prefill executes when the last
     chunk lands, emitting the first token),
  4. decodes, folding per-request telemetry (queue delay, TTFT, TBT,
     preemption count) into ``FrontendStats``.

Preempted requests resume via ``resume_into`` — host pages swap back in
through the same cohort machinery, zero tokens re-prefilled. Per-window
decoded-token demand per tenant accumulates in ``demand_windows`` and feeds
``BudgetArbiter.record_scheduled_demand`` (``feed_arbiter``), which is what
``fleet_report()``/``CapacityPlanner`` price fleets against.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.frontend.admission import (
    DEFAULT_CLASSES,
    REFUSE,
    AdmissionController,
    SLAClass,
)
from repro.frontend.router import ReplicaRouter
from repro.frontend.traces import ArrivalEvent
from repro.serving.engine import PreemptedRequest, Request, TieredEngine


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle + telemetry of one traced request."""

    event: ArrivalEvent
    state: str = "arriving"  # arriving|queued|prefill|running|preempted|done|refused
    request: Optional[Request] = None
    replica: int = -1
    slot: int = -1
    place_step: int = -1  # first slot reservation (queue-delay endpoint)
    first_token_step: int = -1
    done_step: int = -1
    chunks_left: int = 0
    preemptions: int = 0
    parked: Optional[PreemptedRequest] = None
    token_steps: List[int] = dataclasses.field(default_factory=list)

    def queue_delay(self) -> int:
        return self.place_step - self.event.step

    def ttft(self) -> int:
        return self.first_token_step - self.event.step

    def tbt(self) -> np.ndarray:
        return np.diff(np.asarray(self.token_steps, np.int64))


def pctl(values: Sequence[float], q: float) -> float:
    v = np.asarray(list(values), np.float64)
    if v.size == 0:
        return 0.0
    return float(np.percentile(v, q))


@dataclasses.dataclass
class FrontendStats:
    """Fleet-level request telemetry, grouped by SLA class."""

    records: List[RequestRecord]
    classes: Tuple[SLAClass, ...]
    steps: int = 0
    refused: int = 0
    preemptions: int = 0
    resumes: int = 0
    re_prefill_tokens: int = 0
    resumed_pages: int = 0
    decoded_tokens: int = 0
    # Per-window decoded tokens per tenant id — the scheduler-measured
    # decode demand ``BudgetArbiter.record_scheduled_demand`` consumes.
    demand_windows: List[Dict[int, float]] = dataclasses.field(default_factory=list)

    def done(self, sla: Optional[int] = None) -> List[RequestRecord]:
        return [
            r for r in self.records
            if r.state == "done" and (sla is None or r.event.sla == sla)
        ]

    def summary(self) -> Dict[str, object]:
        """Canonical (JSON-stable) roll-up: per-class percentiles + global
        preemption accounting. Two identical runs produce identical dicts —
        the serving_slo determinism probe compares these directly."""
        out: Dict[str, object] = {
            "steps": self.steps,
            "completed": len(self.done()),
            "refused": self.refused,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "resumed_pages": self.resumed_pages,
            "re_prefill_tokens": self.re_prefill_tokens,
            "decoded_tokens": self.decoded_tokens,
            "preemption_rate": round(
                self.preemptions / max(len(self.done()), 1), 6
            ),
        }
        for i, cls in enumerate(self.classes):
            recs = self.done(i)
            ttfts = [r.ttft() for r in recs]
            tbts = (
                np.concatenate([r.tbt() for r in recs])
                if recs else np.zeros(0, np.int64)
            )
            delays = [r.queue_delay() for r in recs]
            out[cls.name] = {
                "completed": len(recs),
                "ttft_p50": round(pctl(ttfts, 50), 6),
                "ttft_p99": round(pctl(ttfts, 99), 6),
                "tbt_p50": round(pctl(tbts, 50), 6),
                "tbt_p99": round(pctl(tbts, 99), 6),
                "queue_delay_mean": round(float(np.mean(delays)) if delays else 0.0, 6),
                "ttft_target": cls.ttft_target_steps,
                "ttft_slo_hit_rate": round(
                    float(np.mean([t <= cls.ttft_target_steps for t in ttfts]))
                    if ttfts else 0.0, 6
                ),
                "preemptions": sum(r.preemptions for r in recs),
            }
        return out

    def demand_by_window(self, tenant_names: Sequence[str]) -> List[Dict[str, float]]:
        """Rekey the per-window tenant-id demand onto arbiter tenant names
        (index-aligned: tenant id i -> tenant_names[i])."""
        return [
            {tenant_names[t]: float(v) for t, v in w.items()}
            for w in self.demand_windows
        ]

    def feed_arbiter(self, arbiter, tenant_names: Sequence[str]) -> int:
        """Push every scheduling window's measured decode demand into the
        arbiter; its next ``fleet_report()`` prices fleets against this
        instead of the synthetic telemetry constant. Returns windows fed."""
        windows = self.demand_by_window(tenant_names)
        for w in windows:
            arbiter.record_scheduled_demand(w)
        return len(windows)


class ContinuousScheduler:
    """SLA-aware continuous batching over N engine replicas."""

    def __init__(
        self,
        engines: Sequence[TieredEngine],
        events: Sequence[ArrivalEvent],
        vocab_size: int,
        classes: Sequence[SLAClass] = DEFAULT_CLASSES,
        admission: Optional[AdmissionController] = None,
        router: Optional[ReplicaRouter] = None,
        prefill_chunk_tokens: int = 16,
        window_steps: Optional[int] = None,
    ):
        if not engines:
            raise ValueError("need at least one engine replica")
        self.engines = list(engines)
        self.vocab = vocab_size
        self.classes = tuple(classes)
        for e in events:
            if not (0 <= e.sla < len(self.classes)):
                raise ValueError(f"event {e.seq} names unknown SLA class {e.sla}")
        self.admission = admission or AdmissionController(self.classes)
        self.router = router or ReplicaRouter(len(self.engines))
        self.chunk = max(int(prefill_chunk_tokens), 1)
        self.window_steps = int(window_steps or self.engines[0].ts.window_steps)
        self.records = [RequestRecord(e) for e in sorted(events, key=lambda e: (e.step, e.seq))]
        self.queue: List[int] = []  # record indices awaiting placement
        # Per-replica slot -> record index (running) and reserved prefills.
        self._running: List[Dict[int, int]] = [dict() for _ in self.engines]
        self._prefilling: List[Dict[int, int]] = [dict() for _ in self.engines]
        self.stats = FrontendStats(records=self.records, classes=self.classes)
        self._win_demand: Dict[int, float] = {}
        self._steps_in_window = 0

    # ------------------------------------------------------------- helpers
    def _cls(self, rec: RequestRecord) -> SLAClass:
        return self.classes[rec.event.sla]

    def _free_slots(self, r: int) -> List[int]:
        eng = self.engines[r]
        held = set(self._prefilling[r]) | set(self._running[r])
        return [s for s in eng.free_slots() if s not in held]

    def _outstanding(self) -> List[int]:
        # Engine outstanding + prefill reservations the engine can't see yet.
        out = []
        for r, eng in enumerate(self.engines):
            extra = sum(
                self.records[i].event.prompt_len + self.records[i].event.max_new_tokens
                for i in self._prefilling[r].values()
            )
            out.append(eng.outstanding_tokens() + extra)
        return out

    def _queued_of_class(self, sla: int) -> int:
        return sum(1 for i in self.queue if self.records[i].event.sla == sla)

    def _live(self) -> bool:
        return bool(
            self.queue
            or any(self._prefilling[r] or self._running[r] for r in range(len(self.engines)))
        )

    # ------------------------------------------------------------ lifecycle
    def _admit_arrivals(self, step: int, cursor: int) -> int:
        while cursor < len(self.records) and self.records[cursor].event.step <= step:
            rec = self.records[cursor]
            outstanding = self._outstanding()
            r = self.router.route(rec.event, outstanding)
            rec.replica = r
            decision = self.admission.decide(
                rec.event,
                capacity_tokens=sum(e.token_capacity() for e in self.engines),
                outstanding_tokens=sum(outstanding),
                headroom_tokens=self.engines[r].device_headroom_tokens(),
                free_slot=bool(self._free_slots(r)),
                queued_of_class=self._queued_of_class(rec.event.sla),
            )
            if decision == REFUSE:
                rec.state = "refused"
                self.stats.refused += 1
                self.router.note_done(rec.event)
            else:  # ADMIT and QUEUE both enter the placement queue; ADMIT
                # is guaranteed to place this same step (slot + headroom).
                rec.state = "queued"
                self.queue.append(cursor)
            cursor += 1
        return cursor

    def _pick_victim(self, r: int, weight: float) -> Optional[int]:
        """Lightest preemptible running slot strictly below ``weight``;
        youngest first (least KV to demote), slot index tie-break."""
        cands = []
        for slot, idx in self._running[r].items():
            rec = self.records[idx]
            cls = self._cls(rec)
            if cls.preemptible and cls.weight < weight:
                cands.append((cls.weight, -rec.place_step, slot))
        if not cands:
            return None
        return min(cands)[2]

    def _place(self, step: int) -> None:
        # Heaviest class first; FIFO (trace order) within a class. A pass
        # places into free slots, then lets strictly-heavier work preempt.
        order = sorted(
            self.queue, key=lambda i: (-self._cls(self.records[i]).weight, i)
        )
        for idx in order:
            rec = self.records[idx]
            r = rec.replica
            eng = self.engines[r]
            free = self._free_slots(r)
            if not free:
                victim_slot = self._pick_victim(r, self._cls(rec).weight)
                if victim_slot is None:
                    continue
                vidx = self._running[r].pop(victim_slot)
                vrec = self.records[vidx]
                vrec.parked = eng.preempt_slot(victim_slot)
                vrec.state = "preempted"
                vrec.slot = -1
                vrec.preemptions += 1
                self.stats.preemptions += 1
                self.queue.append(vidx)
                free = [victim_slot]
            slot = free[0]
            self.queue.remove(idx)
            rec.slot = slot
            if rec.place_step < 0:
                rec.place_step = step
            if rec.parked is not None:
                # Resume: parked host pages swap back in, zero re-prefill.
                eng.resume_into(slot, rec.parked)
                rec.parked = None
                rec.state = "running"
                self._running[r][slot] = idx
                self.stats.resumes += 1
            else:
                rec.state = "prefill"
                rec.chunks_left = max(
                    math.ceil(rec.event.prompt_len / self.chunk), 1
                )
                self._prefilling[r][slot] = idx

    def _advance_prefills(self, step: int) -> None:
        for r, eng in enumerate(self.engines):
            for slot in sorted(self._prefilling[r]):
                idx = self._prefilling[r][slot]
                rec = self.records[idx]
                rec.chunks_left -= 1
                if rec.chunks_left > 0:
                    continue
                # Final chunk: execute the model prefill, emit first token.
                ev = rec.event
                rec.request = eng.make_request(
                    ev.prompt(self.vocab), ev.max_new_tokens, tenant=ev.tenant
                )
                eng.start_request(slot, rec.request)
                rec.first_token_step = step
                rec.token_steps.append(step)
                rec.state = "running"
                del self._prefilling[r][slot]
                self._running[r][slot] = idx
                self.stats.decoded_tokens += 1
                self._win_demand[ev.tenant] = self._win_demand.get(ev.tenant, 0.0) + 1.0

    def _decode(self, step: int) -> None:
        for r, eng in enumerate(self.engines):
            if not self._running[r]:
                continue
            eng.step()
            for slot in sorted(self._running[r]):
                idx = self._running[r][slot]
                rec = self.records[idx]
                rec.token_steps.append(step)
                self.stats.decoded_tokens += 1
                self._win_demand[rec.event.tenant] = (
                    self._win_demand.get(rec.event.tenant, 0.0) + 1.0
                )
                if rec.request.done:
                    rec.state = "done"
                    rec.done_step = step
                    del self._running[r][slot]
                    self.router.note_done(rec.event)

    def _close_window(self) -> None:
        self.stats.demand_windows.append(dict(self._win_demand))
        self._win_demand = {}
        self._steps_in_window = 0

    # ------------------------------------------------------------------ run
    def run(self, max_steps: int = 10_000) -> FrontendStats:
        step, cursor = 0, 0
        while step < max_steps and (cursor < len(self.records) or self._live()):
            cursor = self._admit_arrivals(step, cursor)
            self._place(step)
            # Decode BEFORE finishing prefills: a slot whose last chunk lands
            # this step emits its first token now and begins decoding next
            # step — never two tokens in one virtual step.
            self._decode(step)
            self._advance_prefills(step)
            self._steps_in_window += 1
            if self._steps_in_window >= self.window_steps:
                self._close_window()
            step += 1
        if self._win_demand or self._steps_in_window:
            self._close_window()
        self.stats.steps = step
        for eng in self.engines:
            es = eng.finish()
            self.stats.re_prefill_tokens += es.re_prefill_tokens
            self.stats.resumed_pages += es.resumed_pages
        return self.stats
