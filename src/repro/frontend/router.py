"""Replica router: least-outstanding-tokens with session affinity.

Sessions stick to the replica serving their live requests (their earlier
turns' KV pages and prefetch history live there); otherwise the arrival
lands on the replica with the fewest outstanding tokens, ties broken by the
lowest replica index so routing is fully deterministic.
"""

from __future__ import annotations

from typing import Dict, Sequence

from repro.frontend.traces import ArrivalEvent


class ReplicaRouter:
    def __init__(self, n_replicas: int, affinity: bool = True):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.n = n_replicas
        self.affinity = affinity
        self._session_replica: Dict[int, int] = {}
        self._session_live: Dict[int, int] = {}

    def route(self, event: ArrivalEvent, outstanding: Sequence[int]) -> int:
        """Pick the replica for one arrival given per-replica outstanding
        token counts (binds the session; pair with ``note_done``)."""
        if len(outstanding) != self.n:
            raise ValueError("one outstanding count per replica")
        s = event.session
        if (
            self.affinity
            and s in self._session_replica
            and self._session_live.get(s, 0) > 0
        ):
            r = self._session_replica[s]
        else:
            best = min(outstanding)
            r = next(i for i, o in enumerate(outstanding) if o == best)
            self._session_replica[s] = r
        self._session_live[s] = self._session_live.get(s, 0) + 1
        return r

    def note_done(self, event: ArrivalEvent) -> None:
        """A routed request finished (or was refused after routing): release
        its affinity hold. The sticky binding survives until the session has
        no live requests, then least-outstanding takes over again."""
        s = event.session
        self._session_live[s] = max(self._session_live.get(s, 0) - 1, 0)
