"""SLA classes + token-budget admission control for the serving frontend.

The controller is a PURE decision function over live engine headroom: the
scheduler feeds it the fleet's token capacity, outstanding commitments
(resident context + ungenerated remainder + queued projections) and live
device-tier headroom, all read from ``TieredEngine``/``TieredKVCache``
accessors each step. Admission never lets a class push the fleet past its
token-budget share — requests queue or are refused instead of OOMing the
pools — and per-class queue caps bound the worst-case queue delay.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.frontend.traces import ArrivalEvent

ADMIT = "admit"  # place now (free slot + device headroom for the prompt)
QUEUE = "queue"  # hold in the frontend queue; placement pass retries
REFUSE = "refuse"  # would break the class token budget / queue cap


@dataclasses.dataclass(frozen=True)
class SLAClass:
    """One service class. ``weight`` orders both placement priority and
    preemption (a class may only preempt strictly lighter victims);
    ``budget_frac`` is the fleet token-residency share past which this
    class's arrivals are refused (heavier classes get the larger share);
    ``ttft_target_steps`` is the SLO target the reports grade against."""

    name: str
    weight: float = 1.0
    ttft_target_steps: int = 64
    budget_frac: float = 0.9
    max_queue: int = 64
    preemptible: bool = True


# Default two-class mix: bulk batch traffic fills slots cheaply and yields
# them to the tight-TTFT interactive class, which may preempt but never be
# preempted.
DEFAULT_CLASSES: Tuple[SLAClass, ...] = (
    SLAClass("batch", weight=0.5, ttft_target_steps=256, budget_frac=0.75,
             max_queue=256, preemptible=True),
    SLAClass("interactive", weight=2.0, ttft_target_steps=24, budget_frac=1.0,
             max_queue=16, preemptible=False),
)


class AdmissionController:
    """Token-budget admission over one or more engine replicas."""

    def __init__(self, classes: Sequence[SLAClass] = DEFAULT_CLASSES):
        if not classes:
            raise ValueError("need at least one SLA class")
        self.classes = tuple(classes)

    def projected_tokens(self, event: ArrivalEvent) -> int:
        return int(event.prompt_len) + int(event.max_new_tokens)

    def decide(
        self,
        event: ArrivalEvent,
        *,
        capacity_tokens: int,
        outstanding_tokens: int,
        headroom_tokens: int,
        free_slot: bool,
        queued_of_class: int,
    ) -> str:
        """Admission decision for one arrival against live fleet state.

        ``capacity_tokens``/``outstanding_tokens`` come from the engines'
        token accounting, ``headroom_tokens`` from the live device-pool free
        lists, ``free_slot`` from the routed replica, ``queued_of_class``
        from the frontend queue. Refusal is load shedding; queueing is
        backpressure; admission starts the request this step."""
        cls = self.classes[event.sla]
        projected = self.projected_tokens(event)
        if queued_of_class >= cls.max_queue:
            return REFUSE
        if outstanding_tokens + projected > cls.budget_frac * capacity_tokens:
            return REFUSE
        if free_slot and projected <= headroom_tokens:
            return ADMIT
        return QUEUE
