"""Deterministic arrival traces for the serving frontend.

Requests arrive in VIRTUAL TIME (integer scheduler steps, one decode step
per unit) from three seeded generators:

  * ``poisson`` — constant-rate Poisson arrivals;
  * ``diurnal`` — Poisson with a sinusoidal day/night rate swing
    (``period_steps``, ``trough_frac``);
  * ``burst``   — Poisson base load plus periodic bursts
    (``burst_every``/``burst_len``/``burst_mult``), optionally pinned to one
    SLA class (``burst_sla``) — the preemption trigger.

Tenant mix can flip mid-trace (``tenant_flip_step``): the skew-flip pattern
the placement benchmarks use, expressed as arrival skew. Each event carries
its own ``prompt_seed`` so prompt token ids materialize deterministically
and independently of generation order. ``python -m repro.frontend.traces
--check`` validates two-pass determinism of every kind (the CI tier-1 smoke
invocation).
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import math
import sys
from typing import List, Optional, Tuple

import numpy as np

TRACE_KINDS = ("poisson", "diurnal", "burst")


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One request arrival in virtual time."""

    step: int  # arrival step (scheduler virtual time)
    seq: int  # trace order, unique — FIFO tie-break within an SLA class
    tenant: int
    sla: int  # index into the scheduler's SLA-class list
    session: int  # session id for router affinity
    prompt_len: int
    max_new_tokens: int
    prompt_seed: int

    def prompt(self, vocab_size: int) -> np.ndarray:
        """Materialize the prompt token ids (deterministic per event)."""
        rng = np.random.default_rng(self.prompt_seed)
        return rng.integers(1, vocab_size, size=self.prompt_len).astype(np.int32)

    def key(self) -> Tuple[int, ...]:
        return (self.step, self.seq, self.tenant, self.sla, self.session,
                self.prompt_len, self.max_new_tokens, self.prompt_seed)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    kind: str = "poisson"
    steps: int = 128
    rate: float = 0.25  # mean arrivals per step (base load)
    seed: int = 0
    n_tenants: int = 2
    n_sessions: int = 8
    sla_mix: Tuple[float, ...] = (0.7, 0.3)  # arrival weight per SLA class
    prompt_len: Tuple[int, int] = (16, 32)  # inclusive range
    new_tokens: Tuple[int, int] = (8, 24)  # inclusive range
    # Tenant skew (weights over tenant ids); reversed after tenant_flip_step.
    tenant_mix: Optional[Tuple[float, ...]] = None
    tenant_flip_step: Optional[int] = None
    # diurnal
    period_steps: int = 64
    trough_frac: float = 0.2  # trough rate as a fraction of the peak
    # burst
    burst_every: int = 48
    burst_len: int = 6
    burst_mult: float = 6.0
    burst_sla: Optional[int] = None  # pin burst arrivals to one SLA class


def rate_at(cfg: TraceConfig, step: int) -> float:
    """Instantaneous arrival rate at ``step`` (virtual time)."""
    if cfg.kind == "poisson":
        return cfg.rate
    if cfg.kind == "diurnal":
        # Peak at cfg.rate, trough at trough_frac * rate, sinusoidal.
        lo = cfg.trough_frac * cfg.rate
        phase = 2.0 * math.pi * (step % cfg.period_steps) / cfg.period_steps
        return lo + (cfg.rate - lo) * 0.5 * (1.0 + math.cos(phase))
    if cfg.kind == "burst":
        base = cfg.rate
        if (step % cfg.burst_every) < cfg.burst_len:
            return base * cfg.burst_mult
        return base
    raise ValueError(f"unknown trace kind {cfg.kind!r} (want one of {TRACE_KINDS})")


def _in_burst(cfg: TraceConfig, step: int) -> bool:
    return cfg.kind == "burst" and (step % cfg.burst_every) < cfg.burst_len


def generate(cfg: TraceConfig) -> List[ArrivalEvent]:
    """Generate the full arrival trace (sorted by (step, seq)). Stateless:
    the same config always yields the same events, byte for byte."""
    if cfg.kind not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {cfg.kind!r} (want one of {TRACE_KINDS})")
    rng = np.random.default_rng(cfg.seed)
    sla_p = np.asarray(cfg.sla_mix, np.float64)
    sla_p = sla_p / sla_p.sum()
    ten_p = None
    if cfg.tenant_mix is not None:
        ten_p = np.asarray(cfg.tenant_mix, np.float64)
        if ten_p.size != cfg.n_tenants:
            raise ValueError("tenant_mix must have one weight per tenant")
        ten_p = ten_p / ten_p.sum()
    events: List[ArrivalEvent] = []
    seq = 0
    for step in range(cfg.steps):
        n = int(rng.poisson(rate_at(cfg, step)))
        for _ in range(n):
            if cfg.burst_sla is not None and _in_burst(cfg, step):
                sla = int(cfg.burst_sla)
            else:
                sla = int(rng.choice(sla_p.size, p=sla_p))
            if ten_p is None:
                tenant = int(rng.integers(cfg.n_tenants))
            else:
                p = ten_p
                if cfg.tenant_flip_step is not None and step >= cfg.tenant_flip_step:
                    p = ten_p[::-1]
                tenant = int(rng.choice(cfg.n_tenants, p=p))
            events.append(ArrivalEvent(
                step=step,
                seq=seq,
                tenant=tenant,
                sla=sla,
                session=int(rng.integers(cfg.n_sessions)),
                prompt_len=int(rng.integers(cfg.prompt_len[0], cfg.prompt_len[1] + 1)),
                max_new_tokens=int(rng.integers(cfg.new_tokens[0], cfg.new_tokens[1] + 1)),
                prompt_seed=int(rng.integers(2**31 - 1)),
            ))
            seq += 1
    return events


def digest(events: List[ArrivalEvent]) -> str:
    """Canonical sha256 over the full event stream (replay fingerprint)."""
    h = hashlib.sha256()
    for e in events:
        h.update(repr(e.key()).encode())
    return h.hexdigest()


def check(seeds: Tuple[int, ...] = (0, 1)) -> int:
    """Trace-determinism smoke (CI tier-1 invocation): every kind x seed
    must regenerate bit-identically (fresh RNGs both times), stay sorted in
    virtual time, and produce deterministic prompt token ids."""
    failures = 0
    for kind in TRACE_KINDS:
        for seed in seeds:
            cfg = TraceConfig(
                kind=kind, seed=seed, steps=96, rate=0.5,
                tenant_mix=(0.8, 0.2), tenant_flip_step=48,
                burst_sla=1,
            )
            a, b = generate(cfg), generate(cfg)
            da, db = digest(a), digest(b)
            ok = (
                da == db
                and len(a) > 0
                and all(x.key() == y.key() for x, y in zip(a, b))
                and all(a[i].step <= a[i + 1].step for i in range(len(a) - 1))
                and all(a[i].seq == i for i in range(len(a)))
                and np.array_equal(a[0].prompt(256), b[0].prompt(256))
            )
            status = "ok" if ok else "MISMATCH"
            print(f"  {kind:8s} seed={seed} events={len(a):4d} {da[:16]} {status}")
            if not ok:
                failures += 1
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="validate two-pass trace determinism (CI smoke)")
    ap.add_argument("--kind", default="poisson", choices=TRACE_KINDS)
    ap.add_argument("--steps", type=int, default=128)
    ap.add_argument("--rate", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.check:
        print("trace determinism check:")
        failures = check()
        print("PASS" if failures == 0 else f"FAIL ({failures} mismatches)")
        return 1 if failures else 0
    cfg = TraceConfig(kind=args.kind, steps=args.steps, rate=args.rate, seed=args.seed)
    ev = generate(cfg)
    print(f"{cfg.kind} trace: {len(ev)} arrivals over {cfg.steps} steps "
          f"(digest {digest(ev)[:16]})")
    for e in ev[:10]:
        print(f"  step={e.step:4d} seq={e.seq:4d} tenant={e.tenant} sla={e.sla} "
              f"session={e.session} prompt={e.prompt_len} gen={e.max_new_tokens}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
