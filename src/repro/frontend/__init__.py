"""Serving frontend: SLA-aware continuous batching over engine replicas.

``traces`` generates deterministic virtual-time arrivals, ``admission``
holds the SLA classes + token-budget controller, ``router`` load-balances
replicas, and ``scheduler`` runs the lifecycle — including
preemption-to-host-tier and zero-re-prefill resume.
"""

from repro.frontend.admission import (
    ADMIT,
    DEFAULT_CLASSES,
    QUEUE,
    REFUSE,
    AdmissionController,
    SLAClass,
)
from repro.frontend.router import ReplicaRouter
from repro.frontend.scheduler import (
    ContinuousScheduler,
    FrontendStats,
    RequestRecord,
)
from repro.frontend.traces import ArrivalEvent, TraceConfig, digest, generate

__all__ = [
    "ADMIT",
    "QUEUE",
    "REFUSE",
    "AdmissionController",
    "ArrivalEvent",
    "ContinuousScheduler",
    "DEFAULT_CLASSES",
    "FrontendStats",
    "ReplicaRouter",
    "RequestRecord",
    "SLAClass",
    "TraceConfig",
    "digest",
    "generate",
]
