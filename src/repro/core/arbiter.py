"""Global budget arbiter for multi-tenant tiersets (paper §8 direction).

One ``TierScapeManager`` per tenant — each with its own telemetry, placement
policy and SLA/TCO targets — shares the physical pools. Every profile window
the ``BudgetArbiter``:

  1. closes every tenant's telemetry window,
  2. **waterfills** the fleet-wide TCO budget (Eq. 2's bound, summed over
     tenants) across tenants by marginal TCO-saving per unit of perf impact:
     the globally cheapest demotion edges — smallest
     ``sla_weight * hotness * Δlat / Δcost_saved`` — are taken first until the
     fleet fits the budget, so the hotter / higher-SLA tenant keeps its fast
     tier and the cheapest marginal pages (usually the colder tenant's) are
     demoted,
  3. lets each tenant's manager plan autonomously against its allotted
     budget (analytical tenants consume the budget; waterfall/2T tenants
     plan by threshold and are bounded by step 4),
  4. reconciles shared-pool over-subscription: when a tier's region capacity
     is exceeded across tenants, the cheapest marginal pages (smallest
     weighted hotness) are demoted to the next tier with headroom,
  5. commits every tenant's placement and records per-tenant + fleet stats.

SLA floors: a tenant is never demoted below
``tco_min + alpha_floor * (tco_max - tco_min)`` USD of residency — a starved
tenant keeps at least that much fast-tier budget even when another tenant's
pages are globally hotter. The capacity pass honors floors too (victims are
taken from above-floor tenants first); only when physical capacity cannot
hold every tenant's floor does it breach one — capacity is physical, USD
floors are policy.

Determinism: the waterfill is a single stable argsort over concatenated edge
keys plus an in-order take; with fixed seeds (telemetry, workloads) the
allotted budgets and placements are bit-identical across runs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import tco
from repro.core.analytical import _hull_indices
from repro.core.manager import MigrationPlan, TierScapeManager
from repro.core.pools import TenantLedger


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """SLA/TCO contract of one tenant sharing the tierset substrate."""

    name: str
    # Relative price of this tenant's perf impact in the waterfill: a page of
    # weight-2 tenant is demoted only after an equally-hot page of a weight-1
    # tenant. >1 = latency-sensitive, <1 = batch/best-effort.
    sla_weight: float = 1.0
    # Guaranteed budget floor as a fraction of the tenant's [TCO_min, TCO_max]
    # span. The arbiter never *demotes* the tenant below this spend; a tenant
    # with zero measured hotness still parks at min cost voluntarily (there
    # is no perf to protect, so reserving budget for it would be waste).
    alpha_floor: float = 0.0

    def __post_init__(self):
        if self.sla_weight <= 0:
            raise ValueError("sla_weight must be positive")
        if not 0.0 <= self.alpha_floor <= 1.0:
            raise ValueError("alpha_floor must be in [0, 1]")


@dataclasses.dataclass
class TenantWindowStats:
    tenant: str
    window: int
    budget_usd: float  # allotted by the waterfill (incl. slack share)
    spent_usd: float  # committed placement cost
    sla_floor_usd: float
    savings_pct: float
    fast_regions: int  # regions resident in placement 0 (uncompressed)
    weighted_penalty_s: float  # sla_weight * sum(hot * Lat) of the commit
    # Decode demand the tenant presented this window (closed-window access
    # total, PEBS-noised if telemetry is) — the capacity planner's
    # throughput-demand signal.
    demand_accesses: float = 0.0


@dataclasses.dataclass
class ArbiterWindowStats:
    window: int
    global_budget_usd: float
    fleet_tco_usd: float
    fleet_savings_pct: float
    budget_feasible: bool  # False when SLA floors force spend above budget
    tenants: List[TenantWindowStats]
    # Shared-bandwidth reconcile: fleet migration bytes billed per device
    # and moves deferred because a device's window budget was exhausted.
    media_bytes_by_device: Dict[str, float] = dataclasses.field(default_factory=dict)
    deferred_migrations: int = 0
    # Speculative prefetch traffic recorded mid-window: already moved, so it
    # consumed the device budgets before any demand move was considered.
    speculative_bytes_by_device: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )


class BudgetArbiter:
    """Splits per-tier capacity + the global TCO budget across N tenants."""

    def __init__(
        self,
        specs: Sequence[TenantSpec],
        managers: Sequence[TierScapeManager],
        alpha: float = 0.5,
        tier_capacity_regions: Optional[np.ndarray] = None,
        media_bw_budget_bytes: Optional[Dict[str, float]] = None,
    ):
        """``media_bw_budget_bytes`` caps, per backing-media device, the
        migration bytes the whole fleet may move in one window (bandwidth is
        a shared resource exactly like tier capacity). Moves exceeding a
        device's budget are deferred — the placement keeps its old value and
        the policy retries next window — coldest weighted pages first."""
        if len(specs) != len(managers):
            raise ValueError("one manager per tenant spec")
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("tenant names must be unique")
        n_opts = {m.tierset.n_tiers + 1 for m in managers}
        if len(n_opts) != 1:
            raise ValueError("all tenants must share the tierset structure")
        self.n_options = n_opts.pop()
        self.specs = list(specs)
        self.managers = list(managers)
        self.alpha = alpha
        if tier_capacity_regions is None:
            cap = np.full(self.n_options, np.inf)
        else:
            cap = np.asarray(tier_capacity_regions, dtype=np.float64)
            if cap.shape != (self.n_options,):
                raise ValueError(f"capacity must have shape ({self.n_options},)")
            if cap.sum() < sum(m.n_regions for m in managers):
                raise ValueError("pool capacities cannot hold the fleet's regions")
        self.capacity_regions = cap
        self.media_bw_budget_bytes = dict(media_bw_budget_bytes or {})
        self.ledger = TenantLedger([s.name for s in specs], cap)
        self.history: List[ArbiterWindowStats] = []
        self._window = 0
        self._spec_bytes: Dict[str, float] = {}
        # Scheduler-measured decode demand (tokens decoded per tenant per
        # frontend scheduling window). When any records exist they REPLACE
        # the telemetry access sums as ``fleet_report``'s demand signal.
        self._sched_demand: List[Dict[str, float]] = []

    def record_scheduled_demand(self, demand: Dict[str, float]) -> None:
        """Record one frontend scheduling window's measured decode demand
        (tenant name -> tokens decoded). The capacity planner then prices
        fleets against what the scheduler actually served, not a synthetic
        per-window constant."""
        known = {s.name for s in self.specs}
        unknown = set(demand) - known
        if unknown:
            raise KeyError(f"unknown tenant(s) in scheduled demand: {sorted(unknown)}")
        self._sched_demand.append({k: float(v) for k, v in demand.items()})

    def record_speculative_bytes(self, bytes_by_device: Dict[str, float]) -> None:
        """Bill mid-window speculative prefetch traffic against the shared
        per-device bandwidth budgets. The bytes were already moved by the
        time the window closes, so the upcoming reconcile has that much
        less headroom for demand migrations on the same device —
        mispredicted speculation consumes real budget and shows up as
        deferred demand moves rather than disappearing."""
        for dev, b in bytes_by_device.items():
            self._spec_bytes[dev] = self._spec_bytes.get(dev, 0.0) + float(b)

    # ----------------------------------------------------------------- window
    def global_budget_usd(self) -> float:
        """Fleet-wide Eq. 2 bound: sum of per-tenant alpha-budgets."""
        return sum(
            tco.budget(m.tierset, m.n_regions, m.region_bytes, self.alpha, m.measured_ratios)
            for m in self.managers
        )

    def sla_floor_usd(self, t: int) -> float:
        m, s = self.managers[t], self.specs[t]
        mx = tco.tco_max(m.n_regions, m.region_bytes)
        mn = tco.tco_min(m.tierset, m.n_regions, m.region_bytes, m.measured_ratios)
        return mn + s.alpha_floor * (mx - mn)

    def end_window(self) -> Dict[str, MigrationPlan]:
        """Arbitrate one window; returns each tenant's migration plan."""
        hots = [m.close_telemetry() for m in self.managers]
        avg_hots = [
            m.telemetry.averaged_hotness(m.cfg.history_windows) for m in self.managers
        ]
        costs = [
            tco.usd_per_region(m.tierset, m.region_bytes, m.measured_ratios)
            for m in self.managers
        ]
        # Contended latencies: devices saturated in previous windows make
        # their tiers look slower to every tenant's waterfill.
        lats = [m.contended_latencies_s() for m in self.managers]
        floors = [self.sla_floor_usd(t) for t in range(len(self.specs))]
        global_budget = self.global_budget_usd()

        budgets = self._waterfill(avg_hots, costs, lats, floors, global_budget)

        news = []
        for t, m in enumerate(self.managers):
            if m.cfg.policy == "analytical":
                news.append(
                    m.plan_placement(
                        hots[t], budget=budgets[t],
                        avg_hotness=avg_hots[t], option_costs=costs[t],
                    )
                )
            else:
                news.append(m.plan_placement(hots[t]))
        news, deferred = self._reconcile_bandwidth(news, avg_hots)
        news = self._reconcile_capacity(news, avg_hots, costs, floors)

        plans: Dict[str, MigrationPlan] = {}
        tenant_stats: List[TenantWindowStats] = []
        media_bytes: Dict[str, float] = {}
        for t, (m, s) in enumerate(zip(self.managers, self.specs)):
            plans[s.name] = m.commit_placement(news[t])
            # Fleet media traffic as COMMITTED (capacity-pass moves included,
            # deferred moves excluded) — agrees with the tenants' WindowStats.
            for dev, b in plans[s.name].media_bytes_by_device.items():
                media_bytes[dev] = media_bytes.get(dev, 0.0) + b
            self.ledger.set_usage(
                s.name, np.bincount(news[t], minlength=self.n_options)
            )
            spent = tco.tco_nt(m.tierset, news[t], m.region_bytes, m.measured_ratios)
            tenant_stats.append(
                TenantWindowStats(
                    tenant=s.name,
                    window=self._window,
                    budget_usd=budgets[t],
                    spent_usd=spent,
                    sla_floor_usd=floors[t],
                    savings_pct=m.history[-1].savings_pct,
                    fast_regions=int((news[t] == 0).sum()),
                    weighted_penalty_s=float(
                        s.sla_weight * (avg_hots[t] * lats[t][news[t]]).sum()
                    ),
                    demand_accesses=float(np.asarray(hots[t]).sum()),
                )
            )
        # After commit every manager's placement == news[t], so the fleet
        # aggregation helpers price exactly what was just committed.
        self.history.append(
            ArbiterWindowStats(
                window=self._window,
                global_budget_usd=global_budget,
                fleet_tco_usd=tco.fleet_tco_usd(self.managers),
                fleet_savings_pct=tco.fleet_savings_pct(self.managers),
                budget_feasible=sum(budgets) <= global_budget * (1 + 1e-9),
                tenants=tenant_stats,
                media_bytes_by_device=media_bytes,
                deferred_migrations=deferred,
                speculative_bytes_by_device=dict(self._spec_bytes),
            )
        )
        self._spec_bytes = {}
        self._window += 1
        return plans

    # -------------------------------------------------------------- waterfill
    def _waterfill(
        self,
        avg_hots: Sequence[np.ndarray],
        costs: Sequence[np.ndarray],
        lats: Sequence[np.ndarray],
        floors: Sequence[float],
        global_budget: float,
    ) -> List[float]:
        """Split ``global_budget`` across tenants by marginal utility.

        Mirrors ``analytical.solve_greedy``'s LP-greedy, but the edge pool is
        fleet-wide and each edge key is scaled by the tenant's SLA weight.
        Returns per-tenant USD budgets that sum exactly to
        ``max(global_budget, sum of floor-clamped spends)``.
        """
        n_t = len(self.specs)
        spend = np.zeros(n_t)
        edge_key: List[np.ndarray] = []
        edge_tenant: List[np.ndarray] = []
        edge_saving: List[np.ndarray] = []
        edge_region: List[np.ndarray] = []

        for t in range(n_t):
            hot = np.asarray(avg_hots[t], dtype=np.float64)
            hull = _hull_indices(costs[t], lats[t])
            hull_costs = costs[t][hull]
            cold = hot <= 0
            # Start everyone at min-penalty; cold regions at min cost (their
            # penalty is 0 everywhere — exactly solve_greedy's opening state).
            spend[t] = float(
                (~cold).sum() * hull_costs[0] + cold.sum() * costs[t].min()
            )
            if len(hull) < 2:
                continue
            d_cost = hull_costs[:-1] - hull_costs[1:]  # (E,) > 0 saved per edge
            d_lat = lats[t][hull][1:] - lats[t][hull][:-1]  # (E,) >= 0 added
            slopes = np.where(d_cost > 0, d_lat / np.maximum(d_cost, 1e-30), np.inf)
            hot_idx = np.where(~cold)[0]
            if hot_idx.size == 0:
                continue
            keys = self.specs[t].sla_weight * hot[hot_idx][:, None] * slopes[None, :]
            edge_key.append(keys.reshape(-1))
            edge_tenant.append(np.full(keys.size, t, dtype=np.int64))
            edge_saving.append(
                np.broadcast_to(d_cost[None, :], keys.shape).reshape(-1)
            )
            edge_region.append(
                np.broadcast_to(
                    np.arange(hot_idx.size)[:, None], keys.shape
                ).reshape(-1)
            )

        need = float(spend.sum()) - global_budget
        if need > 0 and edge_key:
            keys = np.concatenate(edge_key)
            tenants = np.concatenate(edge_tenant)
            savings = np.concatenate(edge_saving)
            regions = np.concatenate(edge_region)
            order = np.argsort(keys, kind="stable")
            blocked: set = set()
            for i in order:
                t = int(tenants[i])
                key = (t, int(regions[i]))
                if key in blocked:
                    continue
                dc = float(savings[i])
                if spend[t] - dc < floors[t] - 1e-12:
                    # This demotion would breach the tenant's SLA floor. A
                    # region's hull edges must be taken in order, so block
                    # this region's remaining chain — but keep considering
                    # the tenant's other regions, whose next edges may save
                    # less and still fit above the floor.
                    blocked.add(key)
                    continue
                spend[t] -= dc
                need -= dc
                if need <= 0:
                    break

        budgets = spend.copy()
        slack = global_budget - float(spend.sum())
        if slack > 0:
            # Distribute unneeded headroom by SLA weight so budgets sum to
            # the global budget exactly (the ledger invariant).
            w = np.array([s.sla_weight for s in self.specs])
            budgets = budgets + slack * w / w.sum()
        return [float(b) for b in budgets]

    # --------------------------------------------------- bandwidth reconcile
    def _reconcile_bandwidth(
        self, news: List[np.ndarray], avg_hots: Sequence[np.ndarray]
    ):
        """Enforce per-device migration-bandwidth budgets fleet-wide.

        Every planned move bills a read to its source device and a write to
        its destination device (the manager's media cost model). When a
        device's billed bytes exceed its per-window budget, the cheapest
        marginal moves touching that device — smallest ``sla_weight *
        hotness`` fleet-wide, ties by region index — are *deferred*: the
        region keeps its current placement and the policy re-plans it next
        window. Bandwidth behaves exactly like tier capacity: a shared
        physical resource the arbiter rations, which is what keeps one
        tenant's migration storm from stealing the PCIe link out from under
        another tenant's swap-ins (the MaxMem contention failure).

        Runs before the capacity reconcile (deferring a move can leave a
        tier overfull, which capacity then resolves); capacity-pass moves
        are therefore not bandwidth-capped — a documented one-pass
        approximation. The committed per-device traffic reported in
        ``ArbiterWindowStats`` is aggregated from the tenants' committed
        plans, so it includes those moves.

        Returns (news, total deferred moves).
        """
        if not self.media_bw_budget_bytes:
            return news, 0

        # Flatten every tenant's moves with their per-device byte bills.
        move_t: List[np.ndarray] = []
        move_r: List[np.ndarray] = []
        move_key: List[np.ndarray] = []
        move_read_dev: List[np.ndarray] = []
        move_write_dev: List[np.ndarray] = []
        move_read_b: List[np.ndarray] = []
        move_write_b: List[np.ndarray] = []
        for t, m in enumerate(self.managers):
            moved = np.where(news[t] != m.placement)[0]
            if moved.size == 0:
                continue
            src = m.placement[moved]
            dst = news[t][moved]
            move_t.append(np.full(moved.size, t, np.int64))
            move_r.append(moved)
            hot = np.asarray(avg_hots[t], dtype=np.float64)[moved]
            move_key.append(self.specs[t].sla_weight * hot)
            names = np.array(m._dev_names)
            move_read_dev.append(names[src])
            move_write_dev.append(names[dst])
            # Bill *wire* bytes: devices with an inline hardware compressor
            # move nominal/ratio bytes for this tenant's data (the same
            # accounting the manager's _media_charges applies; the ratio
            # moves only at window boundaries, so replay bills identically).
            r_ratio = np.array([m.media_ratio.get(n, 1.0) for n in names[src]])
            w_ratio = np.array([m.media_ratio.get(n, 1.0) for n in names[dst]])
            move_read_b.append(m._stored_bytes[src].astype(np.float64) / r_ratio)
            move_write_b.append(m._stored_bytes[dst].astype(np.float64) / w_ratio)
        if not move_t:
            return news, 0

        tenants = np.concatenate(move_t)
        regions = np.concatenate(move_r)
        keys = np.concatenate(move_key)
        rdev = np.concatenate(move_read_dev)
        wdev = np.concatenate(move_write_dev)
        rb = np.concatenate(move_read_b)
        wb = np.concatenate(move_write_b)

        spend: Dict[str, float] = {}
        for i in range(tenants.size):
            spend[rdev[i]] = spend.get(rdev[i], 0.0) + rb[i]
            spend[wdev[i]] = spend.get(wdev[i], 0.0) + wb[i]
        alive = np.ones(tenants.size, bool)
        order = np.lexsort((regions, tenants, keys))  # coldest weighted first
        for dev, budget in self.media_bw_budget_bytes.items():
            # Speculative prefetch already spent part of this device's
            # window budget; only the remainder is available to demand moves.
            budget = max(budget - self._spec_bytes.get(dev, 0.0), 0.0)
            if spend.get(dev, 0.0) <= budget:
                continue
            for i in order:
                if not alive[i]:
                    continue
                if rdev[i] != dev and wdev[i] != dev:
                    continue
                # Defer: undo both of the move's device bills.
                spend[rdev[i]] -= rb[i]
                spend[wdev[i]] -= wb[i]
                alive[i] = False
                news[int(tenants[i])][int(regions[i])] = self.managers[
                    int(tenants[i])
                ].placement[int(regions[i])]
                if spend.get(dev, 0.0) <= budget:
                    break
        return news, int((~alive).sum())

    # ---------------------------------------------------- capacity reconcile
    def _reconcile_capacity(
        self,
        news: List[np.ndarray],
        avg_hots: Sequence[np.ndarray],
        costs: Sequence[np.ndarray],
        floors: Sequence[float],
    ) -> List[np.ndarray]:
        """Enforce shared per-tier region capacities across tenants.

        Overfull tiers shed their cheapest marginal pages — smallest
        ``sla_weight * hotness`` fleet-wide — to the next tier index with
        headroom (denser/cheaper, the demotion direction). Victims whose
        tenant would drop below its SLA floor are skipped while any
        above-floor victim exists; if capacity physically cannot hold every
        floor, the coldest page goes regardless (capacity wins over policy).
        When no deeper tier has headroom the overflow spills *upward* into
        the shallowest-constrained faster tier instead (hottest pages first —
        promotion raises spend, so floors are never at risk); the constructor
        guarantees total capacity holds the fleet, so one direction always
        has room.
        """
        if not np.isfinite(self.capacity_regions).any():
            return news
        sizes = [n.size for n in news]
        pl = np.concatenate(news)
        hot = np.concatenate([np.asarray(h, dtype=np.float64) for h in avg_hots])
        tenant_of = np.concatenate(
            [np.full(sz, t, dtype=np.int64) for t, sz in enumerate(sizes)]
        )
        w = np.array([s.sla_weight for s in self.specs])[tenant_of]
        spend = np.array(
            [float(costs[t][news[t]].sum()) for t in range(len(sizes))]
        )
        counts = np.bincount(pl, minlength=self.n_options).astype(np.float64)
        n_t = len(sizes)
        for k in range(self.n_options):
            over = counts[k] - self.capacity_regions[k]
            if over <= 0:
                continue
            over = int(np.ceil(over))
            idx = np.where(pl == k)[0]
            order = np.lexsort((idx, w[idx] * hot[idx]))  # coldest weighted first
            cand = idx[order]
            while over > 0:
                dst = next(
                    (j for j in range(k + 1, self.n_options)
                     if counts[j] + 1 <= self.capacity_regions[j]),
                    None,
                )
                if dst is None:
                    # No deeper headroom: spill upward to the nearest faster
                    # tier with room (total capacity was validated, so it
                    # exists unless every tier is simultaneously full).
                    dst = next(
                        (j for j in range(k - 1, -1, -1)
                         if counts[j] + 1 <= self.capacity_regions[j]),
                        None,
                    )
                if dst is None or cand.size == 0:
                    raise MemoryError(
                        f"tier capacities cannot absorb overflow from tier {k}"
                    )
                room = int(min(self.capacity_regions[dst] - counts[dst], over))
                delta = np.array(
                    [costs[t][dst] - costs[t][k] for t in range(n_t)]
                )
                if dst > k:
                    # Demotion (one batch per destination tier): each tenant
                    # can shed at most floor((spend - floor) / cost_drop)
                    # pages before breaching its SLA floor; fill the room
                    # coldest-first under those per-tenant quotas.
                    quota = np.where(
                        delta < 0,
                        np.floor((spend - np.asarray(floors) + 1e-12)
                                 / np.maximum(-delta, 1e-30)),
                        np.inf,
                    )
                    take, keep = [], []
                    for r in cand:
                        t = tenant_of[r]
                        if len(take) < room and quota[t] >= 1:
                            quota[t] -= 1
                            take.append(r)
                        else:
                            keep.append(r)
                    if not take:
                        # Every candidate's floor would break: capacity is
                        # physical, so the coldest pages go regardless.
                        take, keep = list(cand[:room]), list(cand[room:])
                    take = np.array(take, dtype=np.int64)
                    cand = np.array(keep, dtype=np.int64)
                else:
                    # Promotion: hottest pages benefit, and spend only
                    # rises, so SLA floors cannot be violated.
                    take = cand[-room:]
                    cand = cand[:-room]
                pl[take] = dst
                np.add.at(spend, tenant_of[take], delta[tenant_of[take]])
                counts[dst] += take.size
                counts[k] -= take.size
                over -= take.size
        out, off = [], 0
        for sz in sizes:
            out.append(pl[off : off + sz])
            off += sz
        return out

    # ------------------------------------------------------------------ views
    def tenant_history(self, name: str) -> List[TenantWindowStats]:
        return [
            ts for ws in self.history for ts in ws.tenants if ts.tenant == name
        ]

    def fleet_report(self, last_windows: Optional[int] = None):
        """Summarize the arbiter's recent history as the ``FleetReport`` the
        capacity planner consumes — the live-telemetry bridge from
        ``ArbiterWindowStats`` + per-tenant ``WindowStats`` to
        "how many servers, which tier mix, at what dollar cost".

        ``last_windows`` restricts the aggregation to the most recent N
        windows (e.g. to drop a simulation's warmup); default is the whole
        history. Per-tenant resident bytes are grouped by each tier's
        backing media device from the managers' committed placement
        histograms, so the planner's bin-packing sees the same bytes the
        byte-level TCO model (Eq. 12) priced.
        """
        from repro.core.capacity import FleetReport

        if not self.history:
            raise ValueError("fleet_report needs at least one closed window")
        hist = self.history[-last_windows:] if last_windows else self.history
        n_w = len(hist)

        bytes_by_dev: List[Dict[str, float]] = []
        for m in self.managers:
            mgr_hist = m.history[-n_w:]
            acc: Dict[str, float] = {}
            for ws in mgr_hist:
                resident = ws.placement_hist * m._stored_bytes
                for i, dev in enumerate(m._dev_names):
                    # Physical occupancy: inline-compressed devices hold
                    # nominal/ratio bytes of this tenant's data, so the
                    # planner's bin-packing sees effective capacity.
                    ratio = m.media_ratio.get(dev, 1.0)
                    acc[dev] = acc.get(dev, 0.0) + float(resident[i]) / ratio
            bytes_by_dev.append({d: b / max(len(mgr_hist), 1) for d, b in acc.items()})

        media: Dict[str, float] = {}
        for ws in hist:
            for dev, b in ws.media_bytes_by_device.items():
                media[dev] = media.get(dev, 0.0) + float(b)
            for dev, b in ws.speculative_bytes_by_device.items():
                media[dev] = media.get(dev, 0.0) + float(b)
        media = {d: b / n_w for d, b in media.items()}

        n_t = len(self.specs)
        if self._sched_demand:
            # Scheduler-measured decode demand wins over the telemetry sum:
            # mean tokens/window per tenant across the recorded frontend
            # windows (same ``last_windows`` trim as the history).
            sched = (
                self._sched_demand[-last_windows:]
                if last_windows else self._sched_demand
            )
            demand = tuple(
                float(np.mean([w.get(s.name, 0.0) for w in sched]))
                for s in self.specs
            )
        else:
            demand = tuple(
                float(np.mean([ws.tenants[t].demand_accesses for ws in hist]))
                for t in range(n_t)
            )
        penalty = tuple(
            float(np.mean([ws.tenants[t].weighted_penalty_s for ws in hist]))
            for t in range(n_t)
        )
        return FleetReport(
            windows=n_w,
            tenant_names=tuple(s.name for s in self.specs),
            tenant_footprint_bytes=tuple(
                float(m.n_regions) * float(m.region_bytes) for m in self.managers
            ),
            tenant_bytes_by_device=tuple(bytes_by_dev),
            tenant_demand_accesses=demand,
            tenant_penalty_s=penalty,
            per_window_penalty_s=np.array(
                [sum(ts.weighted_penalty_s for ts in ws.tenants) for ws in hist]
            ),
            fleet_tco_usd=float(np.mean([ws.fleet_tco_usd for ws in hist])),
            fleet_savings_pct=float(np.mean([ws.fleet_savings_pct for ws in hist])),
            media_bytes_by_device=media,
            budget_feasible_frac=float(np.mean([ws.budget_feasible for ws in hist])),
        )
