"""Trace-driven window simulator — the evaluation substrate for §7.

Drives a TierScapeManager with synthetic region-access traces that mirror the
paper's workloads (Table 3):

  * ``gaussian_kv``  — Memcached/Redis analogue: memtier-style Gaussian key
    popularity with slow center drift,
  * ``rotating_frontier`` — BFS/PageRank analogue: a hot frontier that sweeps
    the graph between windows,
  * ``uniform_scan`` — XSBench analogue: huge footprint, near-uniform random
    lookups.

Per window the simulator
  1. draws ground-truth access counts per region,
  2. charges faults: first access to a compressed region pays the tier's
     access latency (Eq. 3-5) and returns the region to DRAM,
  3. feeds (possibly PEBS-noised) counts to the manager,
  4. runs the placement model and executes the migration plan,
  5. records performance overhead, TCO, latency distribution and daemon tax.

Performance metric: relative slowdown = fault_overhead / base_runtime per
window, where base_runtime = accesses * DRAM service time + workload compute
time — matching the paper's "perf w.r.t. all-DRAM" axis in Fig. 8.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core import hw
from repro.core.manager import TierScapeManager

# Service time for an access that hits uncompressed HBM/DRAM (block-granular
# engine access, not a single cache line).
DRAM_ACCESS_US = 0.5


@dataclasses.dataclass
class Workload:
    name: str
    n_regions: int
    accesses_per_window: int
    # compute seconds per window spent off the memory path (so slowdown
    # percentages land in a realistic range, like the paper's benchmarks).
    compute_s_per_window: float
    sampler: Callable[[int, np.random.Generator], np.ndarray]

    def sample_window(self, w: int, rng: np.random.Generator) -> np.ndarray:
        counts = self.sampler(w, rng)
        assert counts.shape == (self.n_regions,)
        return counts


def gaussian_kv(
    n_regions: int = 4096,
    accesses_per_window: int = 2_000_000,
    sigma_frac: float = 0.08,
    drift_frac: float = 0.01,
    compute_s_per_window: float = 1.0,
    name: str = "memcached",
) -> Workload:
    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        center = (0.5 + drift_frac * w) % 1.0
        keys = rng.normal(center, sigma_frac, size=accesses_per_window)
        idx = (np.mod(keys, 1.0) * n_regions).astype(np.int64)
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, accesses_per_window, compute_s_per_window, sampler)


def rotating_frontier(
    n_regions: int = 4096,
    accesses_per_window: int = 2_000_000,
    frontier_frac: float = 0.15,
    advance_frac: float = 0.05,
    background_frac: float = 0.10,
    compute_s_per_window: float = 1.0,
    name: str = "bfs",
) -> Workload:
    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        start = int(w * advance_frac * n_regions) % n_regions
        width = max(int(frontier_frac * n_regions), 1)
        hot = (start + rng.integers(0, width, size=int(accesses_per_window * (1 - background_frac)))) % n_regions
        bg = rng.integers(0, n_regions, size=int(accesses_per_window * background_frac))
        idx = np.concatenate([hot, bg])
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, accesses_per_window, compute_s_per_window, sampler)


def uniform_scan(
    n_regions: int = 16384,
    accesses_per_window: int = 2_000_000,
    compute_s_per_window: float = 2.0,
    name: str = "xsbench",
) -> Workload:
    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        idx = rng.integers(0, n_regions, size=accesses_per_window)
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, accesses_per_window, compute_s_per_window, sampler)


PAPER_WORKLOADS: Callable[[], List[Workload]] = lambda: [
    gaussian_kv(name="memcached", sigma_frac=0.08),
    gaussian_kv(name="redis", sigma_frac=0.12, drift_frac=0.02),
    rotating_frontier(name="bfs", advance_frac=0.08),
    rotating_frontier(name="pagerank", advance_frac=0.02, frontier_frac=0.25),
    uniform_scan(name="xsbench"),
]


@dataclasses.dataclass
class SimResult:
    workload: str
    config: str
    windows: int
    slowdown_pct: float  # mean relative slowdown vs all-DRAM
    tco_savings_pct: float  # mean memory TCO savings
    mean_access_us: float
    p99_access_us: float
    daemon_tax_pct: float  # daemon time / total runtime
    mean_migrations_per_window: float
    mean_cohorts_per_window: float  # batched executor: dispatches per window
    per_window_savings: np.ndarray
    per_window_slowdown: np.ndarray
    placement_hists: np.ndarray  # (W, N+1)
    fault_hists: np.ndarray  # (W, N+1) faults per source placement


def simulate(
    workload: Workload,
    manager: TierScapeManager,
    windows: int = 40,
    warmup_windows: int = 2,
    seed: int = 0,
) -> SimResult:
    rng = np.random.default_rng(seed)
    n = workload.n_regions
    assert manager.n_regions == n

    slowdowns, savings = [], []
    placement_hists, fault_hists = [], []
    # Latency histogram support: DRAM hits + one bucket per placement index
    # (block-granular fault latency — the paper's per-page fault cost).
    blk_lat_us = np.array(manager.tierset.latencies_s()) * 1e6
    lat_support_us = np.concatenate([[DRAM_ACCESS_US], blk_lat_us[1:]])
    lat_counts = np.zeros_like(lat_support_us)
    bpr = manager.blocks_per_region

    for w in range(windows):
        counts = workload.sample_window(w, rng)
        placement_before = manager.placement.copy()

        # --- ground truth fault accounting (engine side) -------------------
        # A compressed region accessed k times faults its distinct blocks on
        # demand: E[distinct blocks among k uniform accesses of B blocks] =
        # B * (1 - (1 - 1/B)^k)  (4KB-page faults within the 2MB region).
        compressed = placement_before > 0
        faulted = (counts > 0) & compressed
        fault_ids = np.where(faulted)[0]
        k = counts[fault_ids]
        n_blocks = bpr * (1.0 - (1.0 - 1.0 / bpr) ** k)
        fault_src = placement_before[fault_ids]
        fault_lat_s = manager.fault_back(fault_ids, n_blocks)
        fault_overhead_s = float(fault_lat_s.sum())

        # Latency distribution: each faulted block pays its tier's fault
        # latency; every other access is a DRAM hit.
        lat_counts[0] += counts.sum() - n_blocks.sum()
        fault_hist = np.zeros(manager.tierset.n_tiers + 1)
        np.add.at(fault_hist, fault_src, n_blocks)
        lat_counts[1:] += fault_hist[1:]
        fault_hists.append(fault_hist)

        # --- telemetry + model ---------------------------------------------
        manager.record_access_counts(counts)
        manager.end_window()

        base_s = workload.compute_s_per_window + counts.sum() * DRAM_ACCESS_US * 1e-6
        if w >= warmup_windows:
            slowdowns.append(100.0 * fault_overhead_s / base_s)
            savings.append(manager.history[-1].savings_pct)
        placement_hists.append(manager.history[-1].placement_hist)

    # Percentiles from the latency histogram.
    order = np.argsort(lat_support_us)
    cdf = np.cumsum(lat_counts[order]) / max(lat_counts.sum(), 1)
    mean_us = float((lat_support_us * lat_counts).sum() / max(lat_counts.sum(), 1))
    p99_us = float(lat_support_us[order][np.searchsorted(cdf, 0.99)])

    total_base = windows * (
        workload.compute_s_per_window
        + workload.accesses_per_window * DRAM_ACCESS_US * 1e-6
    )
    return SimResult(
        workload=workload.name,
        config=f"{manager.cfg.policy}",
        windows=windows,
        slowdown_pct=float(np.mean(slowdowns)) if slowdowns else 0.0,
        tco_savings_pct=float(np.mean(savings)) if savings else 0.0,
        mean_access_us=mean_us,
        p99_access_us=p99_us,
        daemon_tax_pct=100.0 * manager.total_daemon_s / total_base,
        mean_migrations_per_window=float(
            np.mean([h.migrations for h in manager.history])
        ),
        mean_cohorts_per_window=float(
            np.mean([h.migration_cohorts for h in manager.history])
        ),
        per_window_savings=np.array(savings),
        per_window_slowdown=np.array(slowdowns),
        placement_hists=np.stack(placement_hists),
        fault_hists=np.stack(fault_hists),
    )
