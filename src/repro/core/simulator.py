"""Trace-driven window simulator — the evaluation substrate for §7.

Drives a TierScapeManager with synthetic region-access traces that mirror the
paper's workloads (Table 3):

  * ``gaussian_kv``  — Memcached/Redis analogue: memtier-style Gaussian key
    popularity with slow center drift,
  * ``rotating_frontier`` — BFS/PageRank analogue: a hot frontier that sweeps
    the graph between windows,
  * ``uniform_scan`` — XSBench analogue: huge footprint, near-uniform random
    lookups.

Per window the simulator
  1. draws ground-truth access counts per region,
  2. charges faults: first access to a compressed region pays the tier's
     access latency (Eq. 3-5) and returns the region to DRAM,
  3. feeds (possibly PEBS-noised) counts to the manager,
  4. runs the placement model and executes the migration plan,
  5. records performance overhead, TCO, latency distribution and daemon tax.

Performance metric: relative slowdown = fault_overhead / base_runtime per
window, where base_runtime = accesses * DRAM service time + workload compute
time — matching the paper's "perf w.r.t. all-DRAM" axis in Fig. 8.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Dict, List

import numpy as np

from repro.core.manager import TierScapeManager

if TYPE_CHECKING:  # runtime import is deferred: repro.media imports
    from repro.media.devices import MediaQueue  # repro.core back (hw)

# Service time for an access that hits uncompressed HBM/DRAM (block-granular
# engine access, not a single cache line).
DRAM_ACCESS_US = 0.5


@dataclasses.dataclass
class Workload:
    name: str
    n_regions: int
    accesses_per_window: int
    # compute seconds per window spent off the memory path (so slowdown
    # percentages land in a realistic range, like the paper's benchmarks).
    compute_s_per_window: float
    sampler: Callable[[int, np.random.Generator], np.ndarray]
    # Observed line-compression ratio of this tenant's data on
    # inline-compressed media (nominal bytes / wire bytes, in [1, 2] for the
    # cxl_hw line codec). 1.0 = incompressible. Benchmarks measure this from
    # real encoded payloads (codecs.cxl_line_ratio) and bake it into the
    # workload; the simulator feeds it to the adaptive devices and managers
    # at window boundaries only.
    line_ratio: float = 1.0

    def sample_window(self, w: int, rng: np.random.Generator) -> np.ndarray:
        counts = self.sampler(w, rng)
        assert counts.shape == (self.n_regions,)
        return counts


def gaussian_kv(
    n_regions: int = 4096,
    accesses_per_window: int = 2_000_000,
    sigma_frac: float = 0.08,
    drift_frac: float = 0.01,
    compute_s_per_window: float = 1.0,
    name: str = "memcached",
) -> Workload:
    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        center = (0.5 + drift_frac * w) % 1.0
        keys = rng.normal(center, sigma_frac, size=accesses_per_window)
        idx = (np.mod(keys, 1.0) * n_regions).astype(np.int64)
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, accesses_per_window, compute_s_per_window, sampler)


def rotating_frontier(
    n_regions: int = 4096,
    accesses_per_window: int = 2_000_000,
    frontier_frac: float = 0.15,
    advance_frac: float = 0.05,
    background_frac: float = 0.10,
    compute_s_per_window: float = 1.0,
    name: str = "bfs",
) -> Workload:
    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        start = int(w * advance_frac * n_regions) % n_regions
        width = max(int(frontier_frac * n_regions), 1)
        hot = (start + rng.integers(0, width, size=int(accesses_per_window * (1 - background_frac)))) % n_regions
        bg = rng.integers(0, n_regions, size=int(accesses_per_window * background_frac))
        idx = np.concatenate([hot, bg])
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, accesses_per_window, compute_s_per_window, sampler)


def uniform_scan(
    n_regions: int = 16384,
    accesses_per_window: int = 2_000_000,
    compute_s_per_window: float = 2.0,
    name: str = "xsbench",
) -> Workload:
    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        idx = rng.integers(0, n_regions, size=accesses_per_window)
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, accesses_per_window, compute_s_per_window, sampler)


def bursty_kv(
    n_regions: int = 4096,
    accesses_per_window: int = 2_000_000,
    burst_every: int = 8,
    burst_windows: int = 2,
    burst_mult: float = 6.0,
    sigma_frac: float = 0.10,
    compute_s_per_window: float = 1.0,
    name: str = "bursty",
) -> Workload:
    """Bursty tenant: Gaussian popularity whose traffic multiplies by
    ``burst_mult`` for ``burst_windows`` windows out of every ``burst_every``
    (flash-crowd analogue). The arbiter should hand it fast-tier budget
    during bursts and reclaim it between them."""

    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        mult = burst_mult if (w % burst_every) < burst_windows else 1.0
        n_acc = int(accesses_per_window * mult)
        keys = rng.normal(0.5, sigma_frac, size=n_acc)
        idx = (np.mod(keys, 1.0) * n_regions).astype(np.int64)
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, accesses_per_window, compute_s_per_window, sampler)


def skew_flip(
    n_regions: int = 4096,
    accesses_hot: int = 2_000_000,
    accesses_cold: int = 200_000,
    flip_window: int = 20,
    hot_first: bool = True,
    sigma_frac: float = 0.08,
    compute_s_per_window: float = 1.0,
    name: str = "skewflip",
) -> Workload:
    """Skew-flip tenant: hot Gaussian traffic before ``flip_window``, near-idle
    uniform traffic after (or the reverse with ``hot_first=False``). Two such
    tenants with opposite phase model a mid-run skew flip between tenants."""

    def sampler(w: int, rng: np.random.Generator) -> np.ndarray:
        hot_phase = (w < flip_window) == hot_first
        if hot_phase:
            keys = rng.normal(0.5, sigma_frac, size=accesses_hot)
            idx = (np.mod(keys, 1.0) * n_regions).astype(np.int64)
        else:
            idx = rng.integers(0, n_regions, size=accesses_cold)
        return np.bincount(idx, minlength=n_regions).astype(np.float64)

    return Workload(name, n_regions, max(accesses_hot, accesses_cold),
                    compute_s_per_window, sampler)


PAPER_WORKLOADS: Callable[[], List[Workload]] = lambda: [
    gaussian_kv(name="memcached", sigma_frac=0.08),
    gaussian_kv(name="redis", sigma_frac=0.12, drift_frac=0.02),
    rotating_frontier(name="bfs", advance_frac=0.08),
    rotating_frontier(name="pagerank", advance_frac=0.02, frontier_frac=0.25),
    uniform_scan(name="xsbench"),
]


@dataclasses.dataclass
class SimResult:
    workload: str
    config: str
    windows: int
    slowdown_pct: float  # mean relative slowdown vs all-DRAM
    tco_savings_pct: float  # mean memory TCO savings
    mean_access_us: float
    p99_access_us: float
    daemon_tax_pct: float  # daemon time / total runtime
    mean_migrations_per_window: float
    mean_cohorts_per_window: float  # batched executor: dispatches per window
    # Backing-media replay: migration traffic queued through each device's
    # bandwidth/queue-depth model over the whole run.
    media_bytes_by_device: Dict[str, int]
    media_busy_s_by_device: Dict[str, float]
    media_queue_wait_s: float  # time plans spent waiting on busy channels
    per_window_savings: np.ndarray
    per_window_slowdown: np.ndarray
    placement_hists: np.ndarray  # (W, N+1)
    fault_hists: np.ndarray  # (W, N+1) faults per source placement
    # Speculative prefetch replay (``simulate(prefetch=True)``): regions
    # staged ahead that were / were not touched next window, and the
    # speculative bytes billed to the media queues (mispredictions included).
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    prefetch_bytes: int = 0


def charge_window_faults(
    manager: TierScapeManager, counts: np.ndarray, free_mask=None
) -> tuple[float, np.ndarray, np.ndarray]:
    """Ground-truth fault accounting for one window (engine side).

    A compressed region accessed k times faults its distinct blocks on
    demand: E[distinct blocks among k uniform accesses of B blocks] =
    B * (1 - (1 - 1/B)^k)  (4KB-page faults within the 2MB region).
    Returns (fault_overhead_s, per-placement fault histogram, n_blocks).

    ``free_mask`` marks regions whose fault *latency* was hidden (their
    swap-in was prefetched ahead of the first touch): every piece of fault
    bookkeeping — counts, histogram, the refault move back to DRAM — runs
    identically to a prefetch-free window, so placement trajectories and
    migration billing never diverge; only the stall is refunded.
    """
    bpr = manager.blocks_per_region
    placement_before = manager.placement.copy()
    faulted = (counts > 0) & (placement_before > 0)
    fault_ids = np.where(faulted)[0]
    k = counts[fault_ids]
    n_blocks = bpr * (1.0 - (1.0 - 1.0 / bpr) ** k)
    fault_src = placement_before[fault_ids]
    fault_lat_s = manager.fault_back(fault_ids, n_blocks)
    fault_hist = np.zeros(manager.tierset.n_tiers + 1)
    np.add.at(fault_hist, fault_src, n_blocks)
    overhead = float(fault_lat_s.sum())
    if free_mask is not None:
        hidden = float(fault_lat_s[free_mask[fault_ids]].sum())
        manager.discount_fault_overhead(hidden)
        overhead -= hidden
    return overhead, fault_hist, n_blocks


def replay_plan_media(
    manager: TierScapeManager,
    queues: Dict[str, MediaQueue],
    now_s: float,
    price_contention: bool = False,
    window_s: float = 1.0,
) -> None:
    """Replay the last window's migration plan through the media queues.

    Each device's share of the plan (bytes billed by ``manager._plan``) is
    submitted at the window's virtual timestamp, so queue-depth contention
    across windows (and across tenants sharing ``queues``) accumulates in
    ``busy_s``/``queue_wait_s`` deterministically. ``price_contention``
    additionally feeds the executed busy time back into the manager so the
    next window's placement prices the contention.
    """
    ws = manager.history[-1]
    for name, n_bytes in ws.media_bytes_by_device.items():
        queues[name].submit(n_bytes, now=now_s, ops=max(ws.migration_cohorts, 1))
    if price_contention:
        manager.note_media_charges(ws.media_s_by_device, window_s)


def _feed_adaptive_media(managers, workloads, media_queues) -> None:
    """Window-boundary compressibility feedback for adaptive media devices.

    For every inline-compressed device in the shared queue set: observe each
    tenant's resident nominal-vs-wire bytes (weighted by what is actually
    placed there), fold the shared device EWMA once (``commit_window`` — the
    only point the effective bandwidth may move), and update each manager's
    own wire-ratio view plus the measured ratio of its tiers backed by that
    device (effective-capacity pricing in Eq. 9-12). Called strictly at
    window boundaries so in-window service times are replay-deterministic.
    """
    from repro.media.devices import adaptive_devices

    adaptive = adaptive_devices(media_queues)
    if not adaptive:
        return
    for m, wl in zip(managers, workloads):
        ratio = max(float(getattr(wl, "line_ratio", 1.0)), 1.0)
        nominal_ratios = m.tierset.ratios()
        for i, dev in enumerate(m._dev_names):
            if dev not in adaptive:
                continue
            if m.history:
                resident = float(m.history[-1].placement_hist[i]) * float(
                    m._stored_bytes[i]
                )
                if resident > 0:
                    adaptive[dev].observe(resident, resident / ratio)
            m.note_media_ratio(dev, ratio)
            if i >= 1:
                m.update_measured_ratio(i, nominal_ratios[i] * ratio)
    for dev in adaptive.values():
        dev.commit_window()


def _prefetch_consume(staged: np.ndarray, counts: np.ndarray):
    """Window start: resolve last window's speculative staging against the
    ground-truth accesses. Clears ``staged`` and returns (free_mask for
    ``charge_window_faults`` — hits whose fault latency was hidden —
    n_hits, n_misses)."""
    hit = staged & (counts > 0)
    hits = int(hit.sum())
    misses = int((staged & ~hit).sum())
    staged[:] = False
    return hit, hits, misses


def _prefetch_stage(
    manager: TierScapeManager,
    staged: np.ndarray,
    media_queues: Dict[str, "MediaQueue"],
    now_s: float,
    max_regions: int,
) -> Dict[str, float]:
    """Mid-window (telemetry recorded, window not yet closed): flag warming
    compressed regions and bill their speculative reads to each region's
    backing device immediately — spent whether or not the prediction lands,
    so mispredictions cannot vanish from the report. The frontier is the
    current uncompressed (fast) set's size: a region qualifies when its
    projected hotness would rank it inside that set next window. Returns
    the per-device speculative bytes billed."""
    cand = manager.prefetch_candidates(
        manager.placement > 0,
        top_k=max(int((manager.placement == 0).sum()), 1),
        max_regions=max_regions,
    )
    out: Dict[str, float] = {}
    if cand.size:
        staged[cand] = True
        src = manager.placement[cand]
        for lvl in np.unique(src):
            sel = src == lvl
            nb = int(manager._stored_bytes[lvl]) * int(sel.sum())
            dev = manager._dev_names[lvl]
            media_queues[dev].submit(nb, now=now_s, ops=int(sel.sum()))
            out[dev] = out.get(dev, 0.0) + nb
    return out


def simulate(
    workload: Workload,
    manager: TierScapeManager,
    windows: int = 40,
    warmup_windows: int = 2,
    seed: int = 0,
    price_media_contention: bool = False,
    prefetch: bool = False,
    prefetch_max_regions: int = 64,
) -> SimResult:
    """``prefetch=True`` replays speculative readahead: mid-window, the
    warming-page predictor flags compressed regions and their speculative
    reads are billed to the media queues immediately (mispredictions
    included). A staged region touched next window pays no fault *latency*
    — the swap-in already happened — but every piece of fault bookkeeping
    runs unchanged, so placement trajectories, plans and migration billing
    are identical to a prefetch-free run; only the stall disappears and the
    speculative read traffic appears."""
    from repro.media.devices import make_queues

    rng = np.random.default_rng(seed)
    n = workload.n_regions
    assert manager.n_regions == n
    # Backing-media replay: one queue per distinct device in the tierset.
    media_queues = make_queues(d.name for d in manager.tierset.media_devices())
    staged = np.zeros(n, bool)
    prefetch_hits = prefetch_misses = prefetch_bytes = 0

    slowdowns, savings = [], []
    placement_hists, fault_hists = [], []
    # Latency histogram support: DRAM hits + one bucket per placement index
    # (block-granular fault latency — the paper's per-page fault cost).
    blk_lat_us = np.array(manager.tierset.latencies_s()) * 1e6
    lat_support_us = np.concatenate([[DRAM_ACCESS_US], blk_lat_us[1:]])
    lat_counts = np.zeros_like(lat_support_us)

    for w in range(windows):
        counts = workload.sample_window(w, rng)
        free_mask = None
        if prefetch and staged.any():
            # A hit's swap-in was prefetched mid-window: its fault latency
            # is hidden, but all fault bookkeeping (and so the placement
            # trajectory and migration billing) stays bit-identical to a
            # prefetch-free run.
            free_mask, h, m_ = _prefetch_consume(staged, counts)
            prefetch_hits += h
            prefetch_misses += m_
        fault_overhead_s, fault_hist, n_blocks = charge_window_faults(
            manager, counts, free_mask=free_mask
        )

        # Latency distribution: each faulted block pays its tier's fault
        # latency; every other access is a DRAM hit.
        lat_counts[0] += counts.sum() - n_blocks.sum()
        lat_counts[1:] += fault_hist[1:]
        fault_hists.append(fault_hist)

        # --- telemetry + model ---------------------------------------------
        base_s = workload.compute_s_per_window + counts.sum() * DRAM_ACCESS_US * 1e-6
        manager.record_access_counts(counts)
        if prefetch:
            prefetch_bytes += int(sum(
                _prefetch_stage(
                    manager, staged, media_queues, w * base_s,
                    prefetch_max_regions,
                ).values()
            ))
        manager.end_window()

        replay_plan_media(
            manager, media_queues, now_s=w * base_s,
            price_contention=price_media_contention, window_s=base_s,
        )
        _feed_adaptive_media([manager], [workload], media_queues)
        if w >= warmup_windows:
            slowdowns.append(100.0 * fault_overhead_s / base_s)
            savings.append(manager.history[-1].savings_pct)
        placement_hists.append(manager.history[-1].placement_hist)

    # Percentiles from the latency histogram.
    order = np.argsort(lat_support_us)
    cdf = np.cumsum(lat_counts[order]) / max(lat_counts.sum(), 1)
    mean_us = float((lat_support_us * lat_counts).sum() / max(lat_counts.sum(), 1))
    p99_us = float(lat_support_us[order][np.searchsorted(cdf, 0.99)])

    total_base = windows * (
        workload.compute_s_per_window
        + workload.accesses_per_window * DRAM_ACCESS_US * 1e-6
    )
    return SimResult(
        workload=workload.name,
        config=f"{manager.cfg.policy}",
        windows=windows,
        slowdown_pct=float(np.mean(slowdowns)) if slowdowns else 0.0,
        tco_savings_pct=float(np.mean(savings)) if savings else 0.0,
        mean_access_us=mean_us,
        p99_access_us=p99_us,
        daemon_tax_pct=100.0 * manager.total_daemon_s / total_base,
        mean_migrations_per_window=float(
            np.mean([h.migrations for h in manager.history])
        ),
        mean_cohorts_per_window=float(
            np.mean([h.migration_cohorts for h in manager.history])
        ),
        media_bytes_by_device={
            n_: q.bytes_total for n_, q in media_queues.items() if q.ops
        },
        media_busy_s_by_device={
            n_: q.busy_s for n_, q in media_queues.items() if q.ops
        },
        media_queue_wait_s=float(
            sum(q.queue_wait_s for q in media_queues.values())
        ),
        per_window_savings=np.array(savings),
        per_window_slowdown=np.array(slowdowns),
        placement_hists=np.stack(placement_hists),
        fault_hists=np.stack(fault_hists),
        prefetch_hits=prefetch_hits,
        prefetch_misses=prefetch_misses,
        prefetch_bytes=prefetch_bytes,
    )


# ---------------------------------------------------------------------------
# Multi-tenant simulation: N workloads, one manager each, shared substrate
# under a BudgetArbiter (paper §8 direction).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TenantSimStats:
    tenant: str
    workload: str
    slowdown_pct: float  # mean relative slowdown vs all-DRAM (post-warmup)
    tco_savings_pct: float
    mean_fast_regions: float  # mean regions resident uncompressed
    mean_budget_usd: float  # mean arbiter-allotted budget
    # All per-window arrays cover the same post-warmup windows, aligned
    # index-for-index: shape (windows - warmup_windows,).
    per_window_fast: np.ndarray
    per_window_budget: np.ndarray
    per_window_savings: np.ndarray
    per_window_slowdown: np.ndarray


@dataclasses.dataclass
class MultiTenantSimResult:
    windows: int
    fleet_savings_pct: float  # mean aggregate TCO savings (post-warmup)
    fleet_tco_usd: float  # mean aggregate TCO (post-warmup, this run only)
    budget_feasible_frac: float  # this run's windows where floors fit the budget
    tenants: List["TenantSimStats"]
    per_window_fleet_savings: np.ndarray
    # Shared backing-media replay: all tenants' migration traffic queued
    # through ONE set of device queues (the contention the arbiter prices).
    media_bytes_by_device: Dict[str, int] = dataclasses.field(default_factory=dict)
    media_busy_s_by_device: Dict[str, float] = dataclasses.field(default_factory=dict)
    media_queue_wait_s: float = 0.0
    # Fleet-wide speculative prefetch replay (``prefetch=True``): the bytes
    # are also reported to the arbiter per window, consuming its per-device
    # bandwidth budgets before demand moves are considered.
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    prefetch_bytes: int = 0


def simulate_multitenant(
    workloads: List[Workload],
    arbiter,
    windows: int = 40,
    warmup_windows: int = 2,
    seed: int = 0,
    prefetch: bool = False,
    prefetch_max_regions: int = 64,
) -> MultiTenantSimResult:
    """Drive N tenant workloads against one BudgetArbiter.

    Per window, each tenant samples its trace, charges faults against its own
    manager and records telemetry; the arbiter then closes every tenant's
    window at once — waterfilling budgets, reconciling shared-pool capacity
    and committing every placement.

    ``prefetch=True`` replays per-tenant speculative readahead with the same
    placement-neutral semantics as ``simulate``; the fleet's speculative
    bytes are additionally reported to the arbiter via
    ``record_speculative_bytes`` each window, so speculation consumes the
    shared per-device bandwidth budgets before demand moves are considered.
    """
    from repro.media.devices import make_queues

    specs, managers = arbiter.specs, arbiter.managers
    assert len(workloads) == len(managers)
    for wl, m in zip(workloads, managers):
        assert m.n_regions == wl.n_regions
    rngs = [np.random.default_rng(seed + 17 * t) for t in range(len(workloads))]
    # One shared queue set: tenants contend for the same physical devices
    # (union across tiersets — tenants may bind tiers to different devices).
    media_queues = make_queues(
        d.name for m in managers for d in m.tierset.media_devices()
    )

    t_slow: List[List[float]] = [[] for _ in workloads]
    t_save: List[List[float]] = [[] for _ in workloads]
    t_fast: List[List[int]] = [[] for _ in workloads]
    t_budget: List[List[float]] = [[] for _ in workloads]
    fleet_save: List[float] = []
    staged = [np.zeros(wl.n_regions, bool) for wl in workloads]
    prefetch_hits = prefetch_misses = prefetch_bytes = 0

    for w in range(windows):
        overheads = []
        spec_bytes: Dict[str, float] = {}
        for t, (wl, m) in enumerate(zip(workloads, managers)):
            counts = wl.sample_window(w, rngs[t])
            free_mask = None
            if prefetch and staged[t].any():
                free_mask, h, m_ = _prefetch_consume(staged[t], counts)
                prefetch_hits += h
                prefetch_misses += m_
            fault_overhead_s, _, _ = charge_window_faults(
                m, counts, free_mask=free_mask
            )
            m.record_access_counts(counts)
            base_s = wl.compute_s_per_window + counts.sum() * DRAM_ACCESS_US * 1e-6
            overheads.append(100.0 * fault_overhead_s / base_s)
            if prefetch:
                for dev, nb in _prefetch_stage(
                    m, staged[t], media_queues, float(w), prefetch_max_regions
                ).items():
                    spec_bytes[dev] = spec_bytes.get(dev, 0.0) + nb
                    prefetch_bytes += int(nb)
        if spec_bytes:
            arbiter.record_speculative_bytes(spec_bytes)
        arbiter.end_window()
        for m in managers:
            replay_plan_media(m, media_queues, now_s=float(w))
        _feed_adaptive_media(managers, workloads, media_queues)
        ws = arbiter.history[-1]
        if w >= warmup_windows:
            fleet_save.append(ws.fleet_savings_pct)
            for t, ts in enumerate(ws.tenants):
                t_slow[t].append(overheads[t])
                t_save[t].append(ts.savings_pct)
                t_fast[t].append(ts.fast_regions)
                t_budget[t].append(ts.budget_usd)

    tenants = [
        TenantSimStats(
            tenant=specs[t].name,
            workload=workloads[t].name,
            slowdown_pct=float(np.mean(t_slow[t])) if t_slow[t] else 0.0,
            tco_savings_pct=float(np.mean(t_save[t])) if t_save[t] else 0.0,
            mean_fast_regions=float(np.mean(t_fast[t])) if t_fast[t] else 0.0,
            mean_budget_usd=float(np.mean(t_budget[t])) if t_budget[t] else 0.0,
            per_window_fast=np.array(t_fast[t], dtype=np.float64),
            per_window_budget=np.array(t_budget[t]),
            per_window_savings=np.array(t_save[t]),
            per_window_slowdown=np.array(t_slow[t]),
        )
        for t in range(len(workloads))
    ]
    return MultiTenantSimResult(
        windows=windows,
        fleet_savings_pct=float(np.mean(fleet_save)) if fleet_save else 0.0,
        # Restrict aggregates to THIS run's windows (the arbiter may carry
        # history from earlier runs), with the same warmup cut as savings.
        fleet_tco_usd=float(np.mean(
            [h.fleet_tco_usd for h in arbiter.history[-windows:][warmup_windows:]]
        )) if windows > warmup_windows else 0.0,
        budget_feasible_frac=float(np.mean(
            [h.budget_feasible for h in arbiter.history[-windows:]]
        )),
        tenants=tenants,
        per_window_fleet_savings=np.array(fleet_save),
        media_bytes_by_device={
            n_: q.bytes_total for n_, q in media_queues.items() if q.ops
        },
        media_busy_s_by_device={
            n_: q.busy_s for n_, q in media_queues.items() if q.ops
        },
        media_queue_wait_s=float(sum(q.queue_wait_s for q in media_queues.values())),
        prefetch_hits=prefetch_hits,
        prefetch_misses=prefetch_misses,
        prefetch_bytes=prefetch_bytes,
    )


def simulate_single_tenant_baseline(
    workloads: List[Workload],
    manager: TierScapeManager,
    windows: int = 40,
    warmup_windows: int = 2,
    seed: int = 0,
) -> float:
    """Mean post-warmup TCO savings of ONE manager over the concatenated
    region space of all workloads — the no-tenant-split reference that
    ``simulate_multitenant`` results are compared against. Uses the same
    per-tenant trace streams (``default_rng(seed + 17*t)``) so the two runs
    see identical ground-truth accesses.
    """
    assert manager.n_regions == sum(wl.n_regions for wl in workloads)
    rngs = [np.random.default_rng(seed + 17 * t) for t in range(len(workloads))]
    saves = []
    for w in range(windows):
        counts = np.concatenate(
            [wl.sample_window(w, rngs[t]) for t, wl in enumerate(workloads)]
        )
        charge_window_faults(manager, counts)
        manager.record_access_counts(counts)
        manager.end_window()
        if w >= warmup_windows:
            saves.append(manager.history[-1].savings_pct)
    return float(np.mean(saves)) if saves else 0.0
