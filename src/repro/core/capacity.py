"""Fleet-level TCO capacity planner — the paper's headline metric, priced.

The paper's claim is dollars, not microseconds: multiple software-defined
compressed tiers buy 22-40 points of memory-TCO savings at performance
parity (§1, Eq. 9-12). ``core/tco.py`` prices bytes-in-tiers; this module
closes the loop to "how many servers, which tier mix, at what amortized
dollar cost" for a whole fleet:

  * ``ServerSpec`` — a server-level cost model in the spirit of the classic
    private-cloud cost models: purchase + deployment + annual maintenance +
    rack space + power, amortized over a configurable operating period, plus
    the capacity vector a server contributes (HBM / host DRAM / CXL / NVMe
    bytes, decode throughput, per-device migration bandwidth).
  * ``FleetReport`` — the live multi-tenant telemetry summary the planner
    consumes, produced by ``BudgetArbiter.fleet_report()`` from
    ``ArbiterWindowStats`` + per-tenant ``WindowStats``: per-tenant resident
    bytes by backing device, decode demand, latency-penalty distribution,
    fleet TCO, migration traffic per device.
  * ``CapacityPlanner`` — bin-packs tenant footprints + decode-throughput
    demand onto servers (first-fit decreasing over the multi-dimensional
    capacity vector, deterministic), prices the packed fleet against an
    all-DRAM-provisioned reference fleet of the same server spec, and
    searches tier configurations (codec split via ``warm_bits``/
    ``cold_bits``, fast-tier capacity fraction, arbiter ``alpha``, 2T vs 6T
    family) to emit a Pareto frontier of perf-per-dollar points.

Every step is pure numpy + integer arithmetic over a seeded simulation, so
a sweep is bit-reproducible: the same grid on the same seed emits the same
frontier JSON byte-for-byte — the property the CI guard
(``benchmarks/baseline_guard.check_capacity_frontier``) asserts.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.manager import ManagerConfig, TierScapeManager, make_manager

GIB = 1024**3

# Demand/capacity dimension keys: "mem:<device>" is resident bytes on a
# backing device, "bw:<device>" is migration bytes per window through it,
# "decode" is access throughput (accesses per window).
MEM = "mem:"
BW = "bw:"
DECODE = "decode"


def _r(x: float) -> float:
    """Round to 12 significant digits for stable, readable JSON."""
    return float(f"{float(x):.12g}")


# ---------------------------------------------------------------------------
# Server cost + capacity model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """One purchasable server configuration and its amortized cost.

    Costs are in the same relative USD units as ``hw.CostSpec`` (only
    ratios matter). The amortization follows the private-cloud cost model
    shape: purchase is paid once, maintenance is a yearly percentage of
    purchase, rack and power accrue per operating year.
    """

    name: str
    # Memory capacity contributed per server, by backing-media device.
    hbm_gb: float
    host_dram_gb: float
    cxl_gb: float = 0.0
    # Hardware-compressed CXL expander (inline line compressor): capacity is
    # RAW media GB — the planner packs *physical* occupancy (fleet_report
    # already divides resident bytes by the observed line ratio), so the
    # compressor's effective-capacity multiplier shows up on the demand
    # side, not as a fudge here.
    cxl_hw_gb: float = 0.0
    nvme_gb: float = 0.0
    # Decode throughput one server sustains (accesses per profile window —
    # the simulator's demand unit).
    decode_accesses_per_window: float = 8e6
    # Migration-bandwidth budgets per profile window (bytes) through each
    # backing device one server carries; the shared resources the arbiter
    # rations fleet-wide. The HBM budget is the migration share only —
    # decode traffic owns the rest of the link.
    pcie_window_bytes: float = 25e9
    hbm_window_bytes: float = 100e9
    cxl_window_bytes: float = 48e9
    cxl_hw_window_bytes: float = 48e9
    nvme_window_bytes: float = 5e9
    # Dollars (relative units, hw.CostSpec scale).
    base_usd: float = 1900.0  # chassis + CPU + accelerator, memory excluded
    deployment_usd: float = 100.0
    annual_maintenance_pct: float = 10.0
    rack_usd_per_year: float = 120.0
    power_kw: float = 0.6
    usd_per_kwh: float = 0.02

    def purchase_usd(self) -> float:
        """Server purchase price: base + memory at the tco.py $/GB scale."""
        from repro.core import hw

        return (
            self.base_usd
            + self.hbm_gb * hw.COSTS.usd_per_gb_hbm
            + self.host_dram_gb * hw.COSTS.usd_per_gb_host
            # CXL-attached and NVMe capacity at published relative $/GB
            # points below host DRAM (the ZeroPoint CXL pricing direction).
            + self.cxl_gb * hw.COSTS.usd_per_gb_host * 0.75
            # Hardware-compressed expander media is cheaper per raw GB
            # (hw.CostSpec's cxl point); the controller silicon rides in
            # base_usd of the server configs that carry it.
            + self.cxl_hw_gb * hw.COSTS.usd_per_gb_cxl
            + self.nvme_gb * 0.08
        )

    def amortized_usd(self, operating_period_years: float) -> float:
        """Total cost of owning one server for the operating period."""
        if operating_period_years <= 0:
            raise ValueError("operating_period_years must be positive")
        purchase = self.purchase_usd()
        maintenance = (
            self.annual_maintenance_pct / 100.0 * purchase * operating_period_years
        )
        rack = self.rack_usd_per_year * operating_period_years
        power = (
            self.power_kw * 24.0 * 365.0 * operating_period_years * self.usd_per_kwh
        )
        return purchase + self.deployment_usd + maintenance + rack + power

    def capacity_vector(self) -> Dict[str, float]:
        """Per-dimension capacity one server contributes to the fleet."""
        cap = {
            MEM + "hbm": self.hbm_gb * GIB,
            MEM + "host_dram_pcie": self.host_dram_gb * GIB,
            DECODE: self.decode_accesses_per_window,
            BW + "hbm": self.hbm_window_bytes,
            BW + "host_dram_pcie": self.pcie_window_bytes,
        }
        if self.cxl_gb > 0:
            cap[MEM + "cxl"] = self.cxl_gb * GIB
            cap[BW + "cxl"] = self.cxl_window_bytes
        if self.cxl_hw_gb > 0:
            cap[MEM + "cxl_hw"] = self.cxl_hw_gb * GIB
            cap[BW + "cxl_hw"] = self.cxl_hw_window_bytes
        if self.nvme_gb > 0:
            cap[MEM + "nvme"] = self.nvme_gb * GIB
            cap[BW + "nvme"] = self.nvme_window_bytes
        return cap


# Catalog: the v5e-host pairing the rest of the repo models, plus the
# denser-host and CXL-expanded variants the composable-memory direction
# targets. hbm/host sizes mirror hw.ChipSpec.
SERVERS: Dict[str, ServerSpec] = {
    s.name: s
    for s in (
        ServerSpec("v5e-base", hbm_gb=16.0, host_dram_gb=512.0),
        ServerSpec("v5e-bighost", hbm_gb=16.0, host_dram_gb=1536.0,
                   base_usd=2100.0, power_kw=0.7),
        ServerSpec("v5e-cxl", hbm_gb=16.0, host_dram_gb=512.0, cxl_gb=1024.0,
                   base_usd=2200.0, power_kw=0.75),
        ServerSpec("v5e-cxlhw", hbm_gb=16.0, host_dram_gb=512.0,
                   cxl_hw_gb=1024.0, base_usd=2250.0, power_kw=0.75),
    )
}


def get_server(name: str) -> ServerSpec:
    try:
        return SERVERS[name]
    except KeyError:
        raise KeyError(
            f"unknown server spec {name!r}; catalog: {sorted(SERVERS)}"
        ) from None


# ---------------------------------------------------------------------------
# Fleet telemetry summary (produced by BudgetArbiter.fleet_report)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """What the planner needs to know about a live multi-tenant run.

    All means are over the reported window range; per-window arrays are
    aligned to that range. Produced by ``BudgetArbiter.fleet_report()`` —
    the planner runs on live telemetry, not offline traces.
    """

    windows: int
    tenant_names: Tuple[str, ...]
    # Uncompressed footprint per tenant (bytes) — the all-DRAM demand.
    tenant_footprint_bytes: Tuple[float, ...]
    # Mean resident bytes per backing device per tenant (placement_hist x
    # stored_bytes, grouped by each tier's media device).
    tenant_bytes_by_device: Tuple[Dict[str, float], ...]
    # Mean decode demand per tenant (accesses per window).
    tenant_demand_accesses: Tuple[float, ...]
    # Mean SLA-weighted hotness-latency penalty per tenant (seconds).
    tenant_penalty_s: Tuple[float, ...]
    # Fleet latency proxy distribution: per-window sum of tenant penalties.
    per_window_penalty_s: np.ndarray
    fleet_tco_usd: float  # mean Eq. 12 byte-level TCO
    fleet_savings_pct: float  # mean Eq. 9-12 savings vs all-DRAM bytes
    # Mean migration + speculative bytes per window, per device (the
    # bandwidth demand the fleet imposes on each shared link).
    media_bytes_by_device: Dict[str, float]
    budget_feasible_frac: float


# ---------------------------------------------------------------------------
# Tier-configuration search space
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """One searched tier configuration.

    ``family`` picks the tierset: ``2t`` is the production 2-tier baseline
    (threshold policy), ``6t`` the paper's 5-tier analytical config,
    ``split`` the serving KV tierset with a ``warm_bits``/``cold_bits``
    codec split (the class-major deployment axis), and ``cxl`` the 6-tier
    set that inserts the hardware-compressed CXL expander tier (X1) into
    the characterized ladder. ``fast_fraction`` caps the shared fast tier
    (placement 0) at that fraction of fleet regions; ``alpha`` is the
    arbiter/analytical perf-vs-TCO knob.
    """

    family: str  # "2t" | "6t" | "split" | "cxl"
    alpha: float = 0.5
    fast_fraction: float = 0.5
    warm_bits: int = 8
    cold_bits: int = 4

    @property
    def name(self) -> str:
        if self.family == "2t":
            return f"2t-f{self.fast_fraction:.2f}"
        if self.family == "split":
            return (
                f"split{self.warm_bits}{self.cold_bits}"
                f"-a{self.alpha:.2f}-f{self.fast_fraction:.2f}"
            )
        if self.family == "cxl":
            return f"cxl-a{self.alpha:.2f}-f{self.fast_fraction:.2f}"
        return f"6t-a{self.alpha:.2f}-f{self.fast_fraction:.2f}"


def default_search_grid() -> List[PlannerConfig]:
    """The default configuration sweep: the 2T production baseline plus the
    6T alpha ladder and the codec-split family at two fast-tier sizes."""
    grid: List[PlannerConfig] = [PlannerConfig("2t", fast_fraction=0.5)]
    for alpha in (0.9, 0.5, 0.1):
        for frac in (0.5, 0.25):
            grid.append(PlannerConfig("6t", alpha=alpha, fast_fraction=frac))
    for wb, cb in ((8, 4), (8, 8)):
        grid.append(
            PlannerConfig("split", alpha=0.5, fast_fraction=0.5,
                          warm_bits=wb, cold_bits=cb)
        )
    return grid


def cxl_search_grid() -> List[PlannerConfig]:
    """The CXL-expanded sweep: the default grid plus the ``cxl`` family's
    alpha ladder — the configurations only a ``cxl_hw``-equipped server can
    realize. Additive: the shared prefix keeps the 2T/6T/split points
    byte-comparable with the default-grid baselines."""
    grid = default_search_grid()
    for alpha in (0.9, 0.5, 0.1):
        for frac in (0.5, 0.25):
            grid.append(PlannerConfig("cxl", alpha=alpha, fast_fraction=frac))
    return grid


def build_arbiter(
    cfg: PlannerConfig,
    specs: Sequence,
    n_regions: int,
    region_bytes: int = 2 * 1024 * 1024,
    media_bw_budget_bytes: Optional[Dict[str, float]] = None,
):
    """Build a BudgetArbiter realizing one searched tier configuration."""
    from repro.core.arbiter import BudgetArbiter

    n_t = len(specs)
    if cfg.family == "2t":
        managers = [make_manager("2T-M", n_regions, region_bytes=region_bytes,
                                 seed=t) for t in range(n_t)]
    elif cfg.family == "6t":
        managers = [
            make_manager(f"6T-AM-{cfg.alpha}", n_regions,
                         region_bytes=region_bytes, seed=t)
            for t in range(n_t)
        ]
    elif cfg.family == "split":
        from repro.serving.kv_cache import kv_tierset

        ts = kv_tierset(2048, warm_bits=cfg.warm_bits, cold_bits=cfg.cold_bits)
        managers = [
            TierScapeManager(
                ts, n_regions, region_bytes,
                ManagerConfig(policy="analytical", alpha=cfg.alpha), seed=t,
            )
            for t in range(n_t)
        ]
    elif cfg.family == "cxl":
        managers = [
            make_manager(f"7T-CX-{cfg.alpha}", n_regions,
                         region_bytes=region_bytes, seed=t)
            for t in range(n_t)
        ]
    else:
        raise ValueError(f"unknown planner family {cfg.family!r}")
    n_opts = managers[0].tierset.n_tiers + 1
    cap = np.full(n_opts, float(n_t * n_regions))
    cap[0] = max(cfg.fast_fraction * n_t * n_regions, 1.0)
    return BudgetArbiter(
        specs, managers, alpha=cfg.alpha, tier_capacity_regions=cap,
        media_bw_budget_bytes=media_bw_budget_bytes,
    )


def simulate_and_report(
    cfg: PlannerConfig,
    workloads_fn: Callable[[], List],
    specs: Sequence,
    windows: int = 16,
    warmup_windows: int = 2,
    seed: int = 0,
    n_regions: Optional[int] = None,
) -> FleetReport:
    """Run one configuration through ``simulate_multitenant`` and summarize
    it as the FleetReport the planner consumes — live telemetry, not an
    offline trace."""
    from repro.core import simulator

    workloads = workloads_fn()
    n = n_regions if n_regions is not None else workloads[0].n_regions
    arb = build_arbiter(cfg, specs, n)
    simulator.simulate_multitenant(
        workloads, arb, windows=windows, warmup_windows=warmup_windows,
        seed=seed, prefetch=False,
    )
    return arb.fleet_report(last_windows=windows - warmup_windows)


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    """One evaluated configuration: a perf-per-dollar point."""

    config: str
    servers: int
    fleet_usd: float  # amortized server dollars over the operating period
    memory_tco_usd: float  # Eq. 12 byte-level TCO (mean, per tenant cell)
    savings_pct: float  # fleet $ savings vs the all-DRAM-provisioned fleet
    p50_penalty_s: float  # latency proxy: median per-window fleet penalty
    p99_penalty_s: float  # latency proxy: p99 per-window fleet penalty
    perf_per_dollar: float  # served decode demand per amortized dollar

    def to_dict(self) -> Dict[str, float]:
        return {
            "config": self.config,
            "servers": int(self.servers),
            "fleet_usd": _r(self.fleet_usd),
            "memory_tco_usd": _r(self.memory_tco_usd),
            "savings_pct": _r(self.savings_pct),
            "p50_penalty_s": _r(self.p50_penalty_s),
            "p99_penalty_s": _r(self.p99_penalty_s),
            "perf_per_dollar": _r(self.perf_per_dollar),
        }


class CapacityPlanner:
    """Bin-packs fleet demand onto servers and prices tier configurations.

    ``fleet_scale`` replicates the reported tenant mix that many times: the
    report describes one tenant cell (the simulated mix); a fleet serves
    many identical cells, which is what makes server-count quantization
    fine-grained enough for the savings axis to be meaningful.
    """

    def __init__(
        self,
        server: ServerSpec,
        operating_period_years: float = 3.0,
        fleet_scale: int = 256,
    ):
        if fleet_scale < 1:
            raise ValueError("fleet_scale must be >= 1")
        self.server = server
        self.operating_period_years = operating_period_years
        self.fleet_scale = fleet_scale

    # ------------------------------------------------------------- packing
    def _tenant_demands(self, report: FleetReport) -> List[Dict[str, float]]:
        """Per-tenant demand vectors (one tenant cell, not yet scaled)."""
        out = []
        for t in range(len(report.tenant_names)):
            d: Dict[str, float] = {}
            for dev, b in sorted(report.tenant_bytes_by_device[t].items()):
                if b > 0:
                    d[MEM + dev] = float(b)
            d[DECODE] = float(report.tenant_demand_accesses[t])
            # Migration traffic is a fleet aggregate; attribute it evenly
            # across tenants (the arbiter already reconciled who moves).
            n_t = len(report.tenant_names)
            for dev, b in sorted(report.media_bytes_by_device.items()):
                if b > 0:
                    d[BW + dev] = float(b) / n_t
            out.append(d)
        return out

    def _dram_demands(self, report: FleetReport) -> List[Dict[str, float]]:
        """The all-DRAM-provisioned reference: every tenant's full footprint
        resides uncompressed in accelerator-attached memory, no migration
        traffic (nothing is ever compressed or moved)."""
        return [
            {
                MEM + "hbm": float(report.tenant_footprint_bytes[t]),
                DECODE: float(report.tenant_demand_accesses[t]),
            }
            for t in range(len(report.tenant_names))
        ]

    def pack(self, demands: Sequence[Dict[str, float]]) -> int:
        """First-fit-decreasing bin-pack of demand vectors onto servers.

        Deterministic: tenants are ordered by (max capacity fraction,
        tenant index) descending-first; a tenant whose demand exceeds one
        server in any dimension is split into equal shards first (tenant
        sharding). Returns the number of servers needed.
        """
        cap = self.server.capacity_vector()

        def frac(d: Dict[str, float]) -> float:
            f = 0.0
            for k, v in d.items():
                if v <= 0:
                    continue
                if cap.get(k, 0.0) <= 0:
                    raise ValueError(
                        f"server {self.server.name!r} has no capacity for "
                        f"demand dimension {k!r}"
                    )
                f = max(f, v / cap[k])
            return f

        shards: List[Tuple[float, int, Dict[str, float]]] = []
        for i, d in enumerate(demands):
            f = frac(d)
            n_shards = max(int(np.ceil(f)), 1)
            shard = {k: v / n_shards for k, v in d.items()}
            for _ in range(n_shards):
                shards.append((frac(shard), i, shard))
        # Largest shard first; ties by original tenant index then insertion.
        shards.sort(key=lambda s: (-s[0], s[1]))

        free: List[Dict[str, float]] = []  # remaining capacity per open server
        for _, _, d in shards:
            placed = False
            for f in free:
                if all(d.get(k, 0.0) <= f[k] + 1e-9 for k in cap):
                    for k in cap:
                        f[k] -= d.get(k, 0.0)
                    placed = True
                    break
            if not placed:
                f = dict(cap)
                for k in cap:
                    f[k] -= d.get(k, 0.0)
                free.append(f)
        return len(free)

    # ------------------------------------------------------------- pricing
    def _scale(self, demands: Sequence[Dict[str, float]]) -> List[Dict[str, float]]:
        return [d for _ in range(self.fleet_scale) for d in demands]

    def evaluate(self, config_name: str, report: FleetReport) -> FrontierPoint:
        """Price one configuration's report as a frontier point."""
        servers = self.pack(self._scale(self._tenant_demands(report)))
        dram_servers = self.pack(self._scale(self._dram_demands(report)))
        per_server = self.server.amortized_usd(self.operating_period_years)
        fleet_usd = servers * per_server
        dram_usd = dram_servers * per_server
        demand = self.fleet_scale * float(sum(report.tenant_demand_accesses))
        pen = np.asarray(report.per_window_penalty_s, dtype=np.float64)
        return FrontierPoint(
            config=config_name,
            servers=servers,
            fleet_usd=fleet_usd,
            memory_tco_usd=report.fleet_tco_usd,
            savings_pct=(
                100.0 * (dram_usd - fleet_usd) / dram_usd if dram_usd > 0 else 0.0
            ),
            p50_penalty_s=float(np.percentile(pen, 50)) if pen.size else 0.0,
            p99_penalty_s=float(np.percentile(pen, 99)) if pen.size else 0.0,
            perf_per_dollar=demand / fleet_usd if fleet_usd > 0 else 0.0,
        )

    # ------------------------------------------------------------ frontier
    @staticmethod
    def pareto_frontier(points: Sequence[FrontierPoint]) -> List[FrontierPoint]:
        """Non-dominated subset: minimize p99 latency proxy, maximize
        savings. Sorted by latency ascending; deterministic tie-breaks."""
        ordered = sorted(
            points, key=lambda p: (p.p99_penalty_s, -p.savings_pct, p.config)
        )
        out: List[FrontierPoint] = []
        best = -np.inf
        for p in ordered:
            if p.savings_pct > best + 1e-12:
                out.append(p)
                best = p.savings_pct
        return out

    @staticmethod
    def frontier_monotone(frontier: Sequence[FrontierPoint]) -> bool:
        """A valid frontier trades latency for dollars monotonically:
        sorted by latency proxy ascending, savings strictly increase and
        fleet dollars never increase."""
        for a, b in zip(frontier, frontier[1:]):
            if b.p99_penalty_s < a.p99_penalty_s - 1e-12:
                return False
            if b.savings_pct <= a.savings_pct + 1e-12:
                return False
            if b.fleet_usd > a.fleet_usd + 1e-9:
                return False
        return True

    @staticmethod
    def dominance_margin_pct(
        frontier: Sequence[FrontierPoint],
        baseline: FrontierPoint,
        latency_tol: float = 1.05,
    ) -> float:
        """Savings-points margin by which the frontier dominates
        ``baseline``: the best savings of any frontier point whose latency
        proxy is no worse than the baseline's (x ``latency_tol``), minus
        the baseline's savings. Negative = no dominating point."""
        margins = [
            p.savings_pct - baseline.savings_pct
            for p in frontier
            if p.p99_penalty_s <= baseline.p99_penalty_s * latency_tol + 1e-12
        ]
        return max(margins) if margins else -np.inf


# ---------------------------------------------------------------------------
# Sweep driver (shared by scripts/hillclimb.py --capacity and the
# capacity_frontier benchmark)
# ---------------------------------------------------------------------------


def sweep_frontier(
    workloads_fn: Callable[[], List],
    specs: Sequence,
    planner: CapacityPlanner,
    configs: Optional[Sequence[PlannerConfig]] = None,
    windows: int = 16,
    warmup_windows: int = 2,
    seed: int = 0,
) -> Dict:
    """Evaluate every configuration and emit the frontier summary dict
    (JSON-ready, deterministic for a fixed seed)."""
    configs = list(configs) if configs is not None else default_search_grid()
    points: List[FrontierPoint] = []
    baseline_2t: Optional[FrontierPoint] = None
    for cfg in configs:
        report = simulate_and_report(
            cfg, workloads_fn, specs, windows=windows,
            warmup_windows=warmup_windows, seed=seed,
        )
        point = planner.evaluate(cfg.name, report)
        points.append(point)
        if cfg.family == "2t" and baseline_2t is None:
            baseline_2t = point
    frontier = planner.pareto_frontier(points)
    out: Dict = {
        "server": planner.server.name,
        "operating_period_years": _r(planner.operating_period_years),
        "fleet_scale": planner.fleet_scale,
        "windows": windows,
        "seed": seed,
        "points": [p.to_dict() for p in points],
        "frontier": [p.to_dict() for p in frontier],
        "monotone": planner.frontier_monotone(frontier),
    }
    if baseline_2t is not None:
        margin = planner.dominance_margin_pct(frontier, baseline_2t)
        out["baseline_2t"] = baseline_2t.to_dict()
        out["dominance_margin_pct"] = _r(margin) if np.isfinite(margin) else None
        out["dominates_2t"] = bool(margin > 0)
    return out


def frontier_json(result: Dict) -> str:
    """Canonical JSON encoding (the byte-reproducibility contract)."""
    return json.dumps(result, indent=2, sort_keys=True)
