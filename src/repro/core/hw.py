"""Hardware constants for the target platform (TPU v5e) and its host.

These constants are shared by three consumers:
  * the tier cost model (``core/tiers.py``) — per-block access-latency and
    $/GB terms for every software-defined compressed tier,
  * the roofline analysis (``roofline/analysis.py``) — compute / memory /
    collective roofline denominators,
  * the window simulator (``core/simulator.py``) — fault service times.

The container this repo is developed in is CPU-only; TPU v5e is the *target*.
Nothing here is measured at runtime — these are published part specs, which is
exactly what a TCO model should be built from.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip specs for the compute platform."""

    name: str = "tpu-v5e"
    # Compute.
    peak_bf16_flops: float = 197e12  # 197 TFLOP/s bf16 per chip.
    # Effective element-ops/s on the *fault path*: an on-demand dequant is a
    # blocking, launch-bound op (dispatch + no cross-block pipelining), so it
    # sees a small fraction of nominal VPU throughput. This constant is what
    # makes high-ratio codecs the slowest tiers (deflate's role in Fig 3a).
    # Bulk dequant inside the tiered-attention kernel is NOT subject to this —
    # it pipelines across blocks and is accounted by the roofline instead.
    peak_vpu_elem_ops: float = 0.1e12
    # Memory.
    hbm_bytes: int = 16 * 1024**3  # 16 GiB HBM per chip.
    hbm_bw: float = 819e9  # 819 GB/s HBM bandwidth.
    vmem_bytes: int = 128 * 1024**2  # ~128 MiB VMEM (v5e: 128MB total).
    # Interconnect.
    ici_link_bw: float = 50e9  # ~50 GB/s per ICI link (given constant).
    ici_links: int = 4  # 2D torus on v5e.
    # Host attachment.
    host_link_bw: float = 25e9  # effective PCIe Gen4 x16 per chip-host path.
    host_dram_bytes: int = 512 * 1024**3


@dataclasses.dataclass(frozen=True)
class CostSpec:
    """Unit memory cost (relative USD units; only ratios matter).

    The paper (§7.2) sets the per-GB cost of Optane at 1/3 of DRAM [43]. We
    keep the identical ratio between the accelerator-attached tier (HBM) and
    the host-DRAM tier behind PCIe.
    """

    usd_per_gb_hbm: float = 10.0
    usd_per_gb_host: float = 10.0 / 3.0
    # CXL-attached expander DRAM: commodity DIMMs behind a CXL controller,
    # priced below the host tier (no per-chip PCIe lane budget, denser
    # modules). The ZeroPoint-style inline compressor multiplies *effective*
    # $/byte down further via the tier's measured ratio — that part lives in
    # the TCO model, not here.
    usd_per_gb_cxl: float = 10.0 / 4.0

    def usd_per_byte(self, media: str) -> float:
        if media == "hbm":
            return self.usd_per_gb_hbm / 1024**3
        if media == "host":
            return self.usd_per_gb_host / 1024**3
        if media == "cxl":
            return self.usd_per_gb_cxl / 1024**3
        raise ValueError(f"unknown media {media!r}")


V5E = ChipSpec()
COSTS = CostSpec()

# Fixed software overhead charged per fault (engine bookkeeping: page-table
# style lookup of the block handle, launch overhead of the dequant op). The
# analogue of the kernel fault-path cost in the paper.
FAULT_FIXED_US: float = 1.0

# Pool-manager overhead per access operation (µs). ``slab`` mirrors zbud
# (simple O(1) slot addressing); ``packed`` mirrors zsmalloc (dense packing,
# extra index indirection + unaligned gather); ``line`` is the
# hardware-managed layout behind an inline CXL compressor — the controller
# owns line addressing, so the software pool manager charges nothing.
POOL_ACCESS_US = {"slab": 0.2, "packed": 0.8, "line": 0.0}

# Fixed media-access setup cost per access operation (µs): HBM reads issue
# directly; host reads pay PCIe DMA setup + link round-trip (the Optane
# media-latency analogue of paper §4.1.1); CXL.mem loads are cache-line
# transactions, cheaper to set up than a PCIe DMA descriptor.
MEDIA_FIXED_US = {"hbm": 0.0, "host": 2.0, "cxl": 0.6}

# zbud-analogue pair-fill inefficiency: two variable-fit objects per slab
# page achieve < 100% slot utilization in practice (paper: zbud saving
# "cannot be more than 50%", typically less). Packed (zsmalloc) pools do not
# pay this, which is why they win on density.
SLAB_UTILIZATION = 0.85

# Per-element decode cost in VPU element-ops for each codec (unpack, shift,
# scale-multiply, cast chains). Mirrors lz4 < lzo < deflate decode cost.
# ``cxl_hw`` decompresses inline in the memory controller (ZeroPoint-style):
# the VPU only pays a residual scale-apply, near-zero ops/elem.
CODEC_DECODE_OPS = {
    "none": 0.0, "fp8": 1.0, "int8": 2.0, "int4": 4.0, "int2": 6.0,
    "cxl_hw": 0.1,
}
# Encode cost (abs-max reduce + divide + round + pack). The hardware codec's
# line packing happens in the controller; software only quantizes.
CODEC_ENCODE_OPS = {
    "none": 0.0, "fp8": 1.5, "int8": 3.0, "int4": 5.0, "int2": 7.0,
    "cxl_hw": 0.2,
}

# --------------------------------------------------------------------------
# Media-device link specs shared by the MediaDevice presets
# (``media/devices.py``) and anything else that prices far-memory traffic.
# One definition per number — the presets must never fork these.
# --------------------------------------------------------------------------
# CXL 2.0 x8 expander: asymmetric effective read/write, cache-line
# transaction setup, controller-level parallelism.
CXL_LINK_READ_BW: float = 64e9
CXL_LINK_WRITE_BW: float = 48e9
CXL_FIXED_LATENCY_S: float = MEDIA_FIXED_US["cxl"] * 1e-6
CXL_QUEUE_DEPTH: int = 8
# Datacenter NVMe (PCIe Gen4 drive).
NVME_READ_BW: float = 7e9
NVME_WRITE_BW: float = 5e9
NVME_FIXED_LATENCY_S: float = 10e-6
NVME_QUEUE_DEPTH: int = 32


def media_bw(media: str, chip: ChipSpec = V5E) -> float:
    """Effective read bandwidth for a tier's backing media."""
    if media == "hbm":
        return chip.hbm_bw
    if media == "host":
        return chip.host_link_bw
    if media == "cxl":
        return CXL_LINK_READ_BW
    raise ValueError(f"unknown media {media!r}")
