"""Waterfall placement model (paper §5.1).

At the end of every profile window:
  * regions faulted back during the window restart from DRAM (index 0),
  * DRAM regions with hotness < H_th are pushed to tier 1,
  * every compressed region that was NOT accessed ages one tier down
    (T_k -> T_{k+1}), except the last tier.

The model is fully vectorized; its cost is part of the daemon tax.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class WaterfallConfig:
    hotness_threshold: float  # H_th: DRAM regions colder than this are evicted
    # A region whose faulted-back fraction exceeds this within the window
    # "restarts its journey from DRAM" (paper §6.3: "a major portion").
    refault_fraction: float = 0.25


def waterfall_step(
    placement: np.ndarray,
    hotness: np.ndarray,
    fault_fraction: np.ndarray,
    n_tiers: int,
    cfg: WaterfallConfig,
) -> np.ndarray:
    """One end-of-window placement update. Returns the new placement vector.

    Args:
      placement: (R,) int, 0 = DRAM, 1..n_tiers = compressed tier index.
      hotness:   (R,) float, access counts of the closed window.
      fault_fraction: (R,) float in [0,1], fraction of the region's blocks
        faulted back to DRAM during the window.
      n_tiers:   number of compressed tiers N.
      cfg:       thresholds.
    """
    placement = placement.copy()
    in_dram = placement == 0
    compressed = ~in_dram

    # Faulted regions restart from DRAM.
    refaulted = compressed & (fault_fraction >= cfg.refault_fraction)
    placement[refaulted] = 0

    # Untouched compressed regions age one tier down (waterfall).
    untouched = compressed & (hotness <= 0) & ~refaulted
    placement[untouched] = np.minimum(placement[untouched] + 1, n_tiers)

    # Cold DRAM regions are evicted to tier 1.
    evict = in_dram & (hotness < cfg.hotness_threshold)
    placement[evict] = 1
    return placement
