"""Block-quantization codecs — the TPU-native analogue of zswap compressors.

The paper composes tiers from byte-oriented compressors (lz4 / lzo / deflate)
with monotonically increasing compression ratio *and* decompression cost.
Byte-wise LZ coding is bit-serial and has no efficient MXU/VPU mapping, so the
TPU-native compression spectrum is **scaled integer quantization**:

    codec   ratio (w/ scales)   decode cost     paper analogue
    none    1.00x               0               uncompressed DRAM
    fp8     ~2.00x              cast            lz4      (fast, modest ratio)
    int8    ~1.94x              scale-mul       lzo      (balanced)
    int4    ~3.56x              unpack+scale    zstd-ish (dense)
    int2    ~5.33x              unpack+scale    deflate  (max ratio, slow)
    cxl_hw  ~1.88x nominal      ~0 (inline hw)  ZeroPoint CXL line compressor

``cxl_hw`` models an inline hardware compressor on a CXL expander: software
quantizes to dense int8 lines; the controller transparently narrows lines
whose codewords fit int4 range (``cxl_line_bits``), so *observed* stored and
wire bytes are data-dependent (up to ~2x the nominal ratio) while decode
costs the VPU nearly nothing.

Every codec is a pure-jnp, jit-compatible transform with static output shapes
(required so compressed pools can live inside jitted steps). The perf-critical
encode/decode paths also exist as Pallas kernels (``repro.kernels``); the
functions here are the reference semantics those kernels are tested against.

Ratios are fixed-point rather than data-dependent; data-dependence reappears
as *reconstruction error*, which the fig3 characterization benchmark measures
on two input distributions (the nci-vs-dickens analogue).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw

Array = jax.Array

# Group sizes for per-group absmax scaling (elements sharing one f32 scale).
# ``cxl_hw`` scales are deliberately coarse (one per 512 codewords): the
# inline compressor narrows 64-codeword hardware *lines* whose local range
# is small relative to the shared scale — with per-line scales every line
# would span full int8 range and nothing could ever narrow.
GROUP = {"int8": 128, "int4": 64, "int2": 32, "cxl_hw": 512}
QMAX = {"int8": 127, "int4": 7, "int2": 1}
SCALE_BYTES = 4  # f32 scales

# Inline line compressor: a stored line narrows to 4-bit codewords when every
# quantized value in it fits int4 range. Wire/stored bytes shrink; the dense
# int8 view the engine reads back is unchanged.
CXL_LINE_ELEMS = 64  # int8 codewords per hardware cache line
CXL_LINE_NARROW_QMAX = 7  # |q| <= 7 -> the controller stores the line 4-bit


@dataclasses.dataclass(frozen=True)
class Encoded:
    """A compressed block: uint8 payload + f32 per-group scales."""

    payload: Array  # uint8, flat
    scales: Array  # f32, flat (empty for fp8/none)
    codec: str


def _group_reshape(x: Array, group: int) -> Array:
    flat = x.reshape(-1)
    assert flat.shape[0] % group == 0, (
        f"block elems {flat.shape[0]} not divisible by group {group}"
    )
    return flat.reshape(-1, group)


# ---------------------------------------------------------------------------
# int-k family: per-group absmax scale, packed little-endian into uint8.
# ---------------------------------------------------------------------------


def _int_encode(x: Array, bits: int, group: int) -> Encoded:
    qmax = (1 << (bits - 1)) - 1 if bits > 2 else 1  # int2 uses {-1,0,1}
    g = _group_reshape(x.astype(jnp.float32), group)
    scale = jnp.max(jnp.abs(g), axis=1, keepdims=True) / qmax
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    per_byte = 8 // bits
    qf = q.reshape(-1, per_byte)  # values packed into one byte
    packed = jnp.zeros(qf.shape[0], dtype=jnp.uint8)
    mask = (1 << bits) - 1
    for i in range(per_byte):
        nib = (qf[:, i].astype(jnp.int32) & mask).astype(jnp.uint8)
        packed = packed | (nib << (bits * i)).astype(jnp.uint8)
    name = f"int{bits}"
    return Encoded(payload=packed, scales=scale.reshape(-1), codec=name)


def _int_decode(enc: Encoded, bits: int, group: int, n_elem: int) -> Array:
    per_byte = 8 // bits
    mask = (1 << bits) - 1
    sign_bit = 1 << (bits - 1)
    vals = []
    for i in range(per_byte):
        nib = (enc.payload.astype(jnp.int32) >> (bits * i)) & mask
        nib = jnp.where(nib >= sign_bit, nib - (1 << bits), nib)
        vals.append(nib)
    q = jnp.stack(vals, axis=1).reshape(-1)[:n_elem].astype(jnp.float32)
    scale = jnp.repeat(enc.scales, group)[:n_elem]
    return q * scale


# ---------------------------------------------------------------------------
# fp8: one f32 normalizer per block, payload is float8_e4m3fn bytes.
# ---------------------------------------------------------------------------

_FP8_MAX = 448.0  # e4m3fn max finite


def _fp8_encode(x: Array) -> Encoded:
    flat = x.astype(jnp.float32).reshape(-1)
    norm = jnp.max(jnp.abs(flat)) / _FP8_MAX
    norm = jnp.where(norm == 0.0, 1.0, jnp.maximum(norm, 1e-30))
    f8 = (flat / norm).astype(jnp.float8_e4m3fn)
    payload = jax.lax.bitcast_convert_type(f8, jnp.uint8)
    return Encoded(payload=payload, scales=norm.reshape(1), codec="fp8")


def _fp8_decode(enc: Encoded, n_elem: int) -> Array:
    f8 = jax.lax.bitcast_convert_type(enc.payload, jnp.float8_e4m3fn)
    return f8.astype(jnp.float32)[:n_elem] * enc.scales[0]


# ---------------------------------------------------------------------------
# Codec objects
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Codec:
    """A compression algorithm: fixed ratio, fixed decode cost/elem."""

    name: str
    bits_per_elem: float  # payload bits per source element (excl. scales)
    group: int  # elements per f32 scale (0 = one scale per block)

    # -- size accounting ----------------------------------------------------
    def payload_bytes(self, n_elem: int) -> int:
        return int(n_elem * self.bits_per_elem) // 8

    def scale_bytes(self, n_elem: int) -> int:
        if self.name == "none":
            return 0
        n_groups = 1 if self.group == 0 else (n_elem + self.group - 1) // self.group
        return n_groups * SCALE_BYTES

    def compressed_bytes(self, n_elem: int) -> int:
        return self.payload_bytes(n_elem) + self.scale_bytes(n_elem)

    def ratio(self, n_elem: int, src_bytes_per_elem: int = 2) -> float:
        if self.name == "none":
            return 1.0
        return (n_elem * src_bytes_per_elem) / self.compressed_bytes(n_elem)

    # -- transform ----------------------------------------------------------
    def encode(self, x: Array) -> Encoded:
        if self.name == "none":
            flat = x.astype(jnp.bfloat16).reshape(-1)
            payload = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
            return Encoded(payload=payload, scales=jnp.zeros((0,), jnp.float32), codec="none")
        if self.name == "fp8":
            return _fp8_encode(x)
        if self.name == "cxl_hw":
            # Software side of the hardware tier: per-line int8 quantization.
            # Line narrowing (4-bit storage of small lines) happens in the
            # controller model, not in this dense payload — see
            # ``cxl_line_ratio``.
            enc = _int_encode(x, 8, self.group)
            return Encoded(payload=enc.payload, scales=enc.scales, codec=self.name)
        bits = int(self.name[3:])
        return _int_encode(x, bits, self.group)

    def decode(self, enc: Encoded, shape, dtype=jnp.bfloat16) -> Array:
        n_elem = 1
        for s in shape:
            n_elem *= int(s)
        if self.name == "none":
            flat = jax.lax.bitcast_convert_type(
                enc.payload.reshape(-1, 2), jnp.bfloat16
            ).reshape(-1)
            return flat[:n_elem].reshape(shape).astype(dtype)
        if self.name == "fp8":
            return _fp8_decode(enc, n_elem).reshape(shape).astype(dtype)
        if self.name == "cxl_hw":
            return _int_decode(enc, 8, self.group, n_elem).reshape(shape).astype(dtype)
        bits = int(self.name[3:])
        return _int_decode(enc, bits, self.group, n_elem).reshape(shape).astype(dtype)

    # -- modeled costs ------------------------------------------------------
    @property
    def decode_ops_per_elem(self) -> float:
        return hw.CODEC_DECODE_OPS[self.name]

    @property
    def encode_ops_per_elem(self) -> float:
        return hw.CODEC_ENCODE_OPS[self.name]


CODECS: Dict[str, Codec] = {
    "none": Codec("none", 16.0, 0),
    "fp8": Codec("fp8", 8.0, 0),
    "int8": Codec("int8", 8.0, GROUP["int8"]),
    "int4": Codec("int4", 4.0, GROUP["int4"]),
    "int2": Codec("int2", 2.0, GROUP["int2"]),
    "cxl_hw": Codec("cxl_hw", 8.0, GROUP["cxl_hw"]),
}


def cxl_line_bits(payload: Array, line_elems: int = CXL_LINE_ELEMS) -> Array:
    """Per-hardware-line stored width (4 or 8 bits/codeword) the inline
    compressor achieves on a ``cxl_hw`` payload. Lines whose every
    two's-complement codeword fits ``[-CXL_LINE_NARROW_QMAX,
    CXL_LINE_NARROW_QMAX]`` narrow to 4-bit storage; the rest stay 8-bit."""
    q = jax.lax.bitcast_convert_type(payload.reshape(-1), jnp.int8)
    lines = q.reshape(-1, line_elems).astype(jnp.int32)
    narrow = jnp.max(jnp.abs(lines), axis=1) <= CXL_LINE_NARROW_QMAX
    return jnp.where(narrow, 4, 8).astype(jnp.int32)


def cxl_wire_bytes(payload: Array, scales: Array, line_elems: int = CXL_LINE_ELEMS) -> int:
    """Bytes a ``cxl_hw`` payload actually occupies on the compressed media
    (narrowed line payloads + uncompressed scales)."""
    bits = np.asarray(cxl_line_bits(payload, line_elems), dtype=np.int64)
    return int((bits * line_elems).sum() // 8) + int(scales.size) * SCALE_BYTES


def cxl_line_ratio(payload: Array, line_elems: int = CXL_LINE_ELEMS) -> float:
    """Observed line-compression ratio: nominal dense payload bytes over the
    bytes the controller stores/moves. In [1, 2] — 1.0 when no line narrows,
    2.0 when every line holds int4-range values."""
    bits = np.asarray(cxl_line_bits(payload, line_elems), dtype=np.int64)
    nominal = int(payload.size) * 8
    wire = int(bits.sum()) * line_elems
    return float(nominal) / float(max(wire, 1))


def roundtrip_error(codec_name: str, x: Array) -> Array:
    """Relative L2 reconstruction error of one encode/decode roundtrip."""
    codec = CODECS[codec_name]
    enc = codec.encode(x)
    xh = codec.decode(enc, x.shape, jnp.float32)
    num = jnp.linalg.norm(x.astype(jnp.float32) - xh)
    den = jnp.maximum(jnp.linalg.norm(x.astype(jnp.float32)), 1e-12)
    return num / den
