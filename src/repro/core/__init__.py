"""TierScape core: multiple software-defined compressed memory tiers for
TPU model state, with waterfall / analytical placement (paper §4-§6)."""

from repro.core import analytical, arbiter, capacity, codecs, hw, pools, simulator, tco, telemetry, tiers, waterfall
from repro.core.arbiter import ArbiterWindowStats, BudgetArbiter, TenantSpec
from repro.core.capacity import (
    CapacityPlanner,
    FleetReport,
    FrontierPoint,
    PlannerConfig,
    ServerSpec,
    get_server,
)
from repro.core.manager import ManagerConfig, MigrationPlan, TierScapeManager, make_manager
from repro.core.tiers import (
    BASELINE_2T,
    TierSet,
    TierSpec,
    baseline_2t_tierset,
    characterized,
    default_tierset,
    selected,
)

__all__ = [
    "analytical",
    "arbiter",
    "capacity",
    "CapacityPlanner",
    "FleetReport",
    "FrontierPoint",
    "PlannerConfig",
    "ServerSpec",
    "get_server",
    "codecs",
    "hw",
    "pools",
    "simulator",
    "tco",
    "telemetry",
    "tiers",
    "waterfall",
    "ArbiterWindowStats",
    "BudgetArbiter",
    "TenantSpec",
    "ManagerConfig",
    "MigrationPlan",
    "TierScapeManager",
    "make_manager",
    "BASELINE_2T",
    "TierSet",
    "TierSpec",
    "baseline_2t_tierset",
    "characterized",
    "default_tierset",
    "selected",
]
