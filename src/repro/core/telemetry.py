"""Hotness telemetry — the PEBS/TS-Daemon analogue (paper §6.2).

The paper samples MEM_INST_RETIRED.{ALL_LOADS,ALL_STORES} with PEBS and
accumulates counts into 2MB regions per 120s profile window. On TPU there is
no load/store sampling, but the computation itself yields *exact* access
counts:

  * KV-cache blocks: attention mass per block (sum of softmax weights), or
    simply blocks touched per decode step,
  * embedding rows: token-frequency histogram of the batch,
  * optimizer slices: per-slice gradient mass.

Exact telemetry is *better* than PEBS; to reproduce the paper's robustness
claims (waterfall tolerating profiling inaccuracies, §5.1) we also provide a
PEBS-fidelity mode that Bernoulli-thins and mis-attributes a fraction of the
exact counts.

All state is numpy on the host — telemetry is daemon-side (TS-Daemon runs on
host cores in the paper too), and its cost is accounted by the daemon-tax
benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PEBSNoise:
    """Emulate hardware-sampling fidelity loss on exact counts."""

    sample_rate: float = 0.05  # fraction of accesses that produce a sample
    misattribution: float = 0.01  # fraction of samples landing on a neighbour
    seed: int = 0

    def apply(self, counts: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        sampled = rng.binomial(counts.astype(np.int64), self.sample_rate)
        if self.misattribution > 0 and counts.size > 1:
            moved = rng.binomial(sampled, self.misattribution)
            sampled = sampled - moved
            # Mis-attributed samples land on a random neighbouring region.
            shift = np.roll(moved, 1)
            sampled = sampled + shift
        return sampled.astype(np.float64) / max(self.sample_rate, 1e-9)


@dataclasses.dataclass
class RegionTelemetry:
    """Per-region hotness over a sliding history of profile windows.

    ``hotness`` is the access count of the last closed window; ``history``
    keeps the last ``history_len`` windows so the analytical model can use the
    4-window average the paper feeds it (§7.1).
    """

    n_regions: int
    history_len: int = 4
    pebs: Optional[PEBSNoise] = None
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._accum = np.zeros(self.n_regions, dtype=np.float64)
        self.history = np.zeros((self.history_len, self.n_regions), dtype=np.float64)
        self._windows_closed = 0

    # -- ingest -------------------------------------------------------------
    def record(self, counts: np.ndarray) -> None:
        """Accumulate access counts (one engine step / sub-window)."""
        assert counts.shape == (self.n_regions,)
        self._accum += counts

    def record_indices(self, idx: np.ndarray, weights: Optional[np.ndarray] = None) -> None:
        np.add.at(self._accum, idx, 1.0 if weights is None else weights)

    # -- window boundary ------------------------------------------------------
    def close_window(self) -> np.ndarray:
        """End the profile window; returns the (possibly noised) hotness."""
        counts = self._accum
        if self.pebs is not None:
            counts = self.pebs.apply(counts, self._rng)
        self.history = np.roll(self.history, 1, axis=0)
        self.history[0] = counts
        self._accum = np.zeros_like(self._accum)
        self._windows_closed += 1
        return self.history[0].copy()

    # -- views ----------------------------------------------------------------
    @property
    def hotness(self) -> np.ndarray:
        """Last closed window's hotness."""
        return self.history[0]

    def averaged_hotness(self, windows: int = 4) -> np.ndarray:
        """Mean hotness over the last ``windows`` closed windows (paper §7.1)."""
        w = min(windows, max(self._windows_closed, 1), self.history_len)
        return self.history[:w].mean(axis=0)

    def percentile_threshold(self, pct: float) -> float:
        """Hotness value below which ``pct`` fraction of regions fall.

        Used to derive the paper's conservative/moderate/aggressive H_th
        values (cover ~20%/50%/80% of data, §7.1).
        """
        return float(np.quantile(self.hotness, pct))
