"""Software-defined compressed tiers: (codec x pool x media) combinations.

Mirrors paper §4/§4.1/Table 1-2. A tier is a point in the
(access latency, compression ratio, $/byte) space:

  * codec  — block-quantization algorithm (``core/codecs.py``); the
             lz4/lzo/deflate analogue,
  * pool   — packing layout for compressed blocks:
               ``slab``   — zbud analogue: fixed half-block slots, O(1)
                            addressing, space saving capped at ~2x,
               ``packed`` — zsmalloc analogue: dense byte packing (rounded to
                            128B) + index indirection, best density but
                            higher per-access management cost,
               ``line``   — hardware-managed cache-line layout behind an
                            inline CXL compressor: 64B-aligned lines, no
                            software index, zero pool-management cost,
  * media  — ``hbm`` (on-chip, fast, expensive), ``host`` (host DRAM behind
             PCIe, 1/3 the $/GB — the paper's DRAM-vs-Optane cost ratio), or
             ``cxl`` (expander DRAM behind an inline hardware compressor,
             1/4 the $/GB before the observed line-compression multiplier).

Access latency per block is the sum of media read, pool management, dequant
compute and a fixed fault overhead; these are the ``Lat_T`` terms of Eq. 8.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core import hw
from repro.core.codecs import CODECS, Codec

PACKED_ALIGN = 128  # packed pool rounds blocks up to 128B
PACKED_INDEX_BYTES = 8  # per-block index entry (offset + tier metadata)
LINE_ALIGN = 64  # hardware line pool stores 64B cache lines


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One software-defined compressed tier."""

    tid: str  # characterization id, e.g. "C7"
    name: str  # e.g. "PK-I4-HB"
    pool: str  # "slab" | "packed"
    codec_name: str  # key into CODECS
    media: str  # "hbm" | "host"
    # Backing-media device binding (key into repro.media.devices.DEVICES).
    # Empty = the default device for this media class (hbm -> on-chip HBM,
    # host -> host DRAM behind PCIe); override to rebind a tier onto CXL or
    # NVMe swap devices without changing its codec/pool identity.
    media_device: str = ""

    @property
    def codec(self) -> Codec:
        return CODECS[self.codec_name]

    @property
    def device(self):
        """Resolved MediaDevice this tier's payloads live on."""
        from repro.media import devices as media_devices

        name = self.media_device or media_devices.DEFAULT_FOR_MEDIA[self.media]
        return media_devices.get(name)

    # -- size accounting ----------------------------------------------------
    def stored_bytes(self, n_elem: int, src_bytes_per_elem: int = 2) -> int:
        """Bytes this tier actually occupies for one block of n_elem."""
        payload = self.codec.payload_bytes(n_elem)
        scales = self.codec.scale_bytes(n_elem)
        src = n_elem * src_bytes_per_elem
        if self.pool == "slab":
            # zbud analogue: slots of src/2 bytes; payload occupies whole
            # slots (=> saving capped at 2x), scales live in a side-car, and
            # pair-fill inefficiency inflates the footprint (hw.SLAB_UTILIZATION).
            slot = max(src // 2, 1)
            n_slots = -(-payload // slot)
            return int(n_slots * slot / hw.SLAB_UTILIZATION) + scales
        if self.pool == "packed":
            aligned = -(-(payload + scales) // PACKED_ALIGN) * PACKED_ALIGN
            return aligned + PACKED_INDEX_BYTES
        if self.pool == "line":
            # Hardware-managed layout: line-aligned payload + scales, no
            # software index (the controller owns line addressing). This is
            # the *nominal* footprint; the inline compressor's observed line
            # narrowing shows up as a measured-ratio override in the TCO
            # model, not here.
            return -(-(payload + scales) // LINE_ALIGN) * LINE_ALIGN
        raise ValueError(f"unknown pool {self.pool!r}")

    def effective_ratio(self, n_elem: int, src_bytes_per_elem: int = 2) -> float:
        return (n_elem * src_bytes_per_elem) / self.stored_bytes(n_elem, src_bytes_per_elem)

    # -- latency model (Eq. 8's Lat_T, in seconds per block access) ---------
    def access_latency_s(self, n_elem: int, src_bytes_per_elem: int = 2) -> float:
        """Latency of one access *operation* decompressing n_elem elements.

        The fixed terms (fault bookkeeping, pool lookup, media setup) are paid
        once per operation regardless of n_elem, so callers should pass the
        actual access granularity (a 4KB-page block, a KV page, or a whole
        2MB region) rather than summing per-block latencies.
        """
        bytes_read = self.stored_bytes(n_elem, src_bytes_per_elem)
        t_media = bytes_read / hw.media_bw(self.media) + hw.MEDIA_FIXED_US[self.media] * 1e-6
        t_pool = hw.POOL_ACCESS_US[self.pool] * 1e-6
        t_dequant = n_elem * self.codec.decode_ops_per_elem / hw.V5E.peak_vpu_elem_ops
        t_fixed = hw.FAULT_FIXED_US * 1e-6
        return t_media + t_pool + t_dequant + t_fixed

    def compress_latency_s(self, n_elem: int, src_bytes_per_elem: int = 2) -> float:
        """Cost to place one block INTO this tier (encode + media write)."""
        bytes_written = self.stored_bytes(n_elem, src_bytes_per_elem)
        t_media = bytes_written / hw.media_bw(self.media) + hw.MEDIA_FIXED_US[self.media] * 1e-6
        t_encode = n_elem * self.codec.encode_ops_per_elem / hw.V5E.peak_vpu_elem_ops
        return t_media + t_encode

    # -- cost model (Eq. 12's (1/C_Ty)*USD_Ty term) --------------------------
    def usd_per_source_byte(self, n_elem: int, src_bytes_per_elem: int = 2) -> float:
        """USD to store one *source* byte in this tier (compressed)."""
        per_byte = hw.COSTS.usd_per_byte(self.media)
        return per_byte / self.effective_ratio(n_elem, src_bytes_per_elem)


# ---------------------------------------------------------------------------
# The 12 characterized tiers (paper §4.1: 12 of the 63 possible combos) and
# the 5 selected for evaluation (paper §4.2 / Table 2).
#
# Naming: pool SL(slab)/PK(packed) - codec F8/I8/I4/I2 - media HB(hbm)/HO(host)
# Paper mapping: zbud->SL zsmalloc->PK | lz4->F8 lzo->I8 zstd->I4 deflate->I2
#                DRAM->HB Optane->HO
# ---------------------------------------------------------------------------

_T = TierSpec
CHARACTERIZED: List[TierSpec] = [
    _T("C1", "SL-F8-HB", "slab", "fp8", "hbm"),
    _T("C2", "SL-F8-HO", "slab", "fp8", "host"),
    _T("C3", "PK-F8-HB", "packed", "fp8", "hbm"),
    _T("C4", "PK-F8-HO", "packed", "fp8", "host"),
    _T("C5", "SL-I8-HB", "slab", "int8", "hbm"),
    _T("C6", "PK-I8-HB", "packed", "int8", "hbm"),
    _T("C7", "PK-I8-HO", "packed", "int8", "host"),
    _T("C8", "SL-I4-HB", "slab", "int4", "hbm"),
    _T("C9", "PK-I4-HB", "packed", "int4", "hbm"),
    _T("C10", "PK-I4-HO", "packed", "int4", "host"),
    _T("C11", "PK-I2-HB", "packed", "int2", "hbm"),
    _T("C12", "PK-I2-HO", "packed", "int2", "host"),
]

# Extension tiers beyond the paper's characterized 12 (registered in the
# id lookup but kept out of ``characterized()`` so the paper tables stay the
# paper's). X1 is the hardware-compressed CXL expander (ZeroPoint-style):
# line pool + inline hw codec on cxl media. It sits between C1 (fast,
# expensive HBM) and C2 (cheap but PCIe-latency host) on the latency axis,
# and below both on $/GB once the observed line ratio multiplies effective
# capacity.
EXTENSION: List[TierSpec] = [
    _T("X1", "LN-HW-CX", "line", "cxl_hw", "cxl", media_device="cxl_hw"),
]
_BY_ID = {t.tid: t for t in CHARACTERIZED + EXTENSION}


def characterized() -> List[TierSpec]:
    return list(CHARACTERIZED)


def get(tid: str) -> TierSpec:
    return _BY_ID[tid]


# Paper Table 2 analogue. Selection rationale (§4.2):
#   T1 = C1  best-performance config           (paper: ZB-L4-DR)
#   T2 = C2  lowest-latency cheap-media tier   (paper: ZB-L4-OP)
#   T3 = C4  fast codec + dense pool + cheap   (paper: ZS-L4-OP)
#   T4 = C9  latency/TCO gap filler on HBM     (paper: ZS-LO-DR)
#   T5 = C12 best memory-TCO savings config    (paper: ZS-DE-OP)
SELECTED_IDS = ("C1", "C2", "C4", "C9", "C12")


def selected() -> List[TierSpec]:
    return [_BY_ID[i] for i in SELECTED_IDS]


# The paper's 2-Tier baseline: Google's production config — zsmalloc + lzo
# backed by DRAM [36] => packed + int8 + hbm.
BASELINE_2T = _BY_ID["C6"]


@dataclasses.dataclass(frozen=True)
class TierSet:
    """DRAM/HBM (uncompressed, index 0) + N ordered compressed tiers.

    Tiers are ordered low-latency -> high-TCO-savings (paper §5). Placement
    vectors index into this set: 0 = uncompressed, 1..N = tiers[i-1].
    """

    tiers: Sequence[TierSpec]
    block_elems: int = 2048  # elements per managed block (4KB bf16 page)
    src_bytes_per_elem: int = 2

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def block_bytes(self) -> int:
        return self.block_elems * self.src_bytes_per_elem

    def latencies_s(self):
        """Lat_T per placement index (index 0 = DRAM = 0 overhead)."""
        return [0.0] + [t.access_latency_s(self.block_elems, self.src_bytes_per_elem) for t in self.tiers]

    def usd_per_source_byte(self):
        """$/source-byte per placement index (index 0 = uncompressed HBM)."""
        hbm = hw.COSTS.usd_per_byte("hbm")
        return [hbm] + [t.usd_per_source_byte(self.block_elems, self.src_bytes_per_elem) for t in self.tiers]

    def ratios(self):
        return [1.0] + [t.effective_ratio(self.block_elems, self.src_bytes_per_elem) for t in self.tiers]

    def media_devices(self):
        """MediaDevice per placement index (index 0 = uncompressed on-chip)."""
        from repro.media import devices as media_devices

        return [media_devices.get("hbm")] + [t.device for t in self.tiers]


def default_tierset(block_elems: int = 2048) -> TierSet:
    """DRAM + the 5 selected tiers (the paper's 6T evaluation config)."""
    return TierSet(tiers=tuple(selected()), block_elems=block_elems)


def baseline_2t_tierset(block_elems: int = 2048) -> TierSet:
    """DRAM + single compressed tier (Google production config [36])."""
    return TierSet(tiers=(BASELINE_2T,), block_elems=block_elems)


# 6T + the hardware-compressed CXL expander, ordered low-latency ->
# high-TCO-savings: X1 slots in right after C1 (its inline decode makes it
# faster than every host tier despite the expander hop).
CXL_SELECTED_IDS = ("C1", "X1", "C2", "C4", "C9", "C12")


def cxl_tierset(block_elems: int = 2048) -> TierSet:
    """DRAM + the 5 selected tiers + the cxl_hw tier (7T evaluation config)."""
    return TierSet(
        tiers=tuple(_BY_ID[i] for i in CXL_SELECTED_IDS), block_elems=block_elems
    )
