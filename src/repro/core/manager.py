"""TierScapeManager — the TS-Daemon analogue (paper §6.2-6.3).

Host-side controller owning:
  * the TierSet (DRAM/HBM + N software-defined compressed tiers),
  * per-region telemetry (exact or PEBS-emulated),
  * the placement vector,
  * the placement policy (2T threshold / waterfall / analytical),
  * live-measured per-tier compressibility,
  * stats: TCO, faults, migrations, daemon tax.

The engine (window simulator, serving KV cache, or tiered optimizer) calls
``record_*`` during a window, ``fault_back`` whenever it decompresses a region
on access, and ``end_window`` at window boundaries; ``end_window`` runs the
model and returns a MigrationPlan the engine executes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import analytical, tco
from repro.core.telemetry import PEBSNoise, RegionTelemetry
from repro.core.tiers import TierSet, baseline_2t_tierset, cxl_tierset, default_tierset
from repro.core.waterfall import WaterfallConfig, waterfall_step


@dataclasses.dataclass(frozen=True)
class ManagerConfig:
    policy: str  # "waterfall" | "analytical" | "2t"
    hotness_threshold: float = 0.0  # H_th for waterfall/2t (absolute counts)
    alpha: float = 0.5  # knob for analytical (1=max perf, 0=max TCO savings)
    window_steps: int = 64  # engine steps per profile window
    history_windows: int = 4  # averaging depth for the analytical model
    refault_fraction: float = 0.25
    tenant: str = ""  # owning tenant in multi-tenant deploys ("" = sole tenant)


@dataclasses.dataclass
class MigrationPlan:
    """end_window output: region moves the engine must execute."""

    regions: np.ndarray  # (M,) region ids to migrate
    src: np.ndarray  # (M,) old placement index
    dst: np.ndarray  # (M,) new placement index
    bytes_moved: int
    modeled_migration_s: float
    # Distinct (src, dst) pairs: a batched executor needs O(n_cohorts)
    # kernel dispatches for this plan, not O(M).
    n_cohorts: int = 0
    # Per-backing-device bandwidth charges of this plan: reads are billed to
    # each region's source device, writes to its destination device, with
    # the device's fixed per-op setup cost once per region.
    media_bytes_by_device: Dict[str, int] = dataclasses.field(default_factory=dict)
    media_s_by_device: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class WindowStats:
    window: int
    placement_hist: np.ndarray  # (N+1,) region counts per placement index
    tco_usd: float
    savings_pct: float
    faults: int
    fault_overhead_s: float  # Eq. 6 realized
    migrations: int
    migration_bytes: int
    daemon_s: float  # model eval + plan construction wall time
    modeled_migration_s: float
    migration_cohorts: int = 0  # distinct (src, dst) pairs = kernel dispatches
    # Window TCO report: migration traffic billed per backing-media device.
    media_bytes_by_device: Dict[str, int] = dataclasses.field(default_factory=dict)
    media_s_by_device: Dict[str, float] = dataclasses.field(default_factory=dict)


class TierScapeManager:
    def __init__(
        self,
        tierset: TierSet,
        n_regions: int,
        region_bytes: int,
        cfg: ManagerConfig,
        pebs: Optional[PEBSNoise] = None,
        seed: int = 0,
    ):
        if region_bytes % tierset.block_bytes != 0:
            raise ValueError("region_bytes must be a multiple of block_bytes")
        self.tierset = tierset
        self.n_regions = n_regions
        self.region_bytes = region_bytes
        self.blocks_per_region = region_bytes // tierset.block_bytes
        self.cfg = cfg
        self.telemetry = RegionTelemetry(
            n_regions, history_len=cfg.history_windows, pebs=pebs, seed=seed
        )
        self.placement = np.zeros(n_regions, dtype=np.int64)
        # Live-measured compressibility per tier (paper feeds measured ratios
        # to the model; start at nominal).
        self.measured_ratios = np.array(tierset.ratios()[1:], dtype=np.float64)
        # Fault latency at *block* (4KB-page analogue) granularity: faults
        # decompress individual blocks on demand, each paying the fixed
        # bookkeeping + pool + media-setup costs — exactly the paper's page
        # fault path. Region-granular latency (bulk decompress, fixed costs
        # paid once) is used for pricing migrations, not faults.
        self._lat_block = np.array(tierset.latencies_s(), dtype=np.float64)
        region_elems = region_bytes // tierset.src_bytes_per_elem
        self._lat_region = np.array(
            [0.0]
            + [t.access_latency_s(region_elems, tierset.src_bytes_per_elem) for t in tierset.tiers],
            dtype=np.float64,
        )
        # Vectorized migration-pricing tables, one entry per placement index
        # (0 = uncompressed DRAM). _plan is pure numpy over these.
        sbpe = tierset.src_bytes_per_elem
        self._stored_bytes = np.array(
            [region_bytes]
            + [t.stored_bytes(region_elems, sbpe) for t in tierset.tiers],
            dtype=np.int64,
        )
        self._compress_lat = np.array(
            [0.0] + [t.compress_latency_s(region_elems, sbpe) for t in tierset.tiers],
            dtype=np.float64,
        )
        codec_names = sorted({t.codec_name for t in tierset.tiers})
        self._codec_ids = np.array(
            [-1] + [codec_names.index(t.codec_name) for t in tierset.tiers],
            dtype=np.int64,
        )
        # Backing-media devices per placement index (media subsystem): the
        # plan bills migration reads/writes to these, and live contention
        # pressure fed back via ``note_media_charges`` inflates the
        # planning latencies so placement prices bandwidth contention.
        self._devices = tierset.media_devices()
        self._dev_names = [d.name for d in self._devices]
        self._dev_read_bw = np.array([d.read_bw for d in self._devices])
        self._dev_write_bw = np.array([d.write_bw for d in self._devices])
        self._dev_fixed_s = np.array([d.fixed_latency_s for d in self._devices])
        self.media_pressure: Dict[str, float] = {}
        # Per-device wire ratio for THIS tenant's data on compressed media
        # (inline hardware compression: nominal stored bytes / bytes actually
        # moved or resident). 1.0 everywhere until ``note_media_ratio`` feeds
        # observed line compression at a window boundary. Distinct from the
        # shared AdaptiveMediaDevice EWMA, which tracks the byte-weighted
        # tenant *mix* and governs service times; this dict governs how many
        # wire bytes this tenant's plans are billed for.
        self.media_ratio: Dict[str, float] = {}
        self._window = 0
        # In-engine would-have-touched mass for host-resident regions (the
        # fused decode kernel's sentinel telemetry). Accumulates within the
        # profile window, feeds ONLY the prefetch predictor — never the
        # placement-driving access counts — and resets at window close.
        self.host_mass = np.zeros(n_regions, dtype=np.float64)
        self._fault_counts = np.zeros(n_regions, dtype=np.int64)
        self._fault_overhead_s = 0.0
        self.history: List[WindowStats] = []
        self.total_daemon_s = 0.0
        self._pending_daemon_s = 0.0

    # ------------------------------------------------------------------ API
    def record_access_counts(self, counts: np.ndarray) -> None:
        self.telemetry.record(counts)

    def record_access_indices(self, idx: np.ndarray, weights=None) -> None:
        self.telemetry.record_indices(idx, weights)

    def record_host_mass(self, counts: np.ndarray) -> None:
        """Ingest would-have-touched mass for host-resident regions.

        The decode kernel's host sentinel rows score each host page's key
        centroid against live queries — the softmax mass decode *would*
        have spent on the page had it been device-resident. Telemetry for
        the warming-page predictor only (``prefetch_candidates``): it never
        enters ``telemetry``'s access counts, so placement decisions — and
        therefore prefetch's oracle-identical-placement guarantee — are
        untouched by construction."""
        self.host_mass += counts

    def fault_back(self, region_ids: np.ndarray, n_blocks=1) -> np.ndarray:
        """Engine faulted ``n_blocks`` blocks of each region on access.

        Charges Eq. 5 overhead (n_blocks * Lat_T at block granularity) and
        returns the per-region overhead. Regions whose faulted fraction
        reaches ``refault_fraction`` restart from DRAM (paper §6.3: a region
        restarts its journey when a major portion faulted back); partially
        faulted regions stay placed, their faulted blocks now living
        uncompressed (we conservatively keep charging them as compressed on
        later accesses only via fresh fault calls from the engine).
        """
        region_ids = np.atleast_1d(region_ids)
        n_blocks = np.broadcast_to(np.asarray(n_blocks, dtype=np.float64), region_ids.shape)
        src = self.placement[region_ids]
        lat = self._lat_block[src] * n_blocks
        faulted = src > 0
        self._fault_counts[region_ids[faulted]] += n_blocks[faulted].astype(np.int64)
        self._fault_overhead_s += float(lat[faulted].sum())
        move = faulted & (n_blocks >= self.cfg.refault_fraction * self.blocks_per_region)
        self.placement[region_ids[move]] = 0
        return np.where(faulted, lat, 0.0)

    def discount_fault_overhead(self, seconds: float) -> None:
        """Refund fault latency that was hidden (not avoided): a prefetched
        region's swap-in happened ahead of its first touch, so the fault's
        bookkeeping (counts, refault move) stands but its stall does not."""
        self._fault_overhead_s = max(self._fault_overhead_s - float(seconds), 0.0)

    def access_latency_s(self, region_ids: np.ndarray) -> np.ndarray:
        """Latency to access each region under the current placement."""
        src = self.placement[np.atleast_1d(region_ids)]
        return self._lat_region[src]

    @property
    def region_latencies_s(self) -> np.ndarray:
        """Per-placement-index fault latency at region granularity."""
        return self._lat_region

    def update_measured_ratio(self, tier_index: int, ratio: float, ema: float = 0.25) -> None:
        """Feed back actually-achieved compressibility for tier (1-based)."""
        i = tier_index - 1
        self.measured_ratios[i] = (1 - ema) * self.measured_ratios[i] + ema * ratio

    # ------------------------------------------------------------- prefetch
    def prefetch_candidates(
        self, eligible: np.ndarray, top_k: int, max_regions: int
    ) -> np.ndarray:
        """Warming-page predictor for speculative prefetch (readahead).

        Mid-window trend detection: a region is a candidate when its access
        rate in the *accumulating* profile window already exceeds its last
        closed window (``delta > 0`` — it is warming right now) and its
        projected hotness (``accum + delta``) ranks within the global
        top-``top_k`` — i.e. it is rising toward the promotion frontier and
        this window's placement model will plausibly pull it up-tier.
        Purely a read of telemetry: calling this never perturbs placement,
        so a speculative consumer stays bit-identical to a non-speculative
        run by construction.

        Host-resident regions additionally qualify through their in-engine
        would-have-touched mass (``record_host_mass``): live decode traffic
        scoring a host page's sentinel IS the warming signal, so it joins
        the trend term in the projection and makes a region a candidate
        even when the PEBS-analogue feed never sampled it. With no host
        mass recorded the predictor is exactly the trend detector above.

        Returns up to ``max_regions`` region ids, hottest-projected first
        (deterministic: ties broken by region id). Empty until one window
        has closed — there is no baseline to rise from before that.
        """
        if self.telemetry._windows_closed < 1 or max_regions <= 0:
            return np.empty(0, np.int64)
        h_now = self.telemetry._accum
        h_prev = self.telemetry.history[0]
        delta = h_now - h_prev
        projected = h_now + np.maximum(delta, 0.0) + self.host_mass
        mask = np.asarray(eligible, bool) & ((delta > 0) | (self.host_mass > 0))
        if not mask.any():
            return np.empty(0, np.int64)
        k = int(min(max(top_k, 1), self.n_regions))
        frontier = np.partition(projected, self.n_regions - k)[self.n_regions - k]
        cand = np.where(mask & (projected >= frontier))[0]
        if cand.size == 0:
            return cand.astype(np.int64)
        order = np.lexsort((cand, -projected[cand]))
        return cand[order][:max_regions].astype(np.int64)

    # --------------------------------------------------------------- media
    def note_media_charges(
        self, busy_s_by_device: Dict[str, float], window_s: float, ema: float = 0.5
    ) -> None:
        """Feed back executed per-device busy time for one window.

        Utilization (busy / window, clipped to 1) is EMA-folded into
        ``media_pressure``; the analytical policy prices it through
        ``contended_latencies_s`` so a saturated swap device makes its tiers
        look slower and placement routes around the contention.
        """
        for name, busy_s in busy_s_by_device.items():
            rho = min(max(busy_s, 0.0) / max(window_s, 1e-30), 1.0)
            self.media_pressure[name] = (
                (1 - ema) * self.media_pressure.get(name, 0.0) + ema * rho
            )

    def note_media_ratio(self, device: str, ratio: float, ema: float = 0.25) -> None:
        """Feed back this tenant's observed wire-compression ratio on one
        backing device (>= 1.0). Window-boundary only — callers must never
        fold observations mid-window, or replay determinism breaks."""
        r = max(float(ratio), 1.0)
        prev = self.media_ratio.get(device, r)
        self.media_ratio[device] = (1 - ema) * prev + ema * r

    def contended_latencies_s(self) -> np.ndarray:
        """Per-placement-index planning latency with queueing inflation.

        M/M/1-style: a device at utilization rho serves a newcomer
        ~1/(1-rho) slower. With no recorded pressure this is exactly
        ``_lat_region`` (planning behavior unchanged until charges arrive).
        """
        if not self.media_pressure:
            return self._lat_region
        lat = self._lat_region.copy()
        for i, name in enumerate(self._dev_names):
            rho = min(self.media_pressure.get(name, 0.0), 0.95)
            lat[i] *= 1.0 + rho / (1.0 - rho)
        return lat

    # -------------------------------------------------------------- window
    # The window boundary is split into three phases so a multi-tenant
    # BudgetArbiter can interpose between them: close telemetry for every
    # tenant, waterfill the global budget, then plan+commit each tenant
    # against its allotted budget. ``end_window`` composes all three for
    # single-tenant callers (unchanged behavior).
    def close_telemetry(self) -> np.ndarray:
        """Phase 1: close the profile window; returns the window's hotness."""
        t0 = time.perf_counter()
        hotness = self.telemetry.close_window()
        # Would-have-touched mass is a within-window signal: the predictor
        # reads it mid-window; the boundary starts a fresh accumulation.
        self.host_mass[:] = 0.0
        self._pending_daemon_s += time.perf_counter() - t0
        return hotness

    def plan_placement(
        self,
        hotness: np.ndarray,
        budget: Optional[float] = None,
        avg_hotness: Optional[np.ndarray] = None,
        option_costs: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Phase 2: run the placement policy; returns the proposed placement.

        ``budget`` overrides the analytical policy's self-derived alpha budget
        (USD) — this is how an arbiter-allotted per-tenant budget flows in.
        Waterfall/2T ignore it (they are threshold-, not budget-driven).
        ``avg_hotness``/``option_costs`` let an arbiter pass the values it
        already computed for the waterfill instead of recomputing them here.
        """
        t0 = time.perf_counter()
        old = self.placement
        if self.cfg.policy in ("waterfall", "2t"):
            fault_frac = (self._fault_counts > 0).astype(np.float64)
            new = waterfall_step(
                old,
                hotness,
                fault_frac,
                self.tierset.n_tiers,
                WaterfallConfig(self.cfg.hotness_threshold, self.cfg.refault_fraction),
            )
        elif self.cfg.policy == "analytical":
            avg_hot = (
                avg_hotness
                if avg_hotness is not None
                else self.telemetry.averaged_hotness(self.cfg.history_windows)
            )
            if option_costs is None:
                option_costs = tco.usd_per_region(
                    self.tierset, self.region_bytes, self.measured_ratios
                )
            if budget is None:
                budget = tco.budget(
                    self.tierset,
                    self.n_regions,
                    self.region_bytes,
                    self.cfg.alpha,
                    self.measured_ratios,
                )
            sol = analytical.solve_greedy(
                avg_hot, option_costs, self.contended_latencies_s(), budget
            )
            new = sol.placement
        else:
            raise ValueError(f"unknown policy {self.cfg.policy!r}")
        self._pending_daemon_s += time.perf_counter() - t0
        return new

    def commit_placement(self, new: np.ndarray) -> MigrationPlan:
        """Phase 3: adopt ``new``, price the migration, record window stats."""
        t0 = time.perf_counter()
        old = self.placement
        moved = np.where(new != old)[0]
        plan = self._plan(moved, old[moved], new[moved])
        self.placement = new
        daemon_s = time.perf_counter() - t0 + self._pending_daemon_s
        self._pending_daemon_s = 0.0
        self.total_daemon_s += daemon_s + plan.modeled_migration_s

        self.history.append(
            WindowStats(
                window=self._window,
                placement_hist=np.bincount(new, minlength=self.tierset.n_tiers + 1),
                tco_usd=tco.tco_nt(self.tierset, new, self.region_bytes, self.measured_ratios),
                savings_pct=tco.savings_pct(
                    self.tierset, new, self.region_bytes, self.measured_ratios
                ),
                faults=int(self._fault_counts.sum()),
                fault_overhead_s=self._fault_overhead_s,
                migrations=len(moved),
                migration_bytes=plan.bytes_moved,
                daemon_s=daemon_s,
                modeled_migration_s=plan.modeled_migration_s,
                migration_cohorts=plan.n_cohorts,
                media_bytes_by_device=plan.media_bytes_by_device,
                media_s_by_device=plan.media_s_by_device,
            )
        )
        self._window += 1
        self._fault_counts[:] = 0
        self._fault_overhead_s = 0.0
        return plan

    def end_window(self, budget: Optional[float] = None) -> MigrationPlan:
        return self.commit_placement(self.plan_placement(self.close_telemetry(), budget))

    def _plan(self, regions: np.ndarray, src: np.ndarray, dst: np.ndarray) -> MigrationPlan:
        """Price a migration batch — vectorized numpy over (src, dst) cohorts.
        Same-codec moves skip decode/encode (paper §6.1 notes this
        optimization; we implement it). ``_plan_loop`` is the per-page
        reference semantics this must match (equivalence-tested)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.size == 0:
            return MigrationPlan(regions, src, dst, 0, 0.0, 0)
        read_b = self._stored_bytes[src]
        write_b = self._stored_bytes[dst]
        same_codec = (self._codec_ids[src] == self._codec_ids[dst]) & (src > 0) & (dst > 0)
        # Fast path: media-to-media copy, no transcode.
        copy_s = (read_b + write_b) / 819e9
        # Transcode path: decode at src granularity + encode into dst.
        # _lat_region[0] and _compress_lat[0] are 0, matching "DRAM endpoints
        # pay no codec cost".
        code_s = self._lat_region[src] + self._compress_lat[dst]
        total_s = float(np.where(same_codec, copy_s, code_s).sum())
        total_bytes = int((read_b + write_b).sum())
        n_cohorts = int(np.unique(src * (self.tierset.n_tiers + 1) + dst).size)
        media_bytes, media_s = self._media_charges(src, dst, read_b, write_b)
        return MigrationPlan(
            regions, src, dst, total_bytes, total_s, n_cohorts,
            media_bytes_by_device=media_bytes, media_s_by_device=media_s,
        )

    def _media_charges(
        self, src: np.ndarray, dst: np.ndarray, read_b: np.ndarray, write_b: np.ndarray
    ):
        """Bill a migration batch to its backing devices: each region pays a
        read op on its source device and a write op on its destination
        device (fixed setup + bytes/bandwidth). Indexes sharing a physical
        device (e.g. both host tiers behind one PCIe link) aggregate — that
        aggregation is the shared-bandwidth contention the arbiter sees.

        Devices with an observed wire ratio (``media_ratio``, inline
        hardware compression) are billed *wire* bytes: nominal stored bytes
        divided by the tenant's committed ratio. The ratio only moves at
        window boundaries, so identical plans bill identically on replay."""
        media_bytes: Dict[str, int] = {}
        media_s: Dict[str, float] = {}
        for idx in range(len(self._devices)):
            name = self._dev_names[idx]
            r_mask = src == idx
            w_mask = dst == idx
            n_ops = int(r_mask.sum()) + int(w_mask.sum())
            if n_ops == 0:
                continue
            ratio = self.media_ratio.get(name, 1.0)
            rb = int(int(read_b[r_mask].sum()) / ratio)
            wb = int(int(write_b[w_mask].sum()) / ratio)
            t = (
                n_ops * float(self._dev_fixed_s[idx])
                + rb / float(self._dev_read_bw[idx])
                + wb / float(self._dev_write_bw[idx])
            )
            media_bytes[name] = media_bytes.get(name, 0) + rb + wb
            media_s[name] = media_s.get(name, 0.0) + t
        return media_bytes, media_s

    def _plan_loop(self, regions: np.ndarray, src: np.ndarray, dst: np.ndarray) -> MigrationPlan:
        """Per-page reference pricing (the pre-batching executor semantics).
        Kept as the oracle for the vectorized ``_plan`` and for dispatch-count
        comparisons in benchmarks; not used on the window hot path."""
        elems = self.tierset.block_elems * self.blocks_per_region
        sbpe = self.tierset.src_bytes_per_elem
        total_bytes = 0
        total_s = 0.0
        specs = [None] + list(self.tierset.tiers)
        for s, d in zip(src, dst):
            s_spec, d_spec = specs[int(s)], specs[int(d)]
            read_b = self.region_bytes if s_spec is None else s_spec.stored_bytes(elems, sbpe)
            write_b = self.region_bytes if d_spec is None else d_spec.stored_bytes(elems, sbpe)
            total_bytes += read_b + write_b
            if s_spec is not None and d_spec is not None and s_spec.codec_name == d_spec.codec_name:
                total_s += read_b / 819e9 + write_b / 819e9
            else:
                if s_spec is not None:
                    total_s += s_spec.access_latency_s(elems, sbpe)
                if d_spec is not None:
                    total_s += d_spec.compress_latency_s(elems, sbpe)
        return MigrationPlan(regions, src, dst, total_bytes, total_s)

    # -------------------------------------------------------------- views
    @property
    def current_savings_pct(self) -> float:
        return tco.savings_pct(
            self.tierset, self.placement, self.region_bytes, self.measured_ratios
        )


# ---------------------------------------------------------------------------
# Policy presets (paper §7.1 model configurations)
# ---------------------------------------------------------------------------


def make_manager(
    config_name: str,
    n_regions: int,
    region_bytes: int = 2 * 1024 * 1024,
    thresholds: dict | None = None,
    pebs: Optional[PEBSNoise] = None,
    seed: int = 0,
    window_steps: int = 64,
) -> TierScapeManager:
    """Build a manager from a paper config name.

    Names: ``2T-C|2T-M|2T-A`` (DRAM + Google-production single tier),
    ``6T-WF-C|M|A`` (waterfall on DRAM+5 tiers), ``6T-AM-0.9|0.5|0.1``
    (analytical), ``7T-CX-0.9|0.5|0.1`` (analytical over DRAM + 5 tiers +
    the hardware-compressed CXL expander). Thresholds dict maps C/M/A ->
    absolute H_th (workload specific, like the paper's Memcached 50/100/250).
    """
    thresholds = thresholds or {"C": 50.0, "M": 100.0, "A": 250.0}
    name = config_name.upper()
    if name.startswith("2T-"):
        level = name.split("-")[1]
        ts = baseline_2t_tierset()
        cfg = ManagerConfig(
            policy="2t", hotness_threshold=thresholds[level], window_steps=window_steps
        )
    elif name.startswith("6T-WF-"):
        level = name.split("-")[2]
        ts = default_tierset()
        cfg = ManagerConfig(
            policy="waterfall", hotness_threshold=thresholds[level], window_steps=window_steps
        )
    elif name.startswith("6T-AM-"):
        alpha = float(name.split("AM-")[1])
        ts = default_tierset()
        cfg = ManagerConfig(policy="analytical", alpha=alpha, window_steps=window_steps)
    elif name.startswith("7T-CX-"):
        alpha = float(name.split("CX-")[1])
        ts = cxl_tierset()
        cfg = ManagerConfig(policy="analytical", alpha=alpha, window_steps=window_steps)
    else:
        raise ValueError(f"unknown config {config_name!r}")
    return TierScapeManager(ts, n_regions, region_bytes, cfg, pebs=pebs, seed=seed)
