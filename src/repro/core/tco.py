"""Memory-TCO model — Eq. 9-12 of the paper, evaluated live.

All functions take a placement vector (region -> placement index, 0 = DRAM/
HBM uncompressed, 1..N = compressed tiers) plus per-region sizes, and price
the configuration with the TierSet's cost model. ``measured_ratios`` lets the
caller substitute live-measured compressibility for the nominal ratios — the
paper's analytical model consumes measured per-tier compressibility the same
way (§7.4: the model sees deflate achieving only 2x on Memcached).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import hw
from repro.core.tiers import TierSet


def usd_per_region(
    tierset: TierSet,
    region_bytes: int,
    measured_ratios: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """USD cost of holding one region in each placement index. Shape (N+1,).

    cost(0)   = region_bytes * USD_hbm                       (Eq. 9 per page)
    cost(y>0) = region_bytes * (1/C_Ty) * USD_media(Ty)      (Eq. 12 term)
    """
    out = np.empty(tierset.n_tiers + 1, dtype=np.float64)
    out[0] = region_bytes * hw.COSTS.usd_per_byte("hbm")
    for y, t in enumerate(tierset.tiers, start=1):
        if measured_ratios is not None and measured_ratios[y - 1] > 0:
            ratio = measured_ratios[y - 1]
        else:
            ratio = t.effective_ratio(tierset.block_elems, tierset.src_bytes_per_elem)
        out[y] = region_bytes * (1.0 / ratio) * hw.COSTS.usd_per_byte(t.media)
    return out


def tco_max(n_regions: int, region_bytes: int) -> float:
    """Eq. 9: everything uncompressed in DRAM/HBM."""
    return n_regions * region_bytes * hw.COSTS.usd_per_byte("hbm")


def tco_min(
    tierset: TierSet,
    n_regions: int,
    region_bytes: int,
    measured_ratios: Optional[Sequence[float]] = None,
) -> float:
    """Eq. 10: everything in the best-TCO tier (min over tiers, to be safe)."""
    costs = usd_per_region(tierset, region_bytes, measured_ratios)
    return n_regions * float(costs[1:].min())


def tco_nt(
    tierset: TierSet,
    placement: np.ndarray,
    region_bytes: int,
    measured_ratios: Optional[Sequence[float]] = None,
) -> float:
    """Eq. 12: cost of the current placement."""
    costs = usd_per_region(tierset, region_bytes, measured_ratios)
    return float(costs[placement].sum())


def savings_pct(
    tierset: TierSet,
    placement: np.ndarray,
    region_bytes: int,
    measured_ratios: Optional[Sequence[float]] = None,
) -> float:
    """Memory-TCO savings relative to all-DRAM, in percent (paper's metric).

    An empty placement (zero-region tenant) has nothing to save: 0.0, not a
    division by zero.
    """
    mx = tco_max(len(placement), region_bytes)
    if mx <= 0.0:
        return 0.0
    return 100.0 * (mx - tco_nt(tierset, placement, region_bytes, measured_ratios)) / mx


def budget(
    tierset: TierSet,
    n_regions: int,
    region_bytes: int,
    alpha: float,
    measured_ratios: Optional[Sequence[float]] = None,
) -> float:
    """Eq. 2's constraint bound: TCO_min + alpha * MTS  (MTS = Eq. 1)."""
    mx = tco_max(n_regions, region_bytes)
    mn = tco_min(tierset, n_regions, region_bytes, measured_ratios)
    return mn + alpha * (mx - mn)


# ---------------------------------------------------------------------------
# Fleet-level aggregation (multi-tenant: N managers share the substrate)
# ---------------------------------------------------------------------------


def fleet_tco_usd(managers: Sequence) -> float:
    """Aggregate memory TCO across tenant managers (Eq. 12 summed).

    An empty manager sequence is an empty fleet: 0.0.
    """
    return float(sum(
        tco_nt(m.tierset, m.placement, m.region_bytes, m.measured_ratios)
        for m in managers
    ))


def fleet_savings_pct(managers: Sequence) -> float:
    """Fleet TCO savings vs all-DRAM, weighted by each tenant's footprint.

    An empty fleet — no managers, or only zero-region tenants — saves
    nothing: 0.0, not a division by zero.
    """
    managers = list(managers)
    mx = sum(tco_max(m.n_regions, m.region_bytes) for m in managers)
    if mx <= 0.0:
        return 0.0
    return 100.0 * (mx - fleet_tco_usd(managers)) / mx
