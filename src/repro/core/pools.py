"""Compressed-pool data plane — the zswap-pool analogue.

Two halves, split the way a production TPU serving engine splits them:

  * **Device side** (``TierPool`` pytree): fixed-capacity uint8 payload +
    f32 scale arrays living in HBM (or host memory via JAX memory kinds on
    real hardware). All reads/writes are functional ``.at[]`` updates and are
    jit-compatible; the tiered-attention Pallas kernel reads these arrays
    directly.
  * **Host side** (``SlotAllocator``): slot free-lists and block->slot maps.
    Allocation policy runs on the daemon core (it is part of the daemon tax),
    and only integer slot indices cross into jit — exactly how page tables
    stay on the host in the paper's design.

Physical layout note: both ``slab`` and ``packed`` pools store one block per
row here; the *byte accounting* (slab padding, packed alignment + index
overhead) and the *latency model* (gather indirection) come from
``TierSpec.stored_bytes`` / ``access_latency_s``. On real hardware ``packed``
would be an offset-indexed flat buffer; the row layout preserves identical
semantics and identical accounting, which is what the placement models
consume. Recorded as an adaptation in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codecs import CODECS
from repro.core.tiers import TierSpec


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TierPool:
    """Device-side storage for one compressed tier."""

    payload: jax.Array  # uint8 [capacity, payload_bytes]
    scales: jax.Array  # f32 [capacity, n_groups] (n_groups >= 1)

    @property
    def capacity(self) -> int:
        return self.payload.shape[0]


def make_tier_pool(spec: TierSpec, capacity_blocks: int, block_elems: int) -> TierPool:
    codec = spec.codec
    pbytes = codec.payload_bytes(block_elems)
    ngroups = max(codec.scale_bytes(block_elems) // 4, 1)
    return TierPool(
        payload=jnp.zeros((capacity_blocks, pbytes), dtype=jnp.uint8),
        scales=jnp.ones((capacity_blocks, ngroups), dtype=jnp.float32),
    )


def pool_write(pool: TierPool, slot, payload_row, scales_row) -> TierPool:
    return TierPool(
        payload=pool.payload.at[slot].set(payload_row),
        scales=pool.scales.at[slot].set(scales_row),
    )


def pool_compress_block(spec: TierSpec, pool: TierPool, slot, block) -> TierPool:
    """Encode ``block`` with the tier's codec and store it at ``slot``."""
    enc = spec.codec.encode(block)
    scales = enc.scales
    if scales.shape[0] == 0:
        scales = jnp.ones((1,), jnp.float32)
    return pool_write(pool, slot, enc.payload, scales)


def pool_decompress_block(spec: TierSpec, pool: TierPool, slot, shape, dtype=jnp.bfloat16):
    from repro.core.codecs import Encoded

    enc = Encoded(payload=pool.payload[slot], scales=pool.scales[slot], codec=spec.codec_name)
    return spec.codec.decode(enc, shape, dtype)


class SlotAllocator:
    """Host-side slot management for one tier pool (daemon side)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._owner: dict[int, int] = {}  # slot -> block_id

    def alloc(self, block_id: int) -> int:
        if not self._free:
            raise MemoryError("tier pool exhausted")
        slot = self._free.pop()
        self._owner[slot] = block_id
        return slot

    def free(self, slot: int) -> None:
        if slot in self._owner:
            del self._owner[slot]
            self._free.append(slot)

    @property
    def used(self) -> int:
        return self.capacity - len(self._free)


@dataclasses.dataclass
class BlockTable:
    """Host-side block -> (placement, slot) mapping for a managed store."""

    n_blocks: int

    def __post_init__(self):
        self.placement = np.zeros(self.n_blocks, dtype=np.int64)  # 0 = uncompressed
        self.slot = np.full(self.n_blocks, -1, dtype=np.int64)

    def move(self, block_id: int, new_placement: int, new_slot: int) -> Tuple[int, int]:
        old = (int(self.placement[block_id]), int(self.slot[block_id]))
        self.placement[block_id] = new_placement
        self.slot[block_id] = new_slot
        return old
