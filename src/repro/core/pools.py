"""Compressed-pool data plane — the zswap-pool analogue.

Two halves, split the way a production TPU serving engine splits them:

  * **Device side** (``TierPool`` pytree): fixed-capacity uint8 payload +
    f32 scale arrays living in HBM (or host memory via JAX memory kinds on
    real hardware). All reads/writes are functional ``.at[]`` updates and are
    jit-compatible; the tiered-attention Pallas kernel reads these arrays
    directly.
  * **Host side** (``SlotAllocator``): slot free-lists and block->slot maps.
    Allocation policy runs on the daemon core (it is part of the daemon tax),
    and only integer slot indices cross into jit — exactly how page tables
    stay on the host in the paper's design.

Physical layout note: both ``slab`` and ``packed`` pools store one block per
row here; the *byte accounting* (slab padding, packed alignment + index
overhead) and the *latency model* (gather indirection) come from
``TierSpec.stored_bytes`` / ``access_latency_s``. On real hardware ``packed``
would be an offset-indexed flat buffer; the row layout preserves identical
semantics and identical accounting, which is what the placement models
consume. Recorded as an adaptation in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiers import TierSpec


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TierPool:
    """Device-side storage for one compressed tier."""

    payload: jax.Array  # uint8 [capacity, payload_bytes]
    scales: jax.Array  # f32 [capacity, n_groups] (n_groups >= 1)

    @property
    def capacity(self) -> int:
        return self.payload.shape[0]


def make_tier_pool(spec: TierSpec, capacity_blocks: int, block_elems: int) -> TierPool:
    codec = spec.codec
    pbytes = codec.payload_bytes(block_elems)
    ngroups = max(codec.scale_bytes(block_elems) // 4, 1)
    return TierPool(
        payload=jnp.zeros((capacity_blocks, pbytes), dtype=jnp.uint8),
        scales=jnp.ones((capacity_blocks, ngroups), dtype=jnp.float32),
    )


def pool_write(pool: TierPool, slot, payload_row, scales_row) -> TierPool:
    return TierPool(
        payload=pool.payload.at[slot].set(payload_row),
        scales=pool.scales.at[slot].set(scales_row),
    )


def pool_compress_block(spec: TierSpec, pool: TierPool, slot, block) -> TierPool:
    """Encode ``block`` with the tier's codec and store it at ``slot``."""
    enc = spec.codec.encode(block)
    scales = enc.scales
    if scales.shape[0] == 0:
        scales = jnp.ones((1,), jnp.float32)
    return pool_write(pool, slot, enc.payload, scales)


def pool_decompress_block(spec: TierSpec, pool: TierPool, slot, shape, dtype=jnp.bfloat16):
    from repro.core.codecs import Encoded

    enc = Encoded(payload=pool.payload[slot], scales=pool.scales[slot], codec=spec.codec_name)
    return spec.codec.decode(enc, shape, dtype)


class SlotAllocator:
    """Host-side slot management for one tier pool (daemon side).

    In multi-tenant deploys the pool is shared: ``tenant_quota`` caps how many
    slots each tenant may hold concurrently (a hard per-tenant reservation,
    so one tenant cannot starve another's tier — the MaxMem failure mode).

    ``base`` offsets the initial free list to ``[base, base + capacity)``:
    under the codec-class-major layout slots are GLOBAL rows of the shared
    class buffer, and each pool starts with its own contiguous row range of
    the class partition. ``exchange_slots`` may interleave ranges over time
    (same-class migrations transfer row ownership instead of copying
    payloads); capacity accounting is unaffected.
    """

    def __init__(
        self,
        capacity: int,
        tenant_quota: Optional[Dict[str, int]] = None,
        base: int = 0,
    ):
        self.capacity = capacity
        self.base = base
        if tenant_quota is not None and sum(tenant_quota.values()) > capacity:
            raise ValueError("tenant quotas exceed pool capacity")
        self.tenant_quota = tenant_quota
        self._free: List[int] = list(range(base + capacity - 1, base - 1, -1))
        self._owner: dict[int, int] = {}  # slot -> block_id
        self._slot_tenant: Dict[int, str] = {}
        self._tenant_used: Dict[str, int] = {}

    def alloc(self, block_id: int, tenant: Optional[str] = None) -> int:
        if not self._free:
            raise MemoryError("tier pool exhausted")
        if self.tenant_quota is not None:
            # Quotas are a hard contract: every alloc must be attributable,
            # or untenanted calls would drain the pool uncounted.
            if tenant is None:
                raise ValueError("tenant required when tenant_quota is set")
            if tenant not in self.tenant_quota:
                raise KeyError(f"unknown tenant {tenant!r}")
            if self._tenant_used.get(tenant, 0) >= self.tenant_quota[tenant]:
                raise MemoryError(f"tenant {tenant!r} quota exhausted")
        slot = self._free.pop()
        self._owner[slot] = block_id
        if tenant is not None:
            self._slot_tenant[slot] = tenant
            self._tenant_used[tenant] = self._tenant_used.get(tenant, 0) + 1
        return slot

    def free(self, slot: int) -> None:
        """Release an owned slot back to the free list. Freeing a slot this
        allocator does not own raises: a silent no-op here masks double-free
        and stale-page-table bugs, which global class-row addressing turns
        from harmless accounting drift into cross-pool payload corruption."""
        if slot not in self._owner:
            raise KeyError(
                f"free of unowned slot {slot} (double free or stale table?)"
            )
        del self._owner[slot]
        self._free.append(slot)
        tenant = self._slot_tenant.pop(slot, None)
        if tenant is not None:
            self._tenant_used[tenant] -= 1

    @property
    def used(self) -> int:
        return self.capacity - len(self._free)

    def used_by(self, tenant: str) -> int:
        return self._tenant_used.get(tenant, 0)


def exchange_slots(
    src: "SlotAllocator",
    dst: "SlotAllocator",
    slot: int,
    block_id: int,
    tenant: Optional[str] = None,
) -> int:
    """Transfer ownership of physical row ``slot`` from ``src`` to ``dst``
    without moving any payload — the class-major same-codec migration: the
    page's bytes stay in place in the shared class buffer and only the
    bookkeeping moves. ``dst`` hands one of its free rows back to ``src`` so
    both allocators conserve (free + owned) == capacity; over time the
    pools' row ranges interleave, which is fine — rows are global class
    rows, not per-pool indices. ``dst`` tenant quota is enforced exactly
    like ``alloc``. Returns ``slot`` (the page's row, unchanged)."""
    if slot not in src._owner:
        raise KeyError(f"exchange of slot {slot} not owned by source pool")
    if not dst._free:
        raise MemoryError("tier pool exhausted")
    if dst.tenant_quota is not None:
        if tenant is None:
            raise ValueError("tenant required when tenant_quota is set")
        if tenant not in dst.tenant_quota:
            raise KeyError(f"unknown tenant {tenant!r}")
        if dst._tenant_used.get(tenant, 0) >= dst.tenant_quota[tenant]:
            raise MemoryError(f"tenant {tenant!r} quota exhausted")
    # Release on src, but route the row's free-list credit to dst's range:
    # dst donates a free row to src in its place.
    del src._owner[slot]
    st = src._slot_tenant.pop(slot, None)
    if st is not None:
        src._tenant_used[st] -= 1
    src._free.append(dst._free.pop())
    dst._owner[slot] = block_id
    if tenant is not None:
        dst._slot_tenant[slot] = tenant
        dst._tenant_used[tenant] = dst._tenant_used.get(tenant, 0) + 1
    return slot


@dataclasses.dataclass(frozen=True)
class PoolRange:
    """One pool's initial slice of its codec class's shared row space."""

    name: str
    bits: int
    base: int
    capacity: int


class ClassPartition:
    """Codec-class-major row partition over an ordered set of tier pools.

    ``specs`` is an ordered sequence of ``(name, bits, capacity)``; pools of
    the same codec width stack into one shared class buffer, each owning the
    contiguous global-row range ``[base, base + capacity)`` in spec order.
    ``class_rows`` is the total buffer height per codec class (min 1 so an
    empty class still materializes a dummy row for the kernel operands —
    which ``TIER_INVALID`` masking guarantees is never addressed)."""

    def __init__(self, specs: Sequence[Tuple[str, int, int]]):
        self.ranges: Dict[str, PoolRange] = {}
        off: Dict[int, int] = {}
        for name, bits, cap in specs:
            if name in self.ranges:
                raise ValueError(f"duplicate pool name {name!r}")
            b = off.get(int(bits), 0)
            self.ranges[name] = PoolRange(name, int(bits), b, int(cap))
            off[int(bits)] = b + int(cap)
        self._rows = off

    def base(self, name: str) -> int:
        return self.ranges[name].base

    def class_rows(self, bits: int) -> int:
        return max(self._rows.get(int(bits), 0), 1)


class TenantLedger:
    """Per-tenant region accounting + reservations on shared tier pools.

    Tracks, per (tenant, placement index), how many regions the tenant holds
    (``usage``, written by the arbiter each window) and how many it has
    reserved ahead of migration (``reserved``). Capacity is fleet-wide per
    tier; ``headroom``/``oversubscribed`` are what the arbiter's capacity
    reconciliation enforces.
    """

    def __init__(self, tenants: Sequence[str], capacity_regions: np.ndarray):
        self.tenants = list(tenants)
        self._idx = {t: i for i, t in enumerate(self.tenants)}
        if len(self._idx) != len(self.tenants):
            raise ValueError("tenant names must be unique")
        self.capacity = np.asarray(capacity_regions, dtype=np.float64)
        self.usage = np.zeros((len(self.tenants), self.capacity.size), dtype=np.int64)
        self.reserved = np.zeros_like(self.usage)

    def index(self, tenant: str) -> int:
        return self._idx[tenant]

    def set_usage(self, tenant: str, per_tier_regions: np.ndarray) -> None:
        per_tier_regions = np.asarray(per_tier_regions, dtype=np.int64)
        if per_tier_regions.shape != (self.capacity.size,):
            raise ValueError("usage vector must have one entry per placement index")
        self.usage[self._idx[tenant]] = per_tier_regions

    def reserve(self, tenant: str, tier: int, n_regions: int = 1) -> bool:
        """Reserve migration headroom; False when the tier cannot hold it."""
        if self.headroom(tier) < n_regions:
            return False
        self.reserved[self._idx[tenant], tier] += n_regions
        return True

    def release(self, tenant: str, tier: int, n_regions: int = 1) -> None:
        t = self._idx[tenant]
        self.reserved[t, tier] = max(self.reserved[t, tier] - n_regions, 0)

    def headroom(self, tier: int) -> float:
        return float(
            self.capacity[tier] - self.usage[:, tier].sum() - self.reserved[:, tier].sum()
        )

    def tenant_usage(self, tenant: str) -> np.ndarray:
        return self.usage[self._idx[tenant]].copy()

    def oversubscribed(self) -> np.ndarray:
        """Per-tier bool: committed usage + reservations exceed capacity."""
        return (self.usage.sum(axis=0) + self.reserved.sum(axis=0)) > self.capacity


@dataclasses.dataclass
class BlockTable:
    """Host-side block -> (placement, slot) mapping for a managed store."""

    n_blocks: int

    def __post_init__(self):
        self.placement = np.zeros(self.n_blocks, dtype=np.int64)  # 0 = uncompressed
        self.slot = np.full(self.n_blocks, -1, dtype=np.int64)

    def move(self, block_id: int, new_placement: int, new_slot: int) -> Tuple[int, int]:
        old = (int(self.placement[block_id]), int(self.slot[block_id]))
        self.placement[block_id] = new_placement
        self.slot[block_id] = new_slot
        return old
