"""Analytical placement model (paper §5.2) — the per-window ILP.

    minimize   perf_ovh = sum_r hot_r * Lat_{t(r)}              (Eq. 2, 8)
    subject to sum_r cost(r, t(r)) <= TCO_min + alpha * MTS     (Eq. 2, 12)

with one placement decision t(r) in {0=DRAM, 1..N} per region. This is a
multiple-choice knapsack (MCKP). The paper solves it with Google OR-Tools on
an offloaded client; this repo has no solver dependency, so we implement:

  * ``solve_greedy`` — the LP-relaxation/dominance greedy: per-region convex
    hull of (cost, penalty) options, then globally take downgrade edges in
    ascending Δpenalty/Δcost-saved order until the budget holds. This is the
    classic MCKP LP solution (optimal up to one region's fractional edge; we
    round down = stay under budget).
  * ``solve_exact_dp`` — exact integer DP on a scaled cost grid, O(R·B);
    used by tests to bound the greedy's optimality gap and for tiny deploys.

Uniform-region fast path: when every region has the same size, the option
cost vector is shared and every hot region has the *same* hull structure
(penalty = hot_r · Lat_t scales the hull vertically), so the greedy becomes a
single argsort over R·E edge keys — fast enough to run every profile window
on the daemon core even for 10^5 regions.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Solution:
    placement: np.ndarray  # (R,) int placement indices
    penalty: float  # modeled perf_ovh (seconds)
    cost: float  # modeled TCO (USD units)
    feasible: bool  # cost <= budget


def _hull_indices(costs: np.ndarray, pens: np.ndarray) -> List[int]:
    """Lower-left convex hull of (cost, penalty) options.

    Returns option indices ordered by decreasing cost (increasing penalty),
    starting from the min-penalty option and ending at the min-cost option.
    Dominated options (another option with <=cost and <=penalty) are dropped.
    """
    order = np.lexsort((pens, costs))  # by cost asc, penalty asc tiebreak
    best_pen = np.inf
    kept: List[int] = []
    for i in order:
        # Sweeping cost-ascending, an option is non-dominated iff it strictly
        # reduces penalty relative to every cheaper option.
        if pens[i] < best_pen - 1e-18:
            best_pen = pens[i]
            kept.append(int(i))
    kept.reverse()
    pts: List[Tuple[float, float, int]] = [(costs[i], pens[i], i) for i in kept]
    # pts: cost strictly decreasing, penalty strictly increasing. Now enforce
    # convexity (increasing slope of Δpen/Δcost_saved).
    hull_pts: List[Tuple[float, float, int]] = []
    for c, p, i in pts:
        while len(hull_pts) >= 2:
            c1, p1, _ = hull_pts[-2]
            c2, p2, _ = hull_pts[-1]
            # slope from pt1->pt2 must be <= slope pt1->current, else pt2 is
            # above the hull.
            if (p2 - p1) * (c1 - c) >= (p - p1) * (c1 - c2):
                hull_pts.pop()
            else:
                break
        hull_pts.append((c, p, i))
    return [i for _, _, i in hull_pts]


def solve_greedy(
    hotness: np.ndarray,
    option_costs: np.ndarray,
    option_lats: np.ndarray,
    budget: float,
) -> Solution:
    """LP-greedy MCKP. option_costs: (N+1,) uniform-region cost per option.

    option_lats: (N+1,) access latency per option (Lat_0 = 0).
    """
    hotness = np.asarray(hotness, dtype=np.float64)
    costs = np.asarray(option_costs, dtype=np.float64)
    lats = np.asarray(option_lats, dtype=np.float64)
    r = hotness.shape[0]

    # Shared hull for a unit-hot region; cold regions handled separately.
    hull = _hull_indices(costs, lats)
    hull_costs = costs[hull]
    hull_lats = lats[hull]
    n_edges = len(hull) - 1

    placement = np.full(r, hull[0], dtype=np.int64)  # min-penalty start
    cold = hotness <= 0
    # Cold regions: penalty 0 at every option -> place at min cost directly.
    min_cost_opt = int(np.argmin(costs))
    placement[cold] = min_cost_opt
    total_cost = float(costs[placement].sum())
    if total_cost <= budget or n_edges == 0:
        pen = float((hotness * lats[placement]).sum())
        return Solution(placement, pen, total_cost, total_cost <= budget)

    hot_idx = np.where(~cold)[0]
    # Edge k of region i: slope = hot_i * (Δlat_k / Δcost_k), saving Δcost_k.
    d_cost = hull_costs[:-1] - hull_costs[1:]  # (E,) >0 cost saved
    d_lat = hull_lats[1:] - hull_lats[:-1]  # (E,) >=0 penalty added
    slopes = np.where(d_cost > 0, d_lat / np.maximum(d_cost, 1e-30), np.inf)

    # Keys for all (region, edge) pairs; a region's edges must be taken in
    # order, which the global sort preserves because per-region slopes are
    # non-decreasing along the hull and share the hot_i factor.
    keys = hotness[hot_idx][:, None] * slopes[None, :]  # (H, E)
    flat_order = np.argsort(keys, axis=None, kind="stable")
    edge_savings = np.broadcast_to(d_cost[None, :], keys.shape).reshape(-1)

    need = total_cost - budget
    cum = np.cumsum(edge_savings[flat_order])
    take = int(np.searchsorted(cum, need) + 1)
    take = min(take, flat_order.shape[0])
    chosen = flat_order[:take]
    # Count edges taken per region -> final hull position.
    reg_of = chosen // n_edges
    steps = np.bincount(reg_of, minlength=hot_idx.shape[0])
    placement[hot_idx] = np.asarray(hull)[steps]

    total_cost = float(costs[placement].sum())
    pen = float((hotness * lats[placement]).sum())
    return Solution(placement, pen, total_cost, total_cost <= budget)


def solve_generic_greedy(
    hotness: np.ndarray,
    option_costs: np.ndarray,  # (R, N+1) per-region costs
    option_lats: np.ndarray,  # (N+1,)
    budget: float,
) -> Solution:
    """Per-region-cost variant (non-uniform region sizes). Python-loop hulls;
    use only for moderate R (tests, embedding row-groups)."""
    hotness = np.asarray(hotness, dtype=np.float64)
    costs = np.asarray(option_costs, dtype=np.float64)
    lats = np.asarray(option_lats, dtype=np.float64)
    r, _ = costs.shape

    placement = np.zeros(r, dtype=np.int64)
    edges = []  # (slope, region, from_opt, to_opt, saving)
    total_cost = 0.0
    for i in range(r):
        pens = hotness[i] * lats
        hull = _hull_indices(costs[i], pens)
        placement[i] = hull[0]
        total_cost += costs[i, hull[0]]
        for a, b in zip(hull[:-1], hull[1:]):
            dc = costs[i, a] - costs[i, b]
            dp = pens[b] - pens[a]
            slope = dp / max(dc, 1e-30)
            edges.append((slope, i, b, dc))
    if total_cost <= budget:
        pen = float((hotness * lats[placement]).sum())
        return Solution(placement, pen, total_cost, True)
    edges.sort(key=lambda e: e[0])
    for slope, i, to_opt, dc in edges:
        if total_cost <= budget:
            break
        placement[i] = to_opt
        total_cost -= dc
    total_cost = float(np.take_along_axis(costs, placement[:, None], axis=1).sum())
    pen = float((hotness * lats[placement]).sum())
    return Solution(placement, pen, total_cost, total_cost <= budget)


def solve_exact_dp(
    hotness: np.ndarray,
    option_costs: np.ndarray,  # (N+1,)
    option_lats: np.ndarray,
    budget: float,
    grid: int = 2000,
) -> Solution:
    """Exact MCKP via DP on a scaled integer cost grid. Small instances only.

    Costs are ceil-scaled so the DP solution is feasible (never understates
    cost); optimal up to the grid resolution.
    """
    hotness = np.asarray(hotness, dtype=np.float64)
    costs = np.asarray(option_costs, dtype=np.float64)
    lats = np.asarray(option_lats, dtype=np.float64)
    r = hotness.shape[0]
    scale = grid / max(budget, 1e-30)
    icosts = np.ceil(costs * scale - 1e-9).astype(np.int64)
    ibudget = grid

    NEG = np.inf
    dp = np.full(ibudget + 1, NEG)
    dp[0] = 0.0
    choice = np.zeros((r, ibudget + 1), dtype=np.int8)
    for i in range(r):
        pens = hotness[i] * lats
        ndp = np.full(ibudget + 1, NEG)
        nch = np.zeros(ibudget + 1, dtype=np.int8)
        for t in range(costs.shape[0]):
            c = int(icosts[t])
            if c > ibudget:
                continue
            cand = np.full(ibudget + 1, NEG)
            cand[c:] = dp[: ibudget + 1 - c] + pens[t]
            better = cand < ndp
            ndp = np.where(better, cand, ndp)
            nch = np.where(better, t, nch)
        dp = ndp
        choice[i] = nch
    # Backtrack from the best feasible budget cell.
    b = int(np.argmin(dp))
    placement = np.zeros(r, dtype=np.int64)
    for i in range(r - 1, -1, -1):
        t = int(choice[i, b])
        placement[i] = t
        b -= int(icosts[t])
    total_cost = float(costs[placement].sum())
    pen = float((hotness * lats[placement]).sum())
    return Solution(placement, pen, total_cost, total_cost <= budget)
