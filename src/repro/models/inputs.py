"""Input construction: concrete batches (tests/examples) and abstract
ShapeDtypeStruct specs (dry-run) for every arch family and shape kind.

This is the single source of truth for what a (arch x shape) cell feeds the
step function — the modality-frontend stubs live here (audio frame / vision
patch embeddings per the assignment).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Array = jax.Array


def train_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    sd = jax.ShapeDtypeStruct
    spec: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": sd((batch, seq), jnp.int32),
        "targets": sd((batch, seq), jnp.int32),
        "loss_mask": sd((batch, seq), jnp.float32),
    }
    if cfg.frontend == "audio":
        spec["embeds"] = sd((batch, seq, cfg.d_model), jnp.bfloat16)
        del spec["tokens"]
    elif cfg.frontend == "vision":
        spec["embeds"] = sd((batch, seq, cfg.d_model), jnp.bfloat16)
        spec["embeds_mask"] = sd((batch, seq), jnp.bool_)
        spec["positions"] = sd((3, batch, seq), jnp.int32)
    return spec


def decode_token_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, 1), jnp.int32)


def make_train_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> Dict[str, Array]:
    """Concrete random batch matching train_batch_spec (tests/examples)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, Array] = {
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.frontend == "audio":
        out["embeds"] = jnp.asarray(rng.normal(0, 1, (batch, seq, cfg.d_model)), jnp.bfloat16)
    else:
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    if cfg.frontend == "vision":
        n_patch = max(seq // 8, 1)
        mask = np.zeros((batch, seq), bool)
        mask[:, :n_patch] = True
        out["embeds"] = jnp.asarray(rng.normal(0, 1, (batch, seq, cfg.d_model)), jnp.bfloat16)
        out["embeds_mask"] = jnp.asarray(mask)
        pos = np.broadcast_to(np.arange(seq)[None, None], (3, batch, seq)).copy()
        out["positions"] = jnp.asarray(pos, jnp.int32)
    return out
