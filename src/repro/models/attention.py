"""Grouped-query attention with the flavors the assigned archs need:

  * GQA with arbitrary kv-head count (incl. MHA when kv == heads),
  * per-head q/k RMS norm (qwen3), QKV biases (qwen1.5),
  * RoPE / M-RoPE (qwen2-vl), causal or bidirectional (hubert),
  * three execution modes: full (train / prefill), cached decode (one new
    token against a dense KV cache), and tiered decode (KV pages read from
    software-defined compressed pools — the paper's technique; the jnp path
    here is the oracle the Pallas kernel in ``repro.kernels`` matches).

Activation sharding: callers pass an ``ActivationSharding`` so the same code
lowers on a laptop (all None) and on the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ActivationSharding:
    """Logical -> mesh-axis mapping for activation constraints."""

    batch: Optional[str] = None  # usually ("pod","data") flattened upstream
    heads: Optional[str] = None  # usually "model"
    kv_seq: Optional[str] = None  # "model" when sequence-parallel decode
    constrain: Callable[[Array, P], Array] = lambda x, spec: x
    tp: int = 1  # size of the model axis (for divisibility decisions)

    def on_heads(self, x: Array) -> Array:
        # x: [B, S, H, D]
        return self.constrain(x, P(self.batch, None, self.heads, None))

    def on_kv_seq(self, x: Array) -> Array:
        # x: [B, S, H, D] with S the KV sequence axis
        return self.constrain(x, P(self.batch, self.kv_seq, None, None))

    def on_resid(self, x: Array) -> Array:
        # x: [B, S, D] residual stream. Constrained at every block boundary
        # so batch sharding survives scan/remat stashes (SSM blocks have no
        # other constraint and XLA otherwise replicates the stash).
        return self.constrain(x, P(self.batch, None, None))


def init_attn_params(key: Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    hd = cfg.head_dim_()
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(kq, (cfg.d_model, cfg.n_heads, hd), dtype=dtype),
        "wk": layers.dense_init(kk, (cfg.d_model, cfg.n_kv_heads, hd), dtype=dtype),
        "wv": layers.dense_init(kv, (cfg.d_model, cfg.n_kv_heads, hd), dtype=dtype),
        "wo": layers.dense_init(ko, (cfg.n_heads, hd, cfg.d_model), in_axis=1, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(
    p: dict, cfg: ModelConfig, x: Array, positions, shard: ActivationSharding
) -> Tuple[Array, Array, Array]:
    """x: [B, S, D] -> q [B,S,H,hd], k/v [B,S,KV,hd] with rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q, k, v = shard.on_heads(q), shard.on_heads(k), shard.on_heads(v)
    if cfg.mrope:
        q = layers.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, causal: bool, q_offset=0) -> Array:
    """Softmax attention. q: [B,Sq,H,hd]; k/v: [B,Sk,KV,hd] (GQA broadcast).

    Exact O(S^2)-memory path — short sequences and the oracle for the
    chunked path below.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, sq, kv, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / (hd**0.5)
    if causal:
        sk = k.shape[1]
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# Above this sequence length, attend_full switches to the blockwise online-
# softmax path (flash-attention structure expressed in XLA: O(S * blk)
# memory instead of O(S^2)). 32k prefill at d_model 8192 is impossible
# without it.
CHUNKED_ATTN_THRESHOLD = 2048
Q_BLOCK = 512
KV_BLOCK = 1024


def _maybe_expand_kv(q: Array, k: Array, v: Array, shard: ActivationSharding):
    """GQA -> MHA expansion when kv_heads cannot shard over the model axis.

    With kv < TP, the (kv, group) reshape inside attention destroys head
    sharding and GSPMD replicates every score tile across the model axis
    (observed: ~7TB/device of tile all-gathers on the 235B MoE). Repeating
    K/V to the full head count keeps tiles sharded on the 64-head dim; the
    duplicated K/V tiles are ~100x smaller than the score tiles they
    de-replicate.
    """
    h = q.shape[2]
    kvh = k.shape[2]
    if kvh == h or shard.heads is None or shard.tp <= 1:
        return k, v
    if kvh % shard.tp == 0 or h % shard.tp != 0:
        return k, v
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    return shard.on_heads(k), shard.on_heads(v)


def _sdpa_chunked(q: Array, k: Array, v: Array, causal: bool) -> Array:
    """Blockwise exact attention: scan over q blocks, inner scan over kv
    blocks with online-softmax accumulators. f32 accumulation."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    group = h // kvh
    q_blk = min(Q_BLOCK, sq)
    kv_blk = min(KV_BLOCK, sk)
    assert sq % q_blk == 0 and sk % kv_blk == 0, (sq, sk)
    nq, nk = sq // q_blk, sk // kv_blk

    qf = q.astype(jnp.float32).reshape(b, nq, q_blk, kvh, group, hd) / (hd**0.5)
    kf = k.astype(jnp.float32).reshape(b, nk, kv_blk, kvh, hd)
    vf = v.astype(jnp.float32).reshape(b, nk, kv_blk, kvh, hd)

    q_pos = jnp.arange(sq).reshape(nq, q_blk)
    k_pos = jnp.arange(sk).reshape(nk, kv_blk)

    # Remat per q-block: without this the backward stores every
    # [B,H,q_blk,kv_blk] f32 tile (observed 25GB/device at 4k train) —
    # recomputing the kv scan in bwd is the flash-attention trade.
    @jax.checkpoint
    def q_block_body(_, qi):
        qb, qp = qi  # [b, q_blk, kv, g, hd], [q_blk]

        def kv_block_body(carry, ki):
            acc, m, l = carry
            kb, vb, kp = ki
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb)
            if causal:
                mask = qp[:, None] >= kp[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            e = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(e, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", e, vb)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kvh, group, q_blk, hd), jnp.float32)
        m0 = jnp.full((b, kvh, group, q_blk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, group, q_blk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_block_body, (acc0, m0, l0),
            (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), k_pos),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [b,kv,g,q_blk,hd]
        return None, out

    _, outs = jax.lax.scan(q_block_body, None, (jnp.moveaxis(qf, 1, 0), q_pos))
    # outs: [nq, b, kv, g, q_blk, hd] -> [b, nq, q_blk, kv, g, hd] -> [b,S,H,hd]
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    return out.reshape(b, nq * q_blk, h, hd).astype(q.dtype)


def attend_full(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    shard: ActivationSharding,
) -> Array:
    """Training / prefill attention over the whole sequence."""
    q, k, v = _project_qkv(p, cfg, x, positions, shard)
    k, v = _maybe_expand_kv(q, k, v, shard)
    if q.shape[1] > CHUNKED_ATTN_THRESHOLD:
        out = _sdpa_chunked(q, k, v, causal=cfg.causal)
    else:
        out = _sdpa(q, k, v, causal=cfg.causal)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.attn_out_bias:
        y = y + p["bo"]
    return y


def attend_decode(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    k_cache: Array,
    v_cache: Array,
    cache_len,
    shard: ActivationSharding,
    positions: Optional[Array] = None,
) -> Tuple[Array, Array, Array]:
    """One-token decode against a dense KV cache.

    x: [B, 1, D]; k_cache/v_cache: [B, S_max, KV, hd]; cache_len: current
    valid length (scalar int array). Returns (y, new_k_cache, new_v_cache).
    """
    b = x.shape[0]
    if positions is None:
        positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, shard)
    # Masked additive write instead of dynamic-update-slice: elementwise ops
    # keep the cache's (batch, seq-sharded) layout and alias the donated
    # input, where a DUS at a dynamic index across seq shards forces GSPMD
    # into a full-buffer copy (2.5x cache temp memory at 32k context).
    slot = (jnp.arange(k_cache.shape[1]) == cache_len)[None, :, None, None]
    k_cache = jnp.where(slot, k_new.astype(k_cache.dtype), k_cache)
    v_cache = jnp.where(slot, v_new.astype(v_cache.dtype), v_cache)
    k_cache = shard.on_kv_seq(k_cache)
    v_cache = shard.on_kv_seq(v_cache)

    bq, _, h, hd = q.shape
    kvh = k_cache.shape[2]
    group = h // kvh
    qg = q.reshape(b, kvh, group, hd)
    # Keep the cache in bf16 and accumulate in f32 via the MXU — an explicit
    # astype(f32) materializes a full f32 copy of the 32k-token cache.
    scores = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) / (hd**0.5)
    valid = jnp.arange(k_cache.shape[1])[None, None, None, :] <= cache_len
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgs,bskh->bkgh", w.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.attn_out_bias:
        y = y + p["bo"]
    return y, k_cache, v_cache


def attend_decode_tiered(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    pools: dict,
    recent_k: Array,
    recent_v: Array,
    recent_len,
    total_len,
    shard: ActivationSharding,
    dequant_attend_fn=None,
) -> Tuple[Array, Array, Array]:
    """One-token decode against tiered compressed KV pools + a dense recent
    window — the paper's technique on the decode path.

    pools: {"warm": {...}, "cold": {...}} as built by
    ``repro.serving.kv_cache``; each holds quantized K/V pages plus scales
    and a page table. ``dequant_attend_fn`` (default: jnp oracle in
    ``repro.kernels.ref``) computes attention over the pools; the recent
    dense window is attended exactly, and the two are merged with a
    logsumexp-weighted combine (flash-decoding style).
    """
    from repro.kernels import ops as kops

    b = x.shape[0]
    positions = jnp.full((b, 1), total_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, shard)
    recent_k = jax.lax.dynamic_update_slice_in_dim(recent_k, k_new, recent_len, axis=1)
    recent_v = jax.lax.dynamic_update_slice_in_dim(recent_v, v_new, recent_len, axis=1)

    fn = dequant_attend_fn or kops.tiered_decode_attention
    out = fn(q[:, 0], pools, recent_k, recent_v, recent_len, cfg)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    if cfg.attn_out_bias:
        y = y + p["bo"]
    return y, recent_k, recent_v
