"""Shared model layers: norms, rotary embeddings (incl. M-RoPE), inits.

Pure-JAX (no flax): parameters are plain pytrees of jax.Arrays; every layer
is a function (params, x) -> y. Initializers return abstract-friendly
callables so the whole model can be built under jax.eval_shape for the
dry-run without allocating.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: Array, shape, in_axis: int = 0, dtype=jnp.bfloat16) -> Array:
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = (1.0 / fan_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: Array, shape, dtype=jnp.bfloat16) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(w: Array, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(dtype)


def layernorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


def make_norm_params(kind: str, dim: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return jnp.ones((dim,), dtype)
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_norm(kind: str, params, x: Array, eps: float) -> Array:
    if kind == "rmsnorm":
        return rmsnorm(params, x, eps)
    return layernorm(params, x, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE) and Qwen2-VL M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array, positions3: Array, theta: float, sections: Tuple[int, int, int]
) -> Array:
    """Qwen2-VL multimodal RoPE: rotary dims split into (t, h, w) sections.

    x: [batch, seq, heads, head_dim]; positions3: [3, batch, seq] (temporal,
    height, width position ids — text tokens carry identical t/h/w ids, so
    M-RoPE degrades to 1-D RoPE on pure text).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)  # [half]
    # Which section (and hence which position axis) each rotary dim uses.
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )  # [half]
    pos = positions3.astype(jnp.float32)  # [3, B, S]
    pos_per_dim = pos[sec_id]  # [half, B, S]
    angles = jnp.moveaxis(pos_per_dim, 0, -1) * freqs  # [B, S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def swiglu(gate: Array, up: Array) -> Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: Array) -> Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)
