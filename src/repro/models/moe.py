"""Mixture-of-Experts FFN — grouped, capacity-dropping, SPMD-shardable.

Formulation (the production TPU pattern, MaxText/t5x-style "dropping"):
  * each sequence row is a dispatch GROUP (rows are data-sharded, so all
    group-local work shards with them),
  * per group: top-k routing, stable sort of assignments by expert, and a
    capacity-C gather building xe[g, E, C, D] — gathers/scatters carry the
    group dim as a batch dim, which GSPMD partitions cleanly,
  * a sharding constraint flips xe from group-sharded to expert-sharded —
    XLA materializes exactly the token all-to-all of expert parallelism,
  * per-expert SwiGLU with expert-sharded weights, constraint back, and a
    batched scatter-add combine.

Aux: Switch-style load-balance loss + router z-loss + dropped-token frac.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Array = jax.Array


def init_moe_params(key: Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    m = cfg.moe
    kr, kg, ku, kd = jax.random.split(key, 4)
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    return {
        "router": layers.dense_init(kr, (d, e), dtype=jnp.float32),
        "w_gate": layers.dense_init(kg, (e, d, f), in_axis=1, dtype=dtype),
        "w_up": layers.dense_init(ku, (e, d, f), in_axis=1, dtype=dtype),
        "w_down": layers.dense_init(kd, (e, f, d), in_axis=1, dtype=dtype),
    }


# Sequences longer than this are dispatched in chunks (scan) so the live
# expert buffers stay O(chunk): 32k-token prefill would otherwise hold
# ~50GB/device of dispatch state.
MOE_SEQ_CHUNK = 4096

# Quantize the dispatch/combine payloads to int8 around the EP all-to-all —
# the paper's software-defined-compression idea applied to the wire (2x
# fewer bytes than bf16 on the dominant collective). Per-slot absmax scales;
# ~0.4% relative error on the FFN inputs/outputs.
A2A_WIRE_INT8 = True


def set_a2a_wire_int8(flag: bool) -> None:
    global A2A_WIRE_INT8
    A2A_WIRE_INT8 = flag


def _wire_quant(x: Array):
    """[..., D] -> (int8 payload, f32 scale per slot)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-20)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _wire_dequant(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def make_wire_transfer(pin_src: Callable, pin_dst: Callable):
    """int8-compressed sharding transition with a custom VJP.

    Forward: quantize -> (pin_src, pin_dst) reshard of the int8 payload ->
    dequantize. Backward: the COTANGENT takes the mirrored int8 path
    (pin_dst -> pin_src). Without the custom VJP, round() has zero gradient
    (silent training breakage) and the cotangent reshard runs unpinned at
    f32 (observed 1.4TB/device of all-gathers).
    """

    @jax.custom_vjp
    def transfer(x):
        q, s = _wire_quant(x)
        q = pin_dst(pin_src(q))
        s = pin_dst(pin_src(s))
        return _wire_dequant(q, s, x.dtype)

    def fwd(x):
        return transfer(x), None

    def bwd(_, g):
        q, s = _wire_quant(g)
        q = pin_src(pin_dst(q))
        s = pin_src(pin_dst(s))
        return (_wire_dequant(q, s, g.dtype),)

    transfer.defvjp(fwd, bwd)
    return transfer


def moe_ffn(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    constrain_experts: Callable[[Array], Array] = lambda a: a,
    constrain_groups: Callable[[Array], Array] = lambda a: a,
    capacity: Optional[int] = None,
) -> Tuple[Array, dict]:
    """x: [B, S, D] -> (y [B, S, D], aux losses dict)."""
    b_, s_, _ = x.shape
    if s_ > MOE_SEQ_CHUNK and s_ % MOE_SEQ_CHUNK == 0:
        nch = s_ // MOE_SEQ_CHUNK
        xc = jnp.moveaxis(x.reshape(b_, nch, MOE_SEQ_CHUNK, -1), 1, 0)

        @jax.checkpoint
        def body(_, xi):
            y, aux = moe_ffn(p, cfg, xi, constrain_experts, constrain_groups, capacity)
            return None, (y, aux)

        _, (yc, auxs) = jax.lax.scan(body, None, xc)
        y = jnp.moveaxis(yc, 0, 1).reshape(b_, s_, -1)
        aux = jax.tree.map(lambda a: a.mean(), auxs)
        return y, aux
    return _moe_ffn_inner(p, cfg, x, constrain_experts, constrain_groups, capacity)


def _moe_ffn_inner(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    constrain_experts: Callable[[Array], Array] = lambda a: a,
    constrain_groups: Callable[[Array], Array] = lambda a: a,
    capacity: Optional[int] = None,
) -> Tuple[Array, dict]:
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.experts_per_token
    if capacity is None:
        capacity = max(int(s * k * m.capacity_factor / e), 1)
        capacity = -(-capacity // 4) * 4

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux losses (global over the batch).
    me = probs.mean(axis=(0, 1))  # [E]
    ce = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32).mean(axis=(0, 1, 2))
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- per-group sort-by-expert dispatch ----------------------------------
    a = s * k  # assignments per group
    ids_flat = expert_ids.reshape(b, a)
    gates_flat = gate_vals.reshape(b, a)
    order = jnp.argsort(ids_flat, axis=1, stable=True)  # [B, A]
    sorted_ids = jnp.take_along_axis(ids_flat, order, axis=1)

    counts = jax.vmap(lambda i: jnp.bincount(i, length=e))(ids_flat)  # [B, E]
    seg_start = jnp.cumsum(counts, axis=1) - counts  # [B, E]

    c_rng = jnp.arange(capacity)
    slot_valid = c_rng[None, None, :] < jnp.minimum(counts, capacity)[..., None]  # [B,E,C]
    gidx = jnp.clip(seg_start[..., None] + c_rng[None, None, :], 0, a - 1)  # [B,E,C]
    assign_idx = jnp.take_along_axis(order, gidx.reshape(b, e * capacity), axis=1)
    tok_idx = (assign_idx // k).reshape(b, e, capacity)  # [B,E,C] source token
    slot_gate = jnp.take_along_axis(
        gates_flat, assign_idx, axis=1
    ).reshape(b, e, capacity)

    # Gather tokens into expert slots (batched on the group dim).
    xe = jnp.take_along_axis(
        x.reshape(b, s, d), tok_idx.reshape(b, e * capacity)[..., None], axis=1
    ).reshape(b, e, capacity, d)
    xe = jnp.where(slot_valid[..., None], xe, 0)
    # Group-sharded -> expert-sharded: the EP all-to-all. Two explicit pins
    # on the bare tensor make GSPMD emit a dim-to-dim all-to-all; with only
    # the target constraint it falls back to all-gather + slice, which moves
    # (n-1)x more bytes per device (observed 16TB/device on the 235B MoE).
    if A2A_WIRE_INT8:
        xe = make_wire_transfer(constrain_groups, constrain_experts)(xe)
    else:
        xe = constrain_groups(xe)
        xe = constrain_experts(xe)

    # --- per-expert FFN (weights sharded over experts) -----------------------
    gate = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    up = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = layers.swiglu(gate, up)
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])
    # Back to group-sharded for the combine (reverse all-to-all; the expert-
    # TP partial sums over ``data`` reduce into the same transition). The
    # expert-sharded pin also re-shards the COTANGENT on the way back, so
    # the wgrad einsums see matching layouts instead of gathering full-E
    # operands.
    ye = constrain_experts(ye)  # resolve expert-TP partial sums (f32/bf16 AR)
    if A2A_WIRE_INT8:
        ye = make_wire_transfer(constrain_experts, constrain_groups)(ye)
    else:
        ye = constrain_groups(ye)

    # --- combine: batched scatter-add by source token ------------------------
    contrib = ye * (slot_gate * slot_valid)[..., None].astype(ye.dtype)
    yt = jnp.zeros((b, s, d), x.dtype)
    yt = yt.at[
        jnp.arange(b)[:, None], tok_idx.reshape(b, e * capacity)
    ].add(contrib.reshape(b, e * capacity, d))

    aux = {
        "load_balance": load_balance,
        "router_z": z_loss,
        "dropped_frac": 1.0 - (slot_valid.sum() / (b * a)).astype(jnp.float32),
    }
    return yt, aux
