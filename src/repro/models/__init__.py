from repro.models.transformer import DecodeState, Model, init_decode_state
from repro.models.attention import ActivationSharding

__all__ = ["Model", "DecodeState", "init_decode_state", "ActivationSharding"]
