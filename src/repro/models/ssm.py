"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training path: the chunked SSD algorithm (block-diagonal intra-chunk
"attention" + inter-chunk state recurrence via an associative scan over
chunk states). Decode path: the classic recurrent update with an O(1)
state ``[B, H, P, N]`` plus a depthwise-conv ring buffer.

Shapes follow the paper's minimal SSD listing:
  x:  [B, L, H, P]   (H heads, P head_dim)
  dt: [B, L, H]      (softplus-activated step sizes)
  A:  [H]            (negative scalars)
  B,C:[B, L, G, N]   (G state groups, N d_state)
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Array = jax.Array


def init_ssm_params(key: Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    g, n = s.n_groups, s.d_state
    k_in, k_conv, k_dt, k_out = jax.random.split(key, 4)
    # in_proj emits [z (gate), x, B, C, dt] concatenated.
    d_in_proj = 2 * di + 2 * g * n + nh
    return {
        "in_proj": layers.dense_init(k_in, (d, d_in_proj), dtype=dtype),
        "conv_w": layers.dense_init(k_conv, (s.conv_kernel, di + 2 * g * n), dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * g * n,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),  # gated RMSNorm pre out_proj
        "out_proj": layers.dense_init(k_out, (di, d), dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: Array):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    g, n = s.n_groups, s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over [B, L, C] with kernel [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _segsum(x: Array) -> Array:
    """Stable 'segment sum' producing the 1-semiseparable mask (SSD paper).

    x: [..., L] -> [..., L, L] with out[i,j] = sum_{j<k<=i} x[k], -inf for j>i.
    """
    l = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    diff = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: Array, dt: Array, a: Array, b: Array, c: Array, chunk: int
) -> Tuple[Array, Array]:
    """Chunked SSD scan. Returns (y [B,L,H,P], final_state [B,H,P,N]).

    a: [H] negative; b/c: [B, L, G, N] broadcast over heads per group.
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    orig_l = l
    pad = (-l) % chunk
    if pad:
        # Zero-pad the tail: dt=0 makes padded steps identity state updates
        # (exp(0)=1 decay, zero input contribution), so the final state and
        # the first orig_l outputs are exact.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // chunk
    rep = h // g

    # Reshape into chunks.
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, g, n)
    cc = c.reshape(bsz, nc, chunk, g, n)
    bh = jnp.repeat(bc, rep, axis=3)  # [B,nc,ch,H,N]
    ch_ = jnp.repeat(cc, rep, axis=3)

    da = dtc * a  # [B,nc,ch,H] (log decay per step)
    da_cs = jnp.cumsum(da, axis=2)  # within-chunk cumulative

    # 1. Intra-chunk (diagonal block) output. (u = chunk index, i/j = pos in
    # chunk, h = head, p = head_dim, s = state dim.)
    seg = _segsum(jnp.swapaxes(da, 2, 3))  # [B,u,H,ch,ch]
    att = jnp.exp(seg)
    cb = jnp.einsum("buihs,bujhs->buhij", ch_.astype(jnp.float32), bh.astype(jnp.float32))
    scores = cb * att
    y_diag = jnp.einsum("buhij,bujh,bujhp->buihp", scores, dtc, xc.astype(jnp.float32))

    # 2. Chunk-final states: decay-weighted sum of inputs.
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # [B,u,ch,H]
    states = jnp.einsum(
        "bujhs,bujh,bujhp->buhps",
        bh.astype(jnp.float32),
        dtc * decay_to_end,
        xc.astype(jnp.float32),
    )

    # 3. Inter-chunk recurrence over chunk states (associative scan).
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])  # [B,nc,H]

    def combine(carry, nxt):
        s_prev, d_prev = carry
        s_nxt, d_nxt = nxt
        return s_prev * d_nxt[..., None, None] + s_nxt, d_prev * d_nxt

    states_t = jnp.moveaxis(states, 1, 0)  # [u,B,H,P,N]
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)  # [u,B,H]
    scanned, _ = jax.lax.associative_scan(
        lambda x1, x2: combine(x1, x2), (states_t, decay_t), axis=0
    )
    # States *entering* each chunk = scan result shifted by one.
    init = jnp.zeros_like(scanned[:1])
    entering = jnp.concatenate([init, scanned[:-1]], axis=0)
    entering = jnp.moveaxis(entering, 0, 1)  # [B,u,H,P,N]

    # 4. Inter-chunk contribution to outputs.
    decay_from_start = jnp.exp(da_cs)  # [B,u,ch,H]
    y_off = jnp.einsum(
        "buihs,buhps,buih->buihp", ch_.astype(jnp.float32), entering, decay_from_start
    )

    y = (y_diag + y_off).reshape(bsz, l, h, p)[:, :orig_l]  # both [B,nc,ch,H,P]
    final_state = scanned[-1]  # [B,H,P,N]
    return y, final_state


def ssm_block(
    p: dict, cfg: ModelConfig, x: Array
) -> Array:
    """Full Mamba2 block: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    g, n = s.n_groups, s.d_state

    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, b, c = jnp.split(xbc, [di, di + g * n], axis=-1)
    bsz, l, _ = xs.shape
    xs = xs.reshape(bsz, l, nh, s.head_dim)
    b = b.reshape(bsz, l, g, n)
    c = c.reshape(bsz, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    y, _ = ssd_chunked(xs, dt, a, b, c, min(s.chunk, l))
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(bsz, l, di).astype(x.dtype)

    # Gated RMSNorm (mamba2 uses norm(y * silu(z))).
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = layers.rmsnorm(p["norm_w"], y, cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, p["out_proj"])


def ssm_decode_step(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    conv_state: Array,
    ssm_state: Array,
) -> Tuple[Array, Array, Array]:
    """One-token recurrent step.

    x: [B, 1, D]; conv_state: [B, K-1, C_conv]; ssm_state: [B, H, P, N].
    Returns (y [B,1,D], new_conv_state, new_ssm_state).
    """
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    g, n = s.n_groups, s.d_state

    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = xbc[:, 0]  # [B, C_conv]

    # Conv ring buffer: full window = [conv_state, xbc].
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_conv_state = window[:, 1:]

    xs, b, c = jnp.split(conv_out, [di, di + g * n], axis=-1)
    bsz = xs.shape[0]
    xs = xs.reshape(bsz, nh, s.head_dim)
    b = b.reshape(bsz, g, n)
    c = c.reshape(bsz, g, n)
    rep = nh // g
    bh = jnp.repeat(b, rep, axis=1)  # [B,H,N]
    ch_ = jnp.repeat(c, rep, axis=1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["A_log"])  # [H]
    da = jnp.exp(dt1 * a)  # [B,H]

    new_state = ssm_state * da[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt1, xs.astype(jnp.float32), bh.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch_.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(bsz, di)

    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    y = layers.rmsnorm(p["norm_w"], y.astype(x.dtype), cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])
    return out[:, None], new_conv_state, new_state
