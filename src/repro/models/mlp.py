"""Dense FFN blocks (SwiGLU / GELU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Array = jax.Array


def init_mlp_params(key: Array, cfg: ModelConfig, d_ff=None, dtype=jnp.bfloat16) -> dict:
    d_ff = d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        kg, ku, kd = jax.random.split(key, 3)
        return {
            "w_gate": layers.dense_init(kg, (cfg.d_model, d_ff), dtype=dtype),
            "w_up": layers.dense_init(ku, (cfg.d_model, d_ff), dtype=dtype),
            "w_down": layers.dense_init(kd, (d_ff, cfg.d_model), dtype=dtype),
        }
    ku, kd = jax.random.split(key, 2)
    return {
        "w_up": layers.dense_init(ku, (cfg.d_model, d_ff), dtype=dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": layers.dense_init(kd, (d_ff, cfg.d_model), dtype=dtype),
        "b_down": jnp.zeros((cfg.d_model,), dtype),
    }


def mlp(p: dict, cfg: ModelConfig, x: Array, constrain=lambda x: x) -> Array:
    if cfg.act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = constrain(layers.swiglu(gate, up))
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"]) + p["b_up"]
    h = constrain(layers.gelu(h))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"]) + p["b_down"]
