"""Unified model zoo: decoder LMs (dense / MoE / VLM), encoders (HuBERT),
SSM (Mamba2) and hybrid (Zamba2) — all families behind one Model API:

    model = Model(cfg)
    params = model.init(key)                         # or jax.eval_shape
    logits, aux = model.forward(params, batch)       # train / prefill
    loss, metrics = model.loss(params, batch)
    caches = model.init_cache(batch, max_len)        # decode state
    logits, caches = model.decode_step(params, tok, caches)

Layer stacks are *scanned* (stacked parameter pytrees + jax.lax.scan) with
optional per-block remat — both are essential for 40-94 layer archs: compile
time stays O(1) in depth and activation memory is O(sqrt) with remat.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mlp, moe, ssm
from repro.models.attention import ActivationSharding

Array = jax.Array
NO_SHARD = ActivationSharding()


# ---------------------------------------------------------------------------
# Per-block init / forward
# ---------------------------------------------------------------------------


def init_transformer_block(key: Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    ka, kf = jax.random.split(key)
    p = {
        "norm1": layers.make_norm_params(cfg.norm, cfg.d_model),
        "attn": attention.init_attn_params(ka, cfg, dtype),
        "norm2": layers.make_norm_params(cfg.norm, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = moe.init_moe_params(kf, cfg, dtype)
    else:
        p["ffn"] = mlp.init_mlp_params(kf, cfg, dtype=dtype)
    return p


def transformer_block(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    shard: ActivationSharding,
) -> Tuple[Array, dict]:
    x = shard.on_resid(x)
    h = layers.apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    x = x + attention.attend_full(p["attn"], cfg, h, positions, shard)
    h = layers.apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
    aux = {}
    if cfg.family == "moe":
        y, aux = moe.moe_ffn(
            p["moe"], cfg, h,
            constrain_experts=lambda a: shard.constrain(a, _expert_spec(shard)),
            constrain_groups=lambda a: shard.constrain(a, _group_spec(shard)),
        )
    else:
        y = mlp.mlp(p["ffn"], cfg, h)
    return x + y, aux


def _expert_spec(shard: ActivationSharding):
    from jax.sharding import PartitionSpec as P

    # xe [groups, E, C, D]: groups STAY batch-sharded while experts shard
    # over model — dropping the batch axis here replicates xe across the
    # pod/data axes (observed 6x multi-pod regression on the MoE archs).
    return P(shard.batch, shard.heads, None, None)


def _group_spec(shard: ActivationSharding):
    from jax.sharding import PartitionSpec as P

    return P(shard.batch, None, None, None)


def init_ssm_block(key: Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "norm": layers.make_norm_params(cfg.norm, cfg.d_model),
        "mixer": ssm.init_ssm_params(key, cfg, dtype),
    }


def ssm_block_fwd(
    p: dict, cfg: ModelConfig, x: Array, shard: ActivationSharding = NO_SHARD
) -> Array:
    x = shard.on_resid(x)
    h = layers.apply_norm(cfg.norm, p["norm"], x, cfg.norm_eps)
    return x + ssm.ssm_block(p["mixer"], cfg, h)


# ---------------------------------------------------------------------------
# Decode-time state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeState:
    """Stacked per-layer decode state. Unused fields hold size-0 arrays."""

    k_cache: Array  # [L_attn, B, S_max, KV, hd]
    v_cache: Array
    cache_len: Array  # scalar int32 — tokens already in the cache
    conv_state: Array  # [L_ssm, B, K-1, C_conv]
    ssm_state: Array  # [L_ssm, B, H, P, N]


def _attn_layer_count(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe", "vlm"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return -(-cfg.n_layers // cfg.hybrid_attn_every)  # shared-block applications
    return 0


def _ssm_layer_count(cfg: ModelConfig) -> int:
    return cfg.n_layers if cfg.family in ("ssm", "hybrid") else 0


def init_decode_state(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> DecodeState:
    hd = cfg.head_dim_() if cfg.has_attention else 1
    la, ls = _attn_layer_count(cfg), _ssm_layer_count(cfg)
    kv = cfg.n_kv_heads if cfg.has_attention else 1
    if cfg.ssm is not None:
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        cconv = di + 2 * s.n_groups * s.d_state
        conv = jnp.zeros((ls, batch, s.conv_kernel - 1, cconv), dtype)
        sst = jnp.zeros((ls, batch, s.n_heads(cfg.d_model), s.head_dim, s.d_state), jnp.float32)
    else:
        conv = jnp.zeros((0, batch, 0, 0), dtype)
        sst = jnp.zeros((0, batch, 0, 0, 0), jnp.float32)
    return DecodeState(
        k_cache=jnp.zeros((max(la, 0), batch, max_len if la else 0, kv, hd), dtype),
        v_cache=jnp.zeros((max(la, 0), batch, max_len if la else 0, kv, hd), dtype),
        cache_len=jnp.zeros((), jnp.int32),
        conv_state=conv,
        ssm_state=sst,
    )


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Family-dispatching model wrapper (pure functions + config)."""

    def __init__(self, cfg: ModelConfig, parallel=None):
        self.cfg = cfg
        self.parallel = parallel  # ParallelConfig or None

    # ---------------------------------------------------------------- init
    def init(self, key: Array, dtype=jnp.bfloat16) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_blocks, k_head, k_shared = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": layers.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype)
        }
        if cfg.family in ("dense", "moe", "vlm", "encoder"):
            block_init = functools.partial(init_transformer_block, cfg=cfg, dtype=dtype)
        elif cfg.family in ("ssm", "hybrid"):
            block_init = functools.partial(init_ssm_block, cfg=cfg, dtype=dtype)
        else:
            raise ValueError(cfg.family)
        keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: block_init(k))(keys)
        if cfg.family == "hybrid":
            params["shared"] = init_transformer_block(k_shared, cfg, dtype)
        params["final_norm"] = layers.make_norm_params(cfg.norm, cfg.d_model)
        if cfg.is_decoder and not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dtype)
        elif cfg.family == "encoder":
            params["lm_head"] = layers.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dtype)
        return params

    # ------------------------------------------------------------- embed in
    def _embed_inputs(self, params, batch: Dict[str, Array]) -> Array:
        cfg = self.cfg
        if cfg.frontend == "audio":
            # Frontend stub: precomputed frame embeddings.
            return batch["embeds"].astype(params["embed"].dtype)
        x = params["embed"][batch["tokens"]]
        if cfg.frontend == "vision" and "embeds" in batch:
            # Patch embeddings replace token embeddings where mask is set.
            mask = batch["embeds_mask"][..., None]
            x = jnp.where(mask, batch["embeds"].astype(x.dtype), x)
        return x

    def _positions(self, batch: Dict[str, Array], seq: int, bsz: int) -> Array:
        if self.cfg.mrope:
            if "positions" in batch:
                return batch["positions"]  # [3, B, S]
            base = jnp.broadcast_to(jnp.arange(seq)[None], (bsz, seq))
            return jnp.broadcast_to(base[None], (3, bsz, seq))
        if "positions" in batch:
            return batch["positions"]
        return jnp.broadcast_to(jnp.arange(seq)[None], (bsz, seq))

    # -------------------------------------------------------------- forward
    def forward(
        self,
        params,
        batch: Dict[str, Array],
        shard: ActivationSharding = NO_SHARD,
    ) -> Tuple[Array, Dict[str, Array]]:
        """Full-sequence forward (training / prefill). Returns (logits, aux)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        bsz, seq = x.shape[0], x.shape[1]
        positions = self._positions(batch, seq, bsz)

        remat = self.parallel is None or self.parallel.remat == "block"

        if cfg.family in ("dense", "moe", "vlm", "encoder"):

            def body(carry, blk):
                h, aux_lb, aux_z = carry
                h, aux = transformer_block(blk, cfg, h, positions, shard)
                if cfg.family == "moe":
                    aux_lb = aux_lb + aux["load_balance"]
                    aux_z = aux_z + aux["router_z"]
                return (h, aux_lb, aux_z), None

            body_fn = jax.checkpoint(body) if remat else body
            (x, lb, z), _ = jax.lax.scan(
                body_fn, (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                params["blocks"],
            )
            aux = {"load_balance": lb / cfg.n_layers, "router_z": z / cfg.n_layers}

        elif cfg.family == "ssm":

            def body(h, blk):
                return ssm_block_fwd(blk, cfg, h, shard), None

            body_fn = jax.checkpoint(body) if remat else body
            x, _ = jax.lax.scan(body_fn, x, params["blocks"])
            aux = {}

        elif cfg.family == "hybrid":
            x = self._hybrid_forward(params, x, positions, shard, remat)
            aux = {}
        else:
            raise ValueError(cfg.family)

        x = layers.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        logits = self._head(params, x)
        return logits, aux

    def _hybrid_forward(self, params, x, positions, shard, remat) -> Array:
        """Zamba2: scanned Mamba2 backbone + one weight-shared transformer
        block applied every ``hybrid_attn_every`` layers."""
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        n_groups = -(-cfg.n_layers // every)

        def ssm_body(h, blk):
            return ssm_block_fwd(blk, cfg, h, shard), None

        ssm_body = jax.checkpoint(ssm_body) if remat else ssm_body

        def shared_fn(h):
            out, _ = transformer_block(params["shared"], cfg, h, positions, shard)
            return out

        shared_fn = jax.checkpoint(shared_fn) if remat else shared_fn

        done = 0
        for g in range(n_groups):
            x = shared_fn(x)
            width = min(every, cfg.n_layers - done)
            group_blocks = jax.tree.map(lambda a: a[done : done + width], params["blocks"])
            x, _ = jax.lax.scan(ssm_body, x, group_blocks)
            done += width
        return x

    def _head(self, params, x: Array) -> Array:
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])

    # ------------------------------------------------------------------ loss
    def loss(
        self,
        params,
        batch: Dict[str, Array],
        shard: ActivationSharding = NO_SHARD,
        moe_lb_weight: float = 0.01,
        moe_z_weight: float = 1e-3,
    ) -> Tuple[Array, Dict[str, Array]]:
        logits, aux = self.forward(params, batch, shard)
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        if mask is not None:
            denom = jnp.maximum(mask.sum(), 1.0)
            ce = (nll * mask).sum() / denom
        else:
            ce = nll.mean()
        total = ce
        metrics = {"ce": ce}
        if self.cfg.family == "moe":
            total = total + moe_lb_weight * aux["load_balance"] + moe_z_weight * aux["router_z"]
            metrics.update(aux)
        metrics["loss"] = total
        return total, metrics

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16) -> DecodeState:
        return init_decode_state(self.cfg, batch_size, max_len, dtype)

    def prefill(
        self,
        params,
        batch: Dict[str, Array],
        state: DecodeState,
        shard: ActivationSharding = NO_SHARD,
    ) -> Tuple[Array, DecodeState]:
        """Run the full prompt, filling the decode state. Returns last-token
        logits. (KV caches are filled by re-projecting K/V per layer — one
        extra pass kept simple; the serving engine uses this once per
        request batch.)"""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        bsz, seq = x.shape[0], x.shape[1]
        positions = self._positions(batch, seq, bsz)

        if cfg.family in ("dense", "moe", "vlm"):

            def body(h, blk):
                # Capture K/V for the cache while running the block.
                hn = layers.apply_norm(cfg.norm, blk["norm1"], h, cfg.norm_eps)
                q, k, v = attention._project_qkv(blk["attn"], cfg, hn, positions, shard)
                ke, ve = attention._maybe_expand_kv(q, k, v, shard)
                if q.shape[1] > attention.CHUNKED_ATTN_THRESHOLD:
                    out = attention._sdpa_chunked(q, ke, ve, causal=cfg.causal)
                else:
                    out = attention._sdpa(q, ke, ve, causal=cfg.causal)
                y = jnp.einsum("bshk,hkd->bsd", out, blk["attn"]["wo"])
                if cfg.attn_out_bias:
                    y = y + blk["attn"]["bo"]
                h = h + y
                hn = layers.apply_norm(cfg.norm, blk["norm2"], h, cfg.norm_eps)
                if cfg.family == "moe":
                    y2, _ = moe.moe_ffn(blk["moe"], cfg, hn)
                else:
                    y2 = mlp.mlp(blk["ffn"], cfg, hn)
                return h + y2, (k, v)

            x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
            state = dataclasses.replace(
                state,
                k_cache=jax.lax.dynamic_update_slice_in_dim(
                    state.k_cache, ks.astype(state.k_cache.dtype), 0, axis=2
                ),
                v_cache=jax.lax.dynamic_update_slice_in_dim(
                    state.v_cache, vs.astype(state.v_cache.dtype), 0, axis=2
                ),
                cache_len=jnp.asarray(seq, jnp.int32),
            )
        elif cfg.family in ("ssm", "hybrid"):
            # Prefill recurrent state by scanning tokens (simple path used by
            # tests/examples; logits come from the parallel forward).
            state = self._prefill_recurrent(params, batch, state, shard)
            logits, _ = self.forward(params, batch, shard)
            return logits[:, -1:], state
        else:
            raise ValueError(f"prefill undefined for family {cfg.family}")

        x = layers.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        return self._head(params, x[:, -1:]), state

    def _prefill_recurrent(self, params, batch, state: DecodeState, shard) -> DecodeState:
        tokens = batch["tokens"]
        seq = tokens.shape[1]

        def step(st, i):
            tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
            _, st = self.decode_step(params, tok, st, shard)
            return st, None

        state, _ = jax.lax.scan(step, state, jnp.arange(seq))
        return state

    def decode_step(
        self,
        params,
        token: Array,  # [B, 1] int32
        state: DecodeState,
        shard: ActivationSharding = NO_SHARD,
    ) -> Tuple[Array, DecodeState]:
        cfg = self.cfg
        x = params["embed"][token]
        pos = state.cache_len

        if cfg.family in ("dense", "moe", "vlm"):

            def body(h, layer):
                blk, kc, vc = layer
                hn = layers.apply_norm(cfg.norm, blk["norm1"], h, cfg.norm_eps)
                y, kc, vc = attention.attend_decode(blk["attn"], cfg, hn, kc, vc, pos, shard)
                h = h + y
                hn = layers.apply_norm(cfg.norm, blk["norm2"], h, cfg.norm_eps)
                if cfg.family == "moe":
                    y2, _ = moe.moe_ffn(blk["moe"], cfg, hn)
                else:
                    y2 = mlp.mlp(blk["ffn"], cfg, hn)
                return h + y2, (kc, vc)

            x, (kcs, vcs) = jax.lax.scan(body, x, (params["blocks"], state.k_cache, state.v_cache))
            state = dataclasses.replace(
                state, k_cache=kcs, v_cache=vcs, cache_len=state.cache_len + 1
            )
        elif cfg.family == "ssm":

            def body(h, layer):
                blk, conv, sst = layer
                hn = layers.apply_norm(cfg.norm, blk["norm"], h, cfg.norm_eps)
                y, conv, sst = ssm.ssm_decode_step(blk["mixer"], cfg, hn, conv, sst)
                return h + y, (conv, sst)

            x, (convs, ssts) = jax.lax.scan(
                body, x, (params["blocks"], state.conv_state, state.ssm_state)
            )
            state = dataclasses.replace(
                state, conv_state=convs, ssm_state=ssts, cache_len=state.cache_len + 1
            )
        elif cfg.family == "hybrid":
            x, state = self._hybrid_decode(params, x, state, shard)
        else:
            raise ValueError(f"decode undefined for family {cfg.family}")

        x = layers.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        return self._head(params, x), state

    def _hybrid_decode(self, params, x, state: DecodeState, shard):
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        n_apps = _attn_layer_count(cfg)
        pos = state.cache_len

        def ssm_body(h, layer):
            blk, conv, sst = layer
            hn = layers.apply_norm(cfg.norm, blk["norm"], h, cfg.norm_eps)
            y, conv, sst = ssm.ssm_decode_step(blk["mixer"], cfg, hn, conv, sst)
            return h + y, (conv, sst)

        convs_out, ssts_out, kcs_out, vcs_out = [], [], [], []
        done = 0
        for g in range(n_apps):
            blk = params["shared"]
            hn = layers.apply_norm(cfg.norm, blk["norm1"], x, cfg.norm_eps)
            y, kc, vc = attention.attend_decode(
                blk["attn"], cfg, hn, state.k_cache[g], state.v_cache[g], pos, shard
            )
            x = x + y
            hn = layers.apply_norm(cfg.norm, blk["norm2"], x, cfg.norm_eps)
            x = x + mlp.mlp(blk["ffn"], cfg, hn)
            kcs_out.append(kc)
            vcs_out.append(vc)

            width = min(every, cfg.n_layers - done)
            group = jax.tree.map(lambda a: a[done : done + width], params["blocks"])
            conv_g = state.conv_state[done : done + width]
            sst_g = state.ssm_state[done : done + width]
            x, (conv_n, sst_n) = jax.lax.scan(ssm_body, x, (group, conv_g, sst_g))
            convs_out.append(conv_n)
            ssts_out.append(sst_n)
            done += width

        state = dataclasses.replace(
            state,
            k_cache=jnp.stack(kcs_out),
            v_cache=jnp.stack(vcs_out),
            conv_state=jnp.concatenate(convs_out),
            ssm_state=jnp.concatenate(ssts_out),
            cache_len=state.cache_len + 1,
        )
        return x, state
