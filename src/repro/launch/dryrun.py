import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline terms.

The two lines above MUST stay the first statements in this file — jax locks
the device count at first init, and the dry-run needs 512 placeholder host
devices to build the (2, 16, 16) mesh. Nothing else in the repo sets this
flag (smoke tests and benchmarks see the real single CPU device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single|multi

Each success writes experiments/dryrun/<arch>__<shape>__<mesh>.json with the
memory analysis, cost analysis, and parsed collective bytes that
EXPERIMENTS.md §Dry-run / §Roofline report.
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str, smoke: bool = False,
             overrides: dict | None = None) -> dict:
    # Imports deferred so XLA_FLAGS is set before any jax init.
    import repro.configs as configs
    from repro.configs.base import SHAPES
    from repro.launch import cells as cells_mod
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as ra

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256

    t0 = time.time()
    cell = cells_mod.build_cell(arch, shape_name, mesh, smoke=smoke, **(overrides or {}))
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {mesh_name}] {cell.kind} ({cell.notes})")
    print("  memory_analysis:", mem)
    ca = compiled.cost_analysis() or {}
    print("  cost_analysis: flops=%.3e bytes=%.3e" % (ca.get("flops", 0), ca.get("bytes accessed", 0)))

    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    mf = ra.model_flops_for(cfg, SHAPES[shape_name])
    report = ra.analyze_compiled(
        compiled, arch, shape_name, mesh_name, chips, mf, notes=cell.notes
    )
    print(
        "  roofline: compute=%.3es memory=%.3es collective=%.3es -> %s | useful=%.3f fits=%s"
        % (report.compute_s, report.memory_s, report.collective_s,
           report.bottleneck, report.useful_ratio, report.fits_hbm)
    )
    data = report.to_json()
    data.update(
        kind=cell.kind,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        mem_argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        mem_temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        mem_output_bytes=getattr(mem, "output_size_in_bytes", None),
        mem_alias_bytes=getattr(mem, "alias_size_in_bytes", None),
    )
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json"), "w") as f:
        json.dump(data, f, indent=2)
    return data


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="reduced configs (CI sanity)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    import repro.configs as configs

    if args.mesh == "both":
        meshes = [False, True]
    elif args.mesh == "multi" or args.multi_pod:
        meshes = [True]
    else:
        meshes = [False]

    cells = []
    if args.all:
        for arch in configs.arch_ids():
            for shape in configs.cells_for(arch):
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"skip {arch} x {shape} x {mesh_name} (exists)")
                continue
            try:
                run_cell(arch, shape, multi_pod, args.out, smoke=args.smoke)
            except Exception as e:  # noqa: BLE001 — report and continue
                traceback.print_exc()
                failures.append((arch, shape, multi_pod, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("dry-run OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
