"""Cell construction: one (architecture x input-shape) combination -> the
step function + abstract inputs + shardings the dry-run lowers.

Cell kinds:
  train_4k    -> train_step   (loss+grad+AdamW; fsdp per size heuristic,
                               microbatch grad accumulation)
  prefill_32k -> prefill_step (full forward, chunked attention)
  decode_32k  -> serve_step   (one token vs a seq_len dense KV cache,
                               sequence-parallel KV sharding)
  long_500k   -> serve_step   (SSM: recurrent state; hybrid: TIERED
                               compressed KV pools — the paper's technique
                               in the lowered artifact)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.configs.base import ModelConfig, ParallelConfig, SHAPES, TierScapeRunConfig
from repro.models import inputs as minputs
from repro.models.transformer import Model, _attn_layer_count
from repro.optim import adamw, tiered_adam
from repro.runtime import serve as serve_rt
from repro.runtime import sharding as shr
from repro.runtime import train as train_rt

PyTree = Any


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable
    in_shardings: Tuple
    abstract_args: Tuple
    mesh: Mesh
    notes: str = ""
    donate: Tuple[int, ...] = ()
    # Pinning outputs to the input shardings keeps donation/aliasing intact
    # (otherwise XLA may pick a different output layout and materialize a
    # full copy of donated state, e.g. a 32k KV cache).
    out_shardings: Any = None

    def lower(self):
        kw = {}
        if self.out_shardings is not None:
            kw["out_shardings"] = self.out_shardings
        with self.mesh:
            return jax.jit(
                self.fn, in_shardings=self.in_shardings, donate_argnums=self.donate, **kw
            ).lower(*self.abstract_args)


def _sds(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _shardings(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def _dp(mesh: Mesh) -> int:
    return shr.axis_size(mesh, "data") * shr.axis_size(mesh, "pod")


def default_parallel(cfg: ModelConfig, shape_name: str, mesh: Mesh) -> ParallelConfig:
    kind = SHAPES[shape_name].kind
    params_gb = cfg.param_count() * 2 / 1024**3
    tp = shr.axis_size(mesh, "model")
    if kind == "train":
        # Training: params + f32 moments resident -> FSDP early.
        fsdp = params_gb / max(tp, 1) > 2.0
    else:
        # Inference: only bf16 params resident; FSDP would re-gather params
        # every decode token — avoid unless TP alone can't fit them.
        fsdp = params_gb / max(tp, 1) > 8.0
    accum = 1
    if kind == "train":
        sh = SHAPES[shape_name]
        local_batch = max(sh.global_batch // _dp(mesh), 1)
        # Per-microbatch activation budget, tuned per family: SSD's chunk
        # tensors (f32 [B,nc,H,ch,ch]) and MoE's dispatch buffers blow up
        # much faster per token than a dense residual stream.
        target_mb = {"ssm": 16, "hybrid": 16, "moe": 64, "vlm": 64}.get(cfg.family, 128)
        per_seq_bytes = sh.seq_len * max(cfg.d_model, 1) * 2
        micro = max(int((target_mb << 20) // per_seq_bytes), 1)
        while local_batch % micro and micro > 1:
            micro -= 1
        accum = max(local_batch // micro, 1)
    return ParallelConfig(
        fsdp=fsdp,
        grad_accum=accum,
        shard_kv_seq=(kind == "decode" and cfg.has_attention),
    )


def moe_tiered_policy(params_shape) -> dict:
    """MoE train cells store moments through compressed tiers (embeddings &
    expert weights int8) — paper technique applied to training state, and
    what makes the 235B fit the pod."""
    policy = {}

    def visit(path, leaf):
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if "embed" in p or "lm_head" in p or "/moe/w_" in p:
            policy[p] = "int8"
        else:
            policy[p] = "none"

    jax.tree_util.tree_map_with_path(visit, params_shape)
    return policy


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    parallel: Optional[ParallelConfig] = None,
    smoke: bool = False,
    tiered_kv: Optional[bool] = None,
    page_tokens: int = 64,
    warm_frac: float = 0.125,
) -> Cell:
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    shape = SHAPES[shape_name]
    parallel = parallel or default_parallel(cfg, shape_name, mesh)
    model = Model(cfg, parallel)
    notes = f"fsdp={parallel.fsdp} accum={parallel.grad_accum} kvseq={parallel.shard_kv_seq}"

    params_shape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    p_specs = shr.param_specs(params_shape, cfg, mesh, parallel)
    p_shard = _shardings(mesh, p_specs)

    if shape.kind == "train":
        batch_sds = minputs.train_batch_spec(cfg, shape.global_batch, shape.seq_len)
        tiered_policy = moe_tiered_policy(params_shape) if cfg.family == "moe" else None
        step = train_rt.make_train_step(
            model, adamw.AdamWConfig(), mesh, parallel, batch_sds, tiered_policy
        )
        if tiered_policy is not None:
            opt_sds = jax.eval_shape(lambda p: tiered_adam.init(p, tiered_policy), params_shape)
        else:
            opt_sds = jax.eval_shape(adamw.init, params_shape)
        args = (params_shape, opt_sds, batch_sds)
        in_sh = (
            p_shard,
            _shardings(mesh, step.opt_specs),
            _shardings(mesh, step.batch_specs),
        )
        out_sh = (in_sh[0], in_sh[1], None)
        return Cell(arch, shape_name, "train", step.fn, in_sh, args, mesh, notes,
                    donate=(0, 1), out_shardings=out_sh)

    if shape.kind == "prefill":
        batch_sds = minputs.train_batch_spec(cfg, shape.global_batch, shape.seq_len)
        batch_sds.pop("targets", None)
        batch_sds.pop("loss_mask", None)
        fn, _ = serve_rt.make_prefill_step(model, mesh, parallel)
        b_specs = shr.batch_spec(mesh, batch_sds)
        args = (params_shape, batch_sds)
        in_sh = (p_shard, _shardings(mesh, b_specs))
        return Cell(arch, shape_name, "prefill", fn, in_sh, args, mesh, notes)

    # ---- decode kinds -------------------------------------------------------
    assert cfg.is_decoder, f"{arch} has no decode step"
    use_tiered = tiered_kv if tiered_kv is not None else (
        shape_name == "long_500k" and cfg.has_attention
    )
    bsz = shape.global_batch

    if use_tiered:
        ts_cfg = TierScapeRunConfig(enabled=True)
        la = _attn_layer_count(cfg)
        n_pages = shape.seq_len // page_tokens
        warm_pages = max(int(n_pages * warm_frac) * max(bsz, 1), 8)
        cold_pages = max(n_pages * max(bsz, 1), 8)
        tkv = jax.eval_shape(
            lambda: serve_rt.init_tiered_kv_state(
                cfg,
                bsz,
                page_tokens=page_tokens,
                warm_pages=warm_pages,
                cold_pages=cold_pages,
                max_pages_per_seq=n_pages,
                recent_window=256,
                n_attn_layers=la,
            )
        )
        if cfg.family == "hybrid":
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            cconv = di + 2 * s.n_groups * s.d_state
            ssm_sds = (
                jax.ShapeDtypeStruct((cfg.n_layers, bsz, s.conv_kernel - 1, cconv), jnp.bfloat16),
                jax.ShapeDtypeStruct(
                    (cfg.n_layers, bsz, s.n_heads(cfg.d_model), s.head_dim, s.d_state),
                    jnp.float32,
                ),
            )
        else:
            ssm_sds = (
                jax.ShapeDtypeStruct((0,), jnp.float32),
                jax.ShapeDtypeStruct((0,), jnp.float32),
            )
        fn = serve_rt.make_tiered_decode_step(model, mesh, parallel, ts_cfg, use_kernels=False)
        tkv_specs = serve_rt.tiered_kv_state_specs(mesh, parallel, bsz, cold_pages)
        bax = shr.bax_spec(mesh, bsz)
        ssm_specs = (P(None, bax, None, None), P(None, bax, None, None, None)) if cfg.family == "hybrid" else (P(), P())
        tok = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
        args = (params_shape, tok, tkv, ssm_sds)
        in_sh = (
            p_shard,
            NamedSharding(mesh, P(bax, None)),
            _shardings(mesh, tkv_specs),
            _shardings(mesh, ssm_specs),
        )
        out_sh = (NamedSharding(mesh, P(bax, None, None)), in_sh[2], in_sh[3], None)
        return Cell(arch, shape_name, "tiered_decode", fn, in_sh, args, mesh,
                    notes + f" tiered_kv pages={n_pages} pt={page_tokens}",
                    donate=(2, 3), out_shardings=out_sh)

    # Dense-cache decode (or SSM-state decode). Cache length padded to a
    # multiple of TP so the kv-seq axis can shard.
    max_len = shape.seq_len + 64
    state_sds = jax.eval_shape(lambda: model.init_cache(bsz, max_len))
    s_specs = shr.decode_state_specs(cfg, mesh, parallel, bsz, max_len)
    act_shard = shr.activation_sharding(mesh, parallel, bsz)

    def step(params, token, state):
        return model.decode_step(params, token, state, shard=act_shard)

    bax = shr.bax_spec(mesh, bsz)
    tok = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
    args = (params_shape, tok, state_sds)
    in_sh = (p_shard, NamedSharding(mesh, P(bax, None)), _shardings(mesh, s_specs))
    out_sh = (NamedSharding(mesh, P(bax, None, None)), in_sh[2])
    return Cell(arch, shape_name, "decode", step, in_sh, args, mesh, notes,
                donate=(2,), out_shardings=out_sh)
