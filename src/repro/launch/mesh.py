"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — ``pod`` is a
second data-parallel axis crossing the inter-pod (DCN) boundary.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the fake-device count before any init).
"""

from __future__ import annotations

import jax
import jax.sharding as jsh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(jsh.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh helper for tests/examples (1-device CPU friendly)."""
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=(jsh.AxisType.Auto,) * len(axes))
