"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — ``pod`` is a
second data-parallel axis crossing the inter-pod (DCN) boundary.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the fake-device count before any init).

The helpers below paper over the jax.sharding API drift around meshes:
newer jax exposes ``AxisType`` / ``make_mesh(..., axis_types=)`` and
``AbstractMesh(shape, names)``; 0.4.x has neither. All call sites in this
repo go through these helpers so the rest of the code is version-agnostic.
"""

from __future__ import annotations

import jax
import jax.sharding as jsh

_HAS_AXIS_TYPES = hasattr(jsh, "AxisType")


def _mk(shape, axes):
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape), tuple(axes), axis_types=(jsh.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper for tests/examples (1-device CPU friendly)."""
    return _mk(shape, axes)


def make_abstract_mesh(shape, axes):
    """Device-free mesh (spec logic only needs axis sizes, not devices)."""
    try:  # newer jax: AbstractMesh(shape, axis_names)
        return jsh.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:  # 0.4.x: AbstractMesh(((name, size), ...))
        return jsh.AbstractMesh(tuple(zip(axes, shape)))
