"""Fault-tolerant checkpointing: atomic, sharded, async, resumable.

Layout (one directory per step):

    <root>/step_000042.tmp/      # written here first
        shard_<host>.npz         # this host's param/opt leaves (flat index)
        MANIFEST.json            # treedef, leaf index, shapes/dtypes, crc
    <root>/step_000042/          # atomic rename on completion
    <root>/LATEST                # text file, updated last (commit point)

Crash-consistency: a checkpoint exists iff its directory was renamed and
LATEST points at it — a torn write leaves only a ``.tmp`` that restore
ignores and cleanup deletes. The async writer snapshots leaves to host
memory synchronously (cheap) and does file IO on a worker thread so the
train loop never blocks (overlap, like Orbax async).

On restore after an elastic re-shard, every host reads the manifest and
loads only the leaves it now owns (here: whole trees on one host; the
multi-host split hooks are the `host_leaves` argument).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, PyTree], blocking: bool = True) -> None:
        """Snapshot to host memory now; write asynchronously unless blocking."""
        self.wait()  # one outstanding write at a time
        snap = {}
        meta = {}
        for name, tree in state.items():
            items = _flatten_with_paths(tree)
            snap[name] = [(k, np.asarray(v)) for k, v in items if v is not None]
            meta[name] = [
                {"key": k, "shape": list(np.asarray(v).shape), "dtype": str(np.asarray(v).dtype)}
                for k, v in items
                if v is not None
            ]

        def write():
            try:
                tmp = os.path.join(self.root, f"step_{step:08d}.tmp")
                final = os.path.join(self.root, f"step_{step:08d}")
                os.makedirs(tmp, exist_ok=True)
                crc = {}
                for name, items in snap.items():
                    arrs = {f"leaf_{i}": v for i, (k, v) in enumerate(items)}
                    path = os.path.join(tmp, f"{name}.npz")
                    np.savez(path, **arrs)
                    crc[name] = zlib.crc32(open(path, "rb").read())
                with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                    json.dump({"step": step, "meta": meta, "crc": crc}, f)
                os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
                with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
                    f.write(os.path.basename(final))
                os.replace(os.path.join(self.root, "LATEST.tmp"), os.path.join(self.root, "LATEST"))
                self._gc()
            except Exception as e:  # surfaced on next wait()/save()
                self.last_error = e

        if blocking:
            write()
            if self.last_error:
                raise self.last_error
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)
        for d in os.listdir(self.root):
            if d.endswith(".tmp") and d != "LATEST.tmp":
                shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.root, "LATEST")
        if not os.path.exists(latest):
            return None
        name = open(latest).read().strip()
        if not os.path.isdir(os.path.join(self.root, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, example_state: Dict[str, PyTree], step: Optional[int] = None
                ) -> Tuple[int, Dict[str, PyTree]]:
        """Returns (step, state) with leaves shaped like example_state."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no committed checkpoint found")
        d = os.path.join(self.root, f"step_{step:08d}")
        manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
        out = {}
        for name, tree in example_state.items():
            path = os.path.join(d, f"{name}.npz")
            data = np.load(path)
            if zlib.crc32(open(path, "rb").read()) != manifest["crc"][name]:
                raise IOError(f"checkpoint corruption in {path}")
            items = _flatten_with_paths(tree)
            keys = [k for k, v in items if v is not None]
            want = [m["key"] for m in manifest["meta"][name]]
            if keys != want:
                raise ValueError(f"tree mismatch for {name}: {keys[:3]}... vs {want[:3]}...")
            leaves = [data[f"leaf_{i}"] for i in range(len(want))]
            flat = []
            it = iter(leaves)
            for k, v in items:
                flat.append(None if v is None else next(it))
            treedef = jax.tree.structure(tree)
            out[name] = jax.tree.unflatten(treedef, flat)
        return manifest["step"], out
