"""Train-step builder: loss -> grad -> (optionally compressed) reduce ->
AdamW/tiered-AdamW update, with microbatch gradient accumulation, donation,
and sharding in/out specs for pjit.

The returned ``TrainStep`` bundles the pure function with the exact
in/out shardings the launcher and the dry-run lower it with.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.models.transformer import Model
from repro.optim import adamw, tiered_adam
from repro.optim.adamw import AdamWConfig
from repro.runtime import sharding as shr

PyTree = Any


@dataclasses.dataclass
class TrainStep:
    fn: Callable  # (params, opt_state, batch) -> (params, opt_state, metrics)
    params_specs: PyTree
    opt_specs: PyTree
    batch_specs: PyTree
    mesh: Mesh

    def jitted(self, donate: bool = True):
        in_shardings = (
            jax.tree.map(lambda s: NamedSharding(self.mesh, s), self.params_specs,
                         is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: NamedSharding(self.mesh, s), self.opt_specs,
                         is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: NamedSharding(self.mesh, s), self.batch_specs,
                         is_leaf=lambda x: isinstance(x, P)),
        )
        return jax.jit(
            self.fn,
            in_shardings=in_shardings,
            donate_argnums=(0, 1) if donate else (),
        )


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    parallel: ParallelConfig,
    batch_example: PyTree,
    tiered_policy: Optional[dict] = None,
) -> TrainStep:
    cfg = model.cfg
    bs_leaf = next(iter(jax.tree.leaves(batch_example)))
    act_shard = shr.activation_sharding(mesh, parallel, int(bs_leaf.shape[0]))
    use_tiered = tiered_policy is not None

    # Gradients must live in the PARAM layout at all times: XLA otherwise
    # picks a layer-dim sharding for scanned-weight cotangents and the
    # reshard at the optimizer boundary degenerates to full replication
    # ("involuntary full rematerialization" — observed 1TB/device on MoE).
    params_shape0 = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    grad_specs = shr.param_specs(params_shape0, cfg, mesh, parallel)

    def pin_grads(g):
        def one(leaf, spec):
            try:
                return jax.lax.with_sharding_constraint(leaf, NamedSharding(mesh, spec))
            except (ValueError, TypeError):
                return leaf

        return jax.tree.map(one, g, grad_specs)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, shard=act_shard)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        accum = parallel.grad_accum
        if accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, pin_grads(grads)
        # Microbatch accumulation: scan over leading splits; grads in f32.
        micro = {}
        for k, v in batch.items():
            if k == "positions" and v.ndim == 3 and v.shape[0] == 3:
                micro[k] = v.reshape(3, accum, v.shape[1] // accum, *v.shape[2:]).swapaxes(0, 1)
            else:
                micro[k] = v.reshape(accum, v.shape[0] // accum, *v.shape[1:])

        zero_g = pin_grads(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

        def body(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), g = grad_fn(params, mb)
            g = pin_grads(g)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (pin_grads(g_acc), loss_acc + loss), None

        (g, loss_sum), _ = jax.lax.scan(body, (zero_g, jnp.zeros((), jnp.float32)), micro)
        g = jax.tree.map(lambda a: a / accum, g)
        loss = loss_sum / accum
        return loss, {"loss": loss}, g

    if use_tiered:

        def step(params, opt_state, batch):
            loss, metrics, grads = compute_grads(params, batch)
            new_params, new_state, om = tiered_adam.update(grads, opt_state, params, opt_cfg)
            metrics = dict(metrics, **om)
            return new_params, new_state, metrics

    else:

        def step(params, opt_state, batch):
            loss, metrics, grads = compute_grads(params, batch)
            new_params, new_state, om = adamw.update(grads, opt_state, params, opt_cfg)
            metrics = dict(metrics, **om)
            return new_params, new_state, metrics

    # --- shardings ----------------------------------------------------------
    params_shape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    p_specs = shr.param_specs(params_shape, cfg, mesh, parallel)
    if use_tiered:
        opt_shape = jax.eval_shape(
            lambda p: tiered_adam.init(p, tiered_policy), params_shape
        )
        # Compressed payloads keep the param's leading dims (grouping is
        # last-axis only), so they inherit the param spec wherever it still
        # divides; scales drop the last-dim sharding.
        def _fits(spec, shape) -> bool:
            parts = list(spec) + [None] * (len(shape) - len(spec))
            for s, d in zip(parts, shape):
                if s is None:
                    continue
                names = s if isinstance(s, tuple) else (s,)
                size = 1
                for n in names:
                    size *= shr.axis_size(mesh, n)
                if d % size:
                    return False
            return True

        def moment_spec(spec, param_leaf, mom_leaf):
            if _fits(spec, mom_leaf.shape):
                return spec
            parts = list(spec)
            if parts:
                parts[-1] = None
            cand = P(*parts)
            return cand if _fits(cand, mom_leaf.shape) else P()

        def scale_spec(spec, param_leaf, sc_leaf):
            if sc_leaf.shape[0] == 0:
                return P()
            parts = list(spec) + [None] * (len(sc_leaf.shape) - len(spec))
            parts[-1] = None
            cand = P(*parts)
            return cand if _fits(cand, sc_leaf.shape) else P()

        o_specs = tiered_adam.TieredAdamState(
            m=jax.tree.map(moment_spec, p_specs, params_shape, opt_shape.m,
                           is_leaf=lambda x: isinstance(x, P)),
            m_scales=jax.tree.map(scale_spec, p_specs, params_shape, opt_shape.m_scales,
                                  is_leaf=lambda x: isinstance(x, P)),
            v=jax.tree.map(moment_spec, p_specs, params_shape, opt_shape.v,
                           is_leaf=lambda x: isinstance(x, P)),
            v_scales=jax.tree.map(scale_spec, p_specs, params_shape, opt_shape.v_scales,
                                  is_leaf=lambda x: isinstance(x, P)),
            step=P(),
            policy=opt_shape.policy,
        )
    else:
        # ZeRO-1: moments shard over data even where params are replicated.
        m_specs = shr.zero1_moment_specs(p_specs, params_shape, mesh)
        o_specs = {"m": m_specs, "v": m_specs, "step": P()}
    b_specs = shr.batch_spec(mesh, batch_example)
    return TrainStep(fn=step, params_specs=p_specs, opt_specs=o_specs,
                     batch_specs=b_specs, mesh=mesh)
