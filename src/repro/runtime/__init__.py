from repro.runtime import elastic, serve, sharding, train

__all__ = ["sharding", "train", "serve", "elastic"]
