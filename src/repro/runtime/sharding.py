"""Parameter/activation sharding rules for the (pod, data, model) mesh.

Tensor-parallel layout (Megatron-style) over the ``model`` axis:
  * attention: q heads column-sharded, output row-sharded; KV projections
    shard over kv-heads when divisible, otherwise replicate (GQA kv-heads <
    TP degree is the common case at TP=16 — replicating the small KV
    projections is the standard fix),
  * MLP: gate/up column-, down row-sharded,
  * MoE: experts sharded over ``model`` (expert parallelism); router
    replicated,
  * embeddings / lm_head: vocab-sharded,
  * SSM blocks: replicated (sub-1B backbones — TP buys nothing; pure DP;
    recorded in DESIGN.md),
  * norms/biases/scales: replicated.

``pod`` and ``data`` are both batch axes. With ``fsdp=True`` the d_model
dimension of the large block weights and both moment trees additionally
shard over ``data`` (ZeRO-3 style), which is what lets the 235B MoE fit.

Everything is path-pattern driven so new archs inherit rules for free.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple  # noqa: F401

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.attention import ActivationSharding

PyTree = Any


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_axes_for(mesh: Mesh, batch_size: int) -> Tuple[str, ...]:
    """Largest prefix of the batch axes whose product divides batch_size —
    jit input shardings require exact divisibility, so small batches
    (long_500k has global_batch=1) shard over fewer axes or none."""
    out = []
    prod = 1
    for a in batch_axes(mesh):
        sz = axis_size(mesh, a)
        if batch_size % (prod * sz) == 0:
            out.append(a)
            prod *= sz
        else:
            break
    return tuple(out)


def bax_spec(mesh: Mesh, batch_size: int):
    axes = batch_axes_for(mesh, batch_size)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def param_spec(
    path: str,
    shape: Tuple[int, ...],
    cfg: ModelConfig,
    mesh: Mesh,
    parallel: ParallelConfig,
) -> P:
    """PartitionSpec for one parameter leaf (leading L dim when stacked)."""
    tp = axis_size(mesh, "model")
    dp = axis_size(mesh, "data")
    fsdp = parallel.fsdp

    stacked = path.startswith("blocks/")
    lead: Tuple[Optional[str], ...] = (None,) if stacked else ()

    def spec(*rest):
        return P(*(lead + rest))

    def div(dim: int, ax: int) -> bool:
        return shape[ax + len(lead)] % dim == 0 if dim > 1 else True

    d_shard = "data" if (fsdp and div(dp, 0)) else None  # d_model dim helper

    # ---- embeddings / head -------------------------------------------------
    if re.search(r"(^|/)embed$", path):
        return P("model" if shape[0] % tp == 0 else None, "data" if fsdp and shape[1] % dp == 0 else None)
    if re.search(r"(^|/)lm_head$", path):
        return P("data" if fsdp and shape[0] % dp == 0 else None, "model" if shape[1] % tp == 0 else None)

    # ---- attention ---------------------------------------------------------
    # Head-count-divisible -> Megatron head sharding. Otherwise fall back to
    # sharding the d_model (contraction) dim over "model" — partial-sum
    # matmuls + an all-reduce, works for any head count (20 MHA heads on
    # TP=16, GQA kv=8 on TP=16, ...). jit input shardings require exact
    # divisibility, so uneven head sharding is not an option.
    if re.search(r"attn/wq$", path):
        if div(tp, 1):
            return spec(d_shard, "model", None)
        return spec("model" if div(tp, 0) else d_shard, None, None)
    if re.search(r"attn/w[kv]$", path):
        if div(tp, 1):
            return spec(d_shard, "model", None)
        return spec("model" if div(tp, 0) else d_shard, None, None)
    if re.search(r"attn/wo$", path):
        if div(tp, 0):
            return spec("model", None, d_shard)
        return spec(None, None, "model" if div(tp, 2) else d_shard)
    if re.search(r"attn/b[qkv]$", path) or re.search(r"attn/bo$", path):
        return spec(*((None,) * (len(shape) - len(lead))))

    # ---- MoE ---------------------------------------------------------------
    # Experts over ``model`` (EP) and the FFN dim over ``data`` — expert-TP
    # instead of FSDP: weights never re-gather per microbatch (the dominant
    # collective at accum=8 on the 235B), at the cost of one ye all-reduce
    # over ``data`` per layer. Moments inherit the fully-sharded layout.
    if re.search(r"moe/router$", path):
        return spec(None, None)
    if re.search(r"moe/w_(gate|up)$", path):
        f_ax = "data" if (fsdp and div(dp, 2)) else None
        return spec("model" if div(tp, 0) else None, None, f_ax)
    if re.search(r"moe/w_down$", path):
        f_ax = "data" if (fsdp and div(dp, 1)) else None
        return spec("model" if div(tp, 0) else None, f_ax, None)

    # ---- dense MLP ---------------------------------------------------------
    if re.search(r"ffn/w_(gate|up)$", path):
        return spec(d_shard, "model" if div(tp, 1) else None)
    if re.search(r"ffn/w_down$", path):
        return spec("model" if div(tp, 0) else None, d_shard)
    if re.search(r"ffn/b_", path):
        return spec(*((None,) * (len(shape) - len(lead))))

    # ---- SSM (replicated; see module docstring) ----------------------------
    if "mixer/" in path:
        return spec(*((None,) * (len(shape) - len(lead))))

    # ---- everything else (norms, scalars) ----------------------------------
    return spec(*((None,) * (len(shape) - len(lead))))


def param_specs(params_shape: PyTree, cfg: ModelConfig, mesh: Mesh, parallel: ParallelConfig) -> PyTree:
    """Pytree of PartitionSpec matching a params (or eval_shape) pytree."""

    def one(path, leaf):
        return param_spec(_path_str(path), tuple(leaf.shape), cfg, mesh, parallel)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def shardings_for(params_shape: PyTree, cfg: ModelConfig, mesh: Mesh, parallel: ParallelConfig):
    specs = param_specs(params_shape, cfg, mesh, parallel)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_specs_tree: PyTree) -> dict:
    """AdamW moments inherit their parameter's spec; step is replicated."""
    return {
        "m": param_specs_tree,
        "v": param_specs_tree,
        "step": P(),
    }


def zero1_moment_specs(param_specs_tree: PyTree, params_shape: PyTree, mesh: Mesh) -> PyTree:
    """ZeRO-1: shard optimizer moments over the ``data`` axis even where the
    parameter itself is replicated (e.g. SSM blocks, odd-head projections).
    The Adam update is elementwise, so sharded moments never gather; only
    the (small, bf16) param delta does. Inserts ``data`` at the first free,
    divisible dimension of each leaf's spec."""
    dp = axis_size(mesh, "data")
    if dp <= 1:
        return param_specs_tree

    def one(spec, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for s in parts:
            if s is None:
                continue
            for a in (s if isinstance(s, tuple) else (s,)):
                used.add(a)
        if "data" in used:
            return spec
        for i, (s, dim) in enumerate(zip(parts, leaf.shape)):
            if s is None and dim % dp == 0 and dim > 0:
                parts[i] = "data"
                return P(*parts)
        return spec

    return jax.tree.map(one, param_specs_tree, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, batch: PyTree) -> PyTree:
    """Inputs shard over the batch axes (divisibility-checked per leaf).
    positions [3,B,S] shard dim 1."""

    def one(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if p == "positions" and nd == 3 and leaf.shape[0] == 3:
            return P(None, bax_spec(mesh, leaf.shape[1]), *([None] * (nd - 2)))
        return P(bax_spec(mesh, leaf.shape[0]), *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(one, batch)


def activation_sharding(
    mesh: Mesh, parallel: ParallelConfig, batch_size: Optional[int] = None
) -> ActivationSharding:
    if batch_size is None:
        axes = batch_axes(mesh)
        bax = axes if len(axes) > 1 else (axes[0] if axes else None)
    else:
        bax = bax_spec(mesh, batch_size)

    def constrain(x, spec):
        try:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        except (ValueError, TypeError):
            return x

    return ActivationSharding(
        batch=bax,
        heads="model" if axis_size(mesh, "model") > 1 else None,
        kv_seq="model" if parallel.shard_kv_seq else None,
        constrain=constrain,
        tp=axis_size(mesh, "model"),
    )


def decode_state_specs(
    cfg: ModelConfig, mesh: Mesh, parallel: ParallelConfig,
    batch_size: int, max_len: int,
):
    """Shardings for DecodeState: KV caches shard over batch (+ model on the
    kv-seq axis when sequence-parallel decode is on)."""
    bax = bax_spec(mesh, batch_size)
    tp = axis_size(mesh, "model")
    kv_seq_ax = "model" if (parallel.shard_kv_seq and max_len % tp == 0) else None
    kvh = cfg.n_kv_heads or 1
    kv_head_ax = None
    if kv_seq_ax is None and cfg.has_attention and kvh % tp == 0 and tp > 1:
        kv_head_ax = "model"
    from repro.models.transformer import DecodeState

    return DecodeState(
        k_cache=P(None, bax, kv_seq_ax, kv_head_ax, None),
        v_cache=P(None, bax, kv_seq_ax, kv_head_ax, None),
        cache_len=P(),
        conv_state=P(None, bax, None, None),
        ssm_state=P(None, bax, None, None, None),
    )
