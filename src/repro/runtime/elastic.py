"""Elastic scaling + failure handling for multi-pod runs.

Failure model (what a 1000+-node deployment actually sees):
  * a host/chip drops -> the collective times out -> the job controller
    kills the step, reforms the mesh from survivors, restores the last
    committed checkpoint, and resumes;
  * capacity returns -> scale back up at the next window boundary.

What lives here:
  * ``plan_remesh``: given surviving device count and the parallel config,
    pick the largest legal (pod, data, model) mesh <= survivors, keeping the
    model axis intact (TP degree is baked into weight layouts; shrinking DP
    is free, shrinking TP requires resharding weights — we keep TP fixed and
    shed data-parallel replicas, the standard elastic policy);
  * ``rebalance_batch``: recompute per-shard batch so the global batch is
    preserved (grad-accum absorbs the lost replicas);
  * ``ElasticRunner``: drives step -> detect -> remesh -> restore -> resume.
    Failures are injected by tests/examples via ``fail_hook``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    grad_accum: int  # multiplier to preserve global batch

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_remesh(
    n_devices: int,
    model_parallel: int,
    global_batch: int,
    microbatch_per_replica: int,
    multi_pod_size: Optional[int] = None,
) -> MeshPlan:
    """Largest legal mesh from ``n_devices`` survivors with TP fixed.

    DP replicas = floor(n / (tp * pod)); grad_accum scales so that
    dp * accum * microbatch == global_batch stays invariant.
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep TP={model_parallel} with {n_devices} devices; "
            "weight resharding required (full restart path)"
        )
    pods = multi_pod_size or 1
    per_pod = n_devices // pods
    dp = max(per_pod // model_parallel, 1)
    used_replicas = dp * pods
    need = global_batch // microbatch_per_replica
    accum = max(int(math.ceil(need / used_replicas)), 1)
    if pods > 1:
        return MeshPlan((pods, dp, model_parallel), ("pod", "data", "model"), accum)
    return MeshPlan((dp, model_parallel), ("data", "model"), accum)


def rebalance_batch(global_batch: int, plan: MeshPlan) -> int:
    replicas = plan.n_devices // plan.shape[-1]
    per = global_batch // (replicas * plan.grad_accum)
    return max(per, 1)


class ElasticRunner:
    """Step-loop wrapper: run, detect injected failures, remesh, restore.

    The controller is deliberately synchronous and host-driven — the same
    structure a GKE/Borg job controller imposes; tests inject failures via
    ``fail_hook(step) -> surviving_device_count | None``.
    """

    def __init__(
        self,
        build_step: Callable[[MeshPlan], Callable],  # returns step_fn(state, batch)
        save_fn: Callable[[int, dict], None],
        restore_fn: Callable[[], Tuple[int, dict]],
        initial_plan: MeshPlan,
        checkpoint_every: int = 50,
        fail_hook: Optional[Callable[[int], Optional[int]]] = None,
        model_parallel: int = 1,
        global_batch: int = 8,
        microbatch_per_replica: int = 1,
    ):
        self.build_step = build_step
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.plan = initial_plan
        self.checkpoint_every = checkpoint_every
        self.fail_hook = fail_hook
        self.model_parallel = model_parallel
        self.global_batch = global_batch
        self.microbatch_per_replica = microbatch_per_replica
        self.remesh_events = []

    def run(self, state: dict, batches, n_steps: int, start_step: int = 0):
        step_fn = self.build_step(self.plan)
        step = start_step
        it = iter(batches)
        while step < n_steps:
            if self.fail_hook is not None:
                survivors = self.fail_hook(step)
                if survivors is not None:
                    # Failure: reform mesh, restore last checkpoint, resume.
                    new_plan = plan_remesh(
                        survivors,
                        self.model_parallel,
                        self.global_batch,
                        self.microbatch_per_replica,
                        multi_pod_size=None,
                    )
                    self.remesh_events.append((step, self.plan, new_plan))
                    self.plan = new_plan
                    step_fn = self.build_step(new_plan)
                    step, state = self.restore_fn()
                    continue
            batch = next(it)
            state = step_fn(state, batch)
            step += 1
            if step % self.checkpoint_every == 0:
                self.save_fn(step, state)
        return step, state
