"""Serve-step builders: prefill and decode (dense KV cache or tiered
compressed KV pools), with the shardings the dry-run lowers against.

``decode`` lowers one engine step: append one token per sequence against a
seq_len-long KV cache — the ``decode_32k`` / ``long_500k`` cells.

``make_tiered_decode_step`` is the paper's technique on the decode path:
the KV cache's warm/cold pages live in two device-resident quantized pools
(host tiers are engine-managed outside the step, visible only as sentinel
rows); attention runs as ONE fused pass over all pools + host sentinels +
the dense recent window (the megakernel with ``use_kernels=True``, its
jnp oracle otherwise). Per-page softmax mass — including the host pages'
would-have-touched mass — comes back as telemetry for the TierScape
manager and its prefetch predictor.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TierScapeRunConfig
from repro.models import layers
from repro.models.transformer import DecodeState, Model
from repro.runtime import sharding as shr

PyTree = Any


@dataclasses.dataclass
class ServeStep:
    fn: Callable
    params_specs: PyTree
    state_specs: PyTree
    token_spec: PyTree
    mesh: Mesh


def make_decode_step(
    model: Model, mesh: Mesh, parallel: ParallelConfig,
    batch_size: int = 1, max_len: int = 1024,
) -> ServeStep:
    cfg = model.cfg
    act_shard = shr.activation_sharding(mesh, parallel, batch_size)

    def step(params, token, state: DecodeState):
        logits, state = model.decode_step(params, token, state, shard=act_shard)
        return logits, state

    params_shape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    p_specs = shr.param_specs(params_shape, cfg, mesh, parallel)
    s_specs = shr.decode_state_specs(cfg, mesh, parallel, batch_size, max_len)
    bax = shr.bax_spec(mesh, batch_size)
    return ServeStep(
        fn=step,
        params_specs=p_specs,
        state_specs=s_specs,
        token_spec=P(bax, None),
        mesh=mesh,
    )


def make_prefill_step(model: Model, mesh: Mesh, parallel: ParallelConfig):
    cfg = model.cfg
    act_shard = shr.activation_sharding(mesh, parallel)

    def step(params, batch):
        logits, aux = model.forward(params, batch, shard=act_shard)
        return logits[:, -1]

    params_shape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    p_specs = shr.param_specs(params_shape, cfg, mesh, parallel)
    return step, p_specs


# ---------------------------------------------------------------------------
# Tiered decode (the paper's technique on the serving path)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TieredKVState:
    """Device-resident tiered KV state for the jitted decode step.

    Payload storage is CODEC-CLASS-MAJOR: one shared int8-class buffer
    (``c8_*``) and one int4-class buffer (``c4_*``), each holding the rows
    of EVERY tier pool of that codec width. Per-pool page tables
    (``warm_table``/``cold_table``) stay, but their entries are GLOBAL rows
    of the pool's class buffer (``SlotAllocator`` row ranges carve the
    buffer up per pool) — so N same-class tiers address one buffer with
    zero per-step payload concatenation in the fused kernel, and same-class
    migrations are pure table edits. With the default warm=int8/cold=int4
    split each class holds exactly one pool and the layout degenerates to
    the former per-pool buffers (bit-identical shapes and addressing).

    Host tiers (C2/C4/C12) hold evicted pages outside the step; the engine
    swaps them through the warm pool. Host-resident pages are still
    *visible* to the step as sentinel rows: a tiny per-page key centroid
    (``host_summary``) + a sentinel table, which the fused attention launch
    scores for would-have-touched hotness telemetry without fetching any
    payload.
    """

    c8_k: jax.Array  # [L, P8, T, KV, hd] int8 — shared int8-class rows
    c8_k_scales: jax.Array  # [L, P8, T, KV] f32
    c8_v: jax.Array
    c8_v_scales: jax.Array
    c4_k: jax.Array  # [L, P4, T, KV, hd//2] uint8 — shared int4-class rows
    c4_k_scales: jax.Array
    c4_v: jax.Array
    c4_v_scales: jax.Array
    warm_table: jax.Array  # [L, B, MPw] int32 — global class-buffer rows
    warm_n: jax.Array  # [L, B] int32
    cold_table: jax.Array
    cold_n: jax.Array
    recent_k: jax.Array  # [L, B, R, KV, hd] bf16
    recent_v: jax.Array
    recent_len: jax.Array  # [B] int32 — per-slot dense-window fill
    total_len: jax.Array  # [B] int32 — per-slot sequence position
    host_summary: jax.Array  # [L, Hs, KV, hd] f32 — host-page key centroids
    host_table: jax.Array  # [L, B, MP] int32 — sentinel rows -> summary slot
    host_n: jax.Array  # [L, B] int32


# Class-buffer payload fields by codec width; ``class_field("c8", "k")`` etc.
CLASS_FIELDS = ("k", "k_scales", "v", "v_scales")


def class_rows_of(
    warm_pages: int, cold_pages: int, warm_bits: int = 8, cold_bits: int = 4
) -> Dict[int, int]:
    """Rows per codec-class buffer for the (warm, cold) pool pair, warm
    range first (the ``ClassPartition`` order the cache's allocators use).
    An empty class keeps one dummy row so the kernel operands stay
    non-degenerate; ``TIER_INVALID`` masking guarantees it is never read."""
    rows = {8: 0, 4: 0}
    rows[warm_bits] += warm_pages
    rows[cold_bits] += cold_pages
    return {b: max(r, 1) for b, r in rows.items()}


def init_tiered_kv_state(
    cfg: ModelConfig,
    batch: int,
    *,
    page_tokens: int,
    warm_pages: int,
    cold_pages: int,
    max_pages_per_seq: int,
    recent_window: int,
    n_attn_layers: int,
    host_slots: Optional[int] = None,
    warm_bits: int = 8,
    cold_bits: int = 4,
) -> TieredKVState:
    hd = cfg.head_dim_()
    kv = cfg.n_kv_heads
    la = n_attn_layers
    t = page_tokens
    hs = max(host_slots if host_slots is not None else cold_pages, 1)
    rows = class_rows_of(warm_pages, cold_pages, warm_bits, cold_bits)
    p8, p4 = rows[8], rows[4]
    return TieredKVState(
        c8_k=jnp.zeros((la, p8, t, kv, hd), jnp.int8),
        c8_k_scales=jnp.ones((la, p8, t, kv), jnp.float32),
        c8_v=jnp.zeros((la, p8, t, kv, hd), jnp.int8),
        c8_v_scales=jnp.ones((la, p8, t, kv), jnp.float32),
        c4_k=jnp.zeros((la, p4, t, kv, hd // 2), jnp.uint8),
        c4_k_scales=jnp.ones((la, p4, t, kv), jnp.float32),
        c4_v=jnp.zeros((la, p4, t, kv, hd // 2), jnp.uint8),
        c4_v_scales=jnp.ones((la, p4, t, kv), jnp.float32),
        warm_table=jnp.zeros((la, batch, max_pages_per_seq), jnp.int32),
        warm_n=jnp.zeros((la, batch), jnp.int32),
        cold_table=jnp.zeros((la, batch, max_pages_per_seq), jnp.int32),
        cold_n=jnp.zeros((la, batch), jnp.int32),
        recent_k=jnp.zeros((la, batch, recent_window, kv, hd), jnp.bfloat16),
        recent_v=jnp.zeros((la, batch, recent_window, kv, hd), jnp.bfloat16),
        recent_len=jnp.zeros((batch,), jnp.int32),
        total_len=jnp.zeros((batch,), jnp.int32),
        host_summary=jnp.zeros((la, hs, kv, hd), jnp.float32),
        host_table=jnp.zeros((la, batch, max_pages_per_seq), jnp.int32),
        host_n=jnp.zeros((la, batch), jnp.int32),
    )


def make_sp_pool_attention(mesh: Mesh, batch_axes: Tuple[str, ...]):
    """Sequence/batch-parallel tiered-pool attention via shard_map.

    Pools shard on the PAGE dim over (batch axes x model): the engine owns
    allocation, placing a sequence's pages on the (pod, data) shard that owns
    the sequence, striped over ``model`` by table slot — so every gather is
    local. Tables shard (batch over data axes, slots over model); each shard
    computes flash partials over its local pages; partials merge with an
    exact logsumexp psum over ``model`` only. Compute, pool HBM and gather
    traffic all divide by the full mesh — the SPMD-auto path instead
    all-gathers the entire dequantized pool (the baseline bottleneck).
    """
    from jax.experimental.shard_map import shard_map

    page_axes: Tuple[str, ...] = tuple(batch_axes) + ("model",)
    page_spec = page_axes if len(page_axes) > 1 else page_axes[0]
    bax = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def partial_fn(q, kp, ks, vp, vs, table, slot_pos, n_pages, bits):
        from repro.kernels import ref as kref

        # Local page ids: global ids striped over every pool shard.
        nshards = 1
        for a in page_axes:
            nshards *= jax.lax.psum(1, a)
        local_table = table // nshards
        out_u, m, l, mass, base = kref.paged_quant_attention(
            q, kp, ks, vp, vs, local_table, n_pages, bits, slot_pos=slot_pos
        )
        # Exact cross-shard logsumexp merge over the slot axis.
        m_tot = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_tot)
        out_m = jax.lax.psum(out_u * w[..., None], "model")
        l_m = jax.lax.psum(l * w, "model")
        return out_m, m_tot, l_m, mass, base

    def run(q, pool, bits):
        mp = pool["page_table"].shape[1]
        b = pool["page_table"].shape[0]
        slot_pos = jnp.broadcast_to(jnp.arange(mp, dtype=jnp.int32)[None], (b, mp))
        fn = shard_map(
            lambda *a: partial_fn(*a, bits=bits),
            mesh=mesh,
            in_specs=(
                P(bax, None, None),  # q: one token per sequence
                P(page_spec, None, None, None),
                P(page_spec, None, None),
                P(page_spec, None, None, None),
                P(page_spec, None, None),
                P(bax, "model"),  # table: batch rows + slots sharded
                P(bax, "model"),  # global slot positions
                P(bax),  # n_pages per batch row
            ),
            out_specs=(
                P(bax, None, None),  # merged out_u
                P(bax, None),  # merged m
                P(bax, None),  # merged l
                P(bax, "model"),  # local masses stay slot-sharded
                P(bax, "model"),
            ),
            check_rep=False,
        )
        return fn(q, pool["k_pages"], pool["k_scales"], pool["v_pages"],
                  pool["v_scales"], pool["page_table"], slot_pos, pool["n_pages"])

    return run


def make_tiered_decode_step(
    model: Model,
    mesh: Mesh,
    parallel: ParallelConfig,
    ts_cfg: TierScapeRunConfig,
    use_kernels: bool = False,
):
    """Decode step over tiered KV pools for attention/hybrid archs.

    Returns (step_fn, specs...). step_fn(params, token, tkv, extra_state)
    -> (logits, tkv, extra_state, telemetry) where extra_state carries the
    SSM states for hybrid archs (None-sized otherwise) and telemetry is the
    per-layer warm/cold page attention mass.
    """
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    from repro.models import attention as attn_mod
    from repro.models import mlp as mlp_mod
    from repro.models import ssm as ssm_mod

    cfg = model.cfg
    act_shard = shr.activation_sharding(mesh, parallel)
    tp = shr.axis_size(mesh, "model")
    # Sequence-parallel pool attention (shard_map): pages, tables, compute
    # and gathers all divide by TP. Requires the engine's slot-striped page
    # allocation (table column j holds pages of shard j*TP//MP).
    use_sp = parallel.shard_kv_seq and tp > 1 and not use_kernels
    sp_attn = None
    _batch_axes_holder = []
    # Device-pool codec widths (class-major: a pool's payload lives in its
    # class's shared buffer). Defaults give the classic warm=int8/cold=int4
    # split; same-width pairs share one buffer with zero per-step copies.
    wb = int(getattr(ts_cfg, "warm_bits", 8))
    cb = int(getattr(ts_cfg, "cold_bits", 4))
    warm_cls = "c8" if wb == 8 else "c4"
    cold_cls = "c8" if cb == 8 else "c4"

    def _make_sp(batch_size):
        return make_sp_pool_attention(mesh, shr.batch_axes_for(mesh, batch_size))

    def attend_tiered(blk, x, layer_tkv, total_len, recent_len):
        """x [B,1,D]; one attention layer against pools + recent window.
        ``total_len``/``recent_len`` are per-slot [B] vectors: each slot
        rotary-encodes at its own position and appends the new token at its
        own dense-window offset (slots hold unequal sequence lengths)."""
        hn = layers.apply_norm(cfg.norm, blk["norm1"], x, cfg.norm_eps)
        b = x.shape[0]
        positions = total_len[:, None].astype(jnp.int32)  # [B, 1]
        q, k_new, v_new = attn_mod._project_qkv(blk["attn"], cfg, hn, positions, act_shard)
        # Per-slot scatter at index recent_len[b]: one-hot masked write (the
        # vector analogue of dynamic_update_slice_in_dim; an index beyond
        # the window writes nothing, matching an inactive slot).
        r = layer_tkv["recent_k"].shape[1]
        at = (jnp.arange(r, dtype=jnp.int32)[None, :] == recent_len[:, None])
        at = at[:, :, None, None]  # [B, R, 1, 1]
        recent_k = jnp.where(
            at, k_new.astype(layer_tkv["recent_k"].dtype), layer_tkv["recent_k"]
        )
        recent_v = jnp.where(
            at, v_new.astype(layer_tkv["recent_v"].dtype), layer_tkv["recent_v"]
        )
        # Class-major pools: each pool's payload arrays ARE its codec
        # class's shared buffer (same jax array object when two pools share
        # a class — the zero-concat contract ``ops._unified_operands``
        # detects by identity); tables hold global class-buffer rows.
        def pool_of(cls, table, n, bits):
            return {
                "k_pages": layer_tkv[f"{cls}_k"],
                "k_scales": layer_tkv[f"{cls}_k_scales"],
                "v_pages": layer_tkv[f"{cls}_v"],
                "v_scales": layer_tkv[f"{cls}_v_scales"],
                "page_table": layer_tkv[table],
                "n_pages": layer_tkv[n],
                "bits": bits,
            }

        pools = {
            "warm": pool_of(warm_cls, "warm_table", "warm_n", wb),
            "cold": pool_of(cold_cls, "cold_table", "cold_n", cb),
        }
        # Host sentinel rows ride the same attention pass: no payload, just
        # the per-page key centroid scored for would-have-touched mass.
        host = {
            "summary": layer_tkv["host_summary"],
            "table": layer_tkv["host_table"],
            "n": layer_tkv["host_n"],
            "page_tokens": layer_tkv[f"{warm_cls}_k"].shape[1],
        }
        if use_kernels:
            # Fused megakernel: ONE Pallas launch for all pools + host
            # sentinels + the recent window (see kernels/ops.py).
            out, hot = kops.tiered_decode_attention(
                q[:, 0], pools, recent_k, recent_v, recent_len + 1, cfg,
                with_telemetry=True, host=host,
            )
        elif use_sp:
            sp = _make_sp(b)
            parts = [kref.dense_recent_attention(q[:, 0], recent_k, recent_v, recent_len + 1)]
            hot = {}
            for name in ("warm", "cold"):
                out_u, m, l, mass, _base = sp(q[:, 0], pools[name], pools[name]["bits"])
                parts.append((out_u, m, l))
                hot[name] = mass  # unnormalized local masses (telemetry)
            hot["host"], _ = kref.host_page_mass(
                q[:, 0], host["summary"], host["table"], host["n"], host["page_tokens"]
            )
            out = kref.merge_partials(parts)
        else:
            # Pure-jnp fused oracle: same semantics as the megakernel
            # (exact merge + live telemetry incl. host mass), XLA-fused.
            out, m_tot, l_tot, masses = kref.fused_tiered_attention(
                q[:, 0], pools, recent_k, recent_v, recent_len + 1, host=host
            )
            hot = {
                name: kops.page_hotness(mass, base, m_tot, l_tot)
                for name, (mass, base) in masses.items()
            }
        y = jnp.einsum("bhk,hkd->bd", out.astype(x.dtype), blk["attn"]["wo"])[:, None]
        if cfg.attn_out_bias:
            y = y + blk["attn"]["bo"]
        return x + y, recent_k, recent_v, hot

    def step(params, token, tkv: TieredKVState, ssm_state):
        x = params["embed"][token]
        recent_len = tkv.recent_len
        total_len = tkv.total_len
        telemetry = {"warm": [], "cold": [], "host": []}

        new_recent_k, new_recent_v = [], []
        if cfg.family == "hybrid":
            every = cfg.hybrid_attn_every
            n_apps = tkv.recent_k.shape[0]
            conv_states, ssm_states = ssm_state
            new_conv, new_ssm = [], []

            def ssm_body(h, layer):
                blk, conv, sst = layer
                hn = layers.apply_norm(cfg.norm, blk["norm"], h, cfg.norm_eps)
                y, conv, sst = ssm_mod.ssm_decode_step(blk["mixer"], cfg, hn, conv, sst)
                return h + y, (conv, sst)

            done = 0
            for g in range(n_apps):
                layer_tkv = {
                    f: getattr(tkv, f)[g]
                    for f in (
                        "c8_k", "c8_k_scales", "c8_v", "c8_v_scales",
                        "c4_k", "c4_k_scales", "c4_v", "c4_v_scales",
                        "warm_table", "warm_n", "cold_table", "cold_n",
                        "recent_k", "recent_v",
                        "host_summary", "host_table", "host_n",
                    )
                }
                x, rk, rv, hot = attend_tiered(params["shared"], x, layer_tkv, total_len, recent_len)
                hn = layers.apply_norm(cfg.norm, params["shared"]["norm2"], x, cfg.norm_eps)
                x = x + mlp_mod.mlp(params["shared"]["ffn"], cfg, hn)
                new_recent_k.append(rk)
                new_recent_v.append(rv)
                telemetry["warm"].append(hot["warm"])
                telemetry["cold"].append(hot["cold"])
                telemetry["host"].append(hot["host"])

                width = min(every, cfg.n_layers - done)
                group = jax.tree.map(lambda a: a[done : done + width], params["blocks"])
                x, (cv, ss) = jax.lax.scan(
                    ssm_body, x, (group, conv_states[done : done + width], ssm_states[done : done + width])
                )
                new_conv.append(cv)
                new_ssm.append(ss)
                done += width
            ssm_state = (jnp.concatenate(new_conv), jnp.concatenate(new_ssm))
        else:
            n_layers = tkv.recent_k.shape[0]
            for li in range(n_layers):
                blk = jax.tree.map(lambda a: a[li], params["blocks"])
                layer_tkv = {
                    f: getattr(tkv, f)[li]
                    for f in (
                        "c8_k", "c8_k_scales", "c8_v", "c8_v_scales",
                        "c4_k", "c4_k_scales", "c4_v", "c4_v_scales",
                        "warm_table", "warm_n", "cold_table", "cold_n",
                        "recent_k", "recent_v",
                        "host_summary", "host_table", "host_n",
                    )
                }
                x, rk, rv, hot = attend_tiered(blk, x, layer_tkv, total_len, recent_len)
                hn = layers.apply_norm(cfg.norm, blk["norm2"], x, cfg.norm_eps)
                if cfg.family == "moe":
                    y2, _ = moe_ffn_local(blk, x, hn)
                else:
                    y2 = mlp_mod.mlp(blk["ffn"], cfg, hn)
                x = x + y2
                new_recent_k.append(rk)
                new_recent_v.append(rv)
                telemetry["warm"].append(hot["warm"])
                telemetry["cold"].append(hot["cold"])
                telemetry["host"].append(hot["host"])

        tkv = dataclasses.replace(
            tkv,
            recent_k=jnp.stack(new_recent_k),
            recent_v=jnp.stack(new_recent_v),
            recent_len=recent_len + 1,
            total_len=total_len + 1,
        )
        x = layers.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        logits = model._head(params, x)
        telemetry = {k: jnp.stack(v) for k, v in telemetry.items()}
        return logits, tkv, ssm_state, telemetry

    def moe_ffn_local(blk, x, hn):
        from repro.models import moe as moe_mod

        return moe_mod.moe_ffn(blk["moe"], cfg, hn)

    return step


def tiered_kv_state_specs(
    mesh: Mesh, parallel: ParallelConfig, batch_size: int = 1, n_pool_pages: int = 0
) -> TieredKVState:
    """Pool pages shard over the model axis (sequence-parallel KV: each model
    shard owns a slice of every sequence's pages); batch dims over data."""
    bax = shr.bax_spec(mesh, batch_size)
    tp = shr.axis_size(mesh, "model")
    axes = shr.batch_axes_for(mesh, batch_size) + ("model",)
    n_shards = 1
    for a in axes:
        n_shards *= shr.axis_size(mesh, a)
    sp_on = parallel.shard_kv_seq and tp > 1 and n_pool_pages and n_pool_pages % n_shards == 0
    page_ax = (axes if len(axes) > 1 else axes[0]) if sp_on else None
    # Table slots shard with the pages (sequence parallelism).
    table_ax = "model" if sp_on else None
    return TieredKVState(
        c8_k=P(None, page_ax, None, None, None),
        c8_k_scales=P(None, page_ax, None, None),
        c8_v=P(None, page_ax, None, None, None),
        c8_v_scales=P(None, page_ax, None, None),
        c4_k=P(None, page_ax, None, None, None),
        c4_k_scales=P(None, page_ax, None, None),
        c4_v=P(None, page_ax, None, None, None),
        c4_v_scales=P(None, page_ax, None, None),
        warm_table=P(None, bax, table_ax),
        warm_n=P(None, bax),
        cold_table=P(None, bax, table_ax),
        cold_n=P(None, bax),
        recent_k=P(None, bax, None, None, None),
        recent_v=P(None, bax, None, None, None),
        recent_len=P(bax),
        total_len=P(bax),
        # Host sentinel summaries are tiny (one [KV, hd] vector per page);
        # replicate them like the tables so sentinel gathers stay local.
        host_summary=P(None, None, None, None),
        host_table=P(None, bax, None),
        host_n=P(None, bax),
    )
