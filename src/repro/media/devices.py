"""Backing-media device catalog + deterministic queue/bandwidth model.

A ``MediaDevice`` is the third axis of a software-defined tier (codec x
pool x media): the physical thing a compressed payload is read from and
written to. The cost model is the standard DMA-engine abstraction:

  service_time(bytes) = fixed_latency + bytes / bandwidth

with ``queue_depth`` concurrent channels — a transfer submitted while every
channel is busy queues behind the earliest-finishing one. ``MediaQueue``
evaluates that model in *virtual time* (callers supply ``now``; nothing here
reads a clock), so contention accounting is bit-deterministic across runs —
the property the equivalence and determinism tests lean on.

Presets mirror the platforms the paper's tiers (and the CXL follow-on work)
are built from; HBM/host numbers come from ``core/hw.py`` so the device
model and the per-tier latency model (Eq. 8) agree by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core import hw


@dataclasses.dataclass(frozen=True)
class MediaDevice:
    """One backing-media device class and its transfer cost model."""

    name: str
    read_bw: float  # sustained B/s
    write_bw: float  # sustained B/s
    fixed_latency_s: float  # per-op setup (DMA descriptor / doorbell / link RTT)
    queue_depth: int  # concurrent in-flight transfers the device sustains

    def __post_init__(self):
        if self.read_bw <= 0 or self.write_bw <= 0:
            raise ValueError("bandwidth must be positive")
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")

    def service_time_s(self, n_bytes: int, write: bool = False) -> float:
        """Uncontended transfer time for one op of ``n_bytes``."""
        bw = self.write_bw if write else self.read_bw
        return self.fixed_latency_s + n_bytes / bw

    def batch_service_time_s(
        self, n_bytes: int, ops: int = 1, write: bool = False
    ) -> float:
        """Uncontended transfer time for an aggregate of ``ops`` operations
        totalling ``n_bytes`` (each op pays the fixed setup cost) — the same
        formula ``MediaQueue.submit`` charges, exposed for callers that need
        the service time without touching queue state (stall accounting)."""
        bw = self.write_bw if write else self.read_bw
        return ops * self.fixed_latency_s + n_bytes / bw


# ---------------------------------------------------------------------------
# Catalog. Every preset reuses the hw.py constants so the TierSpec latency
# model (Eq. 8) and the device model price the same hardware the same way —
# including the CXL and NVMe swap devices (their numbers used to be forked
# literals here; they now have one definition in core/hw.py).
# ---------------------------------------------------------------------------

DEVICES: Dict[str, MediaDevice] = {
    d.name: d
    for d in (
        MediaDevice("hbm", hw.V5E.hbm_bw, hw.V5E.hbm_bw, 0.0, queue_depth=8),
        MediaDevice(
            "host_dram_pcie",
            hw.V5E.host_link_bw,
            hw.V5E.host_link_bw,
            hw.MEDIA_FIXED_US["host"] * 1e-6,
            queue_depth=4,
        ),
        # CXL 2.0 x8-class memory expander: near-PCIe bandwidth, lower setup
        # cost (load/store semantics, no DMA descriptor round-trip).
        MediaDevice(
            "cxl",
            hw.CXL_LINK_READ_BW,
            hw.CXL_LINK_WRITE_BW,
            hw.CXL_FIXED_LATENCY_S,
            queue_depth=hw.CXL_QUEUE_DEPTH,
        ),
        # The same expander behind a ZeroPoint-style inline line compressor:
        # nominal link numbers here; make_queues wraps this entry in an
        # AdaptiveMediaDevice whose *effective* bandwidth scales with the
        # observed compression ratio of the data moving through it.
        MediaDevice(
            "cxl_hw",
            hw.CXL_LINK_READ_BW,
            hw.CXL_LINK_WRITE_BW,
            hw.CXL_FIXED_LATENCY_S,
            queue_depth=hw.CXL_QUEUE_DEPTH,
        ),
        # Datacenter NVMe (Gen4 x4 class): the deepest, cheapest swap device;
        # long setup, deep queues.
        MediaDevice(
            "nvme",
            hw.NVME_READ_BW,
            hw.NVME_WRITE_BW,
            hw.NVME_FIXED_LATENCY_S,
            queue_depth=hw.NVME_QUEUE_DEPTH,
        ),
    )
}

# Media string (TierSpec.media) -> default device binding. ``cxl`` media in
# this repo means the hardware-compressed expander tier.
DEFAULT_FOR_MEDIA: Dict[str, str] = {
    "hbm": "hbm",
    "host": "host_dram_pcie",
    "cxl": "cxl_hw",
}

# Catalog names make_queues instantiates as compressibility-adaptive.
ADAPTIVE_DEVICES = frozenset({"cxl_hw"})


class AdaptiveMediaDevice:
    """A ``MediaDevice`` whose effective bandwidth tracks data compressibility.

    Models an inline hardware compressor on the media link (ZeroPoint-style
    CXL): when resident data compresses by ``ratio``, each nominal byte costs
    ``1/ratio`` wire bytes, so effective read/write bandwidth is the base
    link rate times the ratio.

    Determinism contract: ``observe`` only *accumulates* real encoded sizes —
    it never changes service times mid-window. ``commit_window`` folds the
    accumulated observation into the committed ratio via an EWMA at the
    window boundary, the only point where the estimate (and therefore any
    service time) may move. Replay of identical submissions with identical
    boundary commits is bit-identical.
    """

    def __init__(self, base: MediaDevice, init_ratio: float = 1.0, ema: float = 0.25):
        if init_ratio < 1.0:
            raise ValueError("init_ratio must be >= 1.0")
        self.base = base
        self.ratio = float(init_ratio)  # committed estimate (boundary-updated)
        self.ema = float(ema)
        self._pending_nominal = 0.0
        self._pending_wire = 0.0

    # -- MediaDevice interface (effective numbers) --------------------------
    @property
    def name(self) -> str:
        return self.base.name

    @property
    def read_bw(self) -> float:
        return self.base.read_bw * self.ratio

    @property
    def write_bw(self) -> float:
        return self.base.write_bw * self.ratio

    @property
    def fixed_latency_s(self) -> float:
        return self.base.fixed_latency_s

    @property
    def queue_depth(self) -> int:
        return self.base.queue_depth

    def service_time_s(self, n_bytes: int, write: bool = False) -> float:
        bw = self.write_bw if write else self.read_bw
        return self.fixed_latency_s + n_bytes / bw

    def batch_service_time_s(
        self, n_bytes: int, ops: int = 1, write: bool = False
    ) -> float:
        bw = self.write_bw if write else self.read_bw
        return ops * self.fixed_latency_s + n_bytes / bw

    # -- compressibility feedback -------------------------------------------
    def observe(self, nominal_bytes: float, wire_bytes: float) -> None:
        """Record real encoded sizes seen mid-window. Pure accumulation —
        no effect on any service time until ``commit_window``."""
        if nominal_bytes < 0 or wire_bytes < 0:
            raise ValueError("observed byte counts must be non-negative")
        self._pending_nominal += float(nominal_bytes)
        self._pending_wire += float(wire_bytes)

    def commit_window(self) -> float:
        """Window-boundary EWMA fold of the pending observation into the
        committed ratio. Returns the (possibly unchanged) committed ratio."""
        if self._pending_wire > 0.0:
            observed = max(self._pending_nominal / self._pending_wire, 1.0)
            self.ratio = (1.0 - self.ema) * self.ratio + self.ema * observed
        self._pending_nominal = 0.0
        self._pending_wire = 0.0
        return self.ratio


def get(name: str) -> MediaDevice:
    try:
        return DEVICES[name]
    except KeyError:
        raise KeyError(
            f"unknown media device {name!r}; catalog: {sorted(DEVICES)}"
        ) from None


class MediaQueue:
    """Virtual-time transfer queue for one device.

    ``submit`` places a transfer on the earliest-free of ``queue_depth``
    channels and returns ``(start_s, done_s)``; cumulative ``busy_s`` /
    ``bytes_total`` / ``queue_wait_s`` are the per-device bandwidth charges
    the TCO report and the arbiter consume. Purely arithmetic — identical
    submissions produce identical accounting.
    """

    def __init__(self, device: MediaDevice):
        self.device = device
        self._channels: List[float] = [0.0] * device.queue_depth
        self.busy_s = 0.0
        self.queue_wait_s = 0.0
        self.bytes_total = 0
        self.ops = 0

    def submit(
        self, n_bytes: int, now: float = 0.0, write: bool = False, ops: int = 1
    ) -> Tuple[float, float]:
        """Charge one aggregate transfer of ``n_bytes`` spanning ``ops``
        device operations (each op pays the fixed setup cost)."""
        svc = self.device.batch_service_time_s(n_bytes, ops=ops, write=write)
        ch = min(range(len(self._channels)), key=lambda i: self._channels[i])
        start = max(now, self._channels[ch])
        done = start + svc
        self._channels[ch] = done
        self.busy_s += svc
        self.queue_wait_s += start - now
        self.bytes_total += int(n_bytes)
        self.ops += ops
        return start, done

    def utilization(self, elapsed_s: float) -> float:
        """Fraction of one channel's time spent transferring (can exceed 1
        on multi-channel devices under heavy load; callers clip)."""
        return self.busy_s / max(elapsed_s, 1e-30)


def make_queues(names) -> Dict[str, MediaQueue]:
    """One MediaQueue per distinct device name (shared across callers of one
    substrate — that sharing IS the contention being modeled). Adaptive
    catalog entries get a *fresh* ``AdaptiveMediaDevice`` per queue set, so
    one run's committed ratio can never leak into another run's replay."""
    queues: Dict[str, MediaQueue] = {}
    for n in dict.fromkeys(names):
        dev = get(n)
        if n in ADAPTIVE_DEVICES:
            dev = AdaptiveMediaDevice(dev)
        queues[n] = MediaQueue(dev)
    return queues


def adaptive_devices(queues: Dict[str, MediaQueue]) -> Dict[str, AdaptiveMediaDevice]:
    """The adaptive devices of a queue set, by name (boundary-commit hook)."""
    return {
        n: q.device
        for n, q in queues.items()
        if isinstance(q.device, AdaptiveMediaDevice)
    }
