"""Backing-media device catalog + deterministic queue/bandwidth model.

A ``MediaDevice`` is the third axis of a software-defined tier (codec x
pool x media): the physical thing a compressed payload is read from and
written to. The cost model is the standard DMA-engine abstraction:

  service_time(bytes) = fixed_latency + bytes / bandwidth

with ``queue_depth`` concurrent channels — a transfer submitted while every
channel is busy queues behind the earliest-finishing one. ``MediaQueue``
evaluates that model in *virtual time* (callers supply ``now``; nothing here
reads a clock), so contention accounting is bit-deterministic across runs —
the property the equivalence and determinism tests lean on.

Presets mirror the platforms the paper's tiers (and the CXL follow-on work)
are built from; HBM/host numbers come from ``core/hw.py`` so the device
model and the per-tier latency model (Eq. 8) agree by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core import hw


@dataclasses.dataclass(frozen=True)
class MediaDevice:
    """One backing-media device class and its transfer cost model."""

    name: str
    read_bw: float  # sustained B/s
    write_bw: float  # sustained B/s
    fixed_latency_s: float  # per-op setup (DMA descriptor / doorbell / link RTT)
    queue_depth: int  # concurrent in-flight transfers the device sustains

    def __post_init__(self):
        if self.read_bw <= 0 or self.write_bw <= 0:
            raise ValueError("bandwidth must be positive")
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")

    def service_time_s(self, n_bytes: int, write: bool = False) -> float:
        """Uncontended transfer time for one op of ``n_bytes``."""
        bw = self.write_bw if write else self.read_bw
        return self.fixed_latency_s + n_bytes / bw

    def batch_service_time_s(
        self, n_bytes: int, ops: int = 1, write: bool = False
    ) -> float:
        """Uncontended transfer time for an aggregate of ``ops`` operations
        totalling ``n_bytes`` (each op pays the fixed setup cost) — the same
        formula ``MediaQueue.submit`` charges, exposed for callers that need
        the service time without touching queue state (stall accounting)."""
        bw = self.write_bw if write else self.read_bw
        return ops * self.fixed_latency_s + n_bytes / bw


# ---------------------------------------------------------------------------
# Catalog. HBM and host-DRAM-over-PCIe reuse the hw.py constants so the
# TierSpec latency model and the device model price the same hardware the
# same way; CXL and NVMe are published-part-class numbers for the swap
# devices the composable-memory work targets.
# ---------------------------------------------------------------------------

DEVICES: Dict[str, MediaDevice] = {
    d.name: d
    for d in (
        MediaDevice("hbm", hw.V5E.hbm_bw, hw.V5E.hbm_bw, 0.0, queue_depth=8),
        MediaDevice(
            "host_dram_pcie",
            hw.V5E.host_link_bw,
            hw.V5E.host_link_bw,
            hw.MEDIA_FIXED_US["host"] * 1e-6,
            queue_depth=4,
        ),
        # CXL 2.0 x8-class memory expander: near-PCIe bandwidth, lower setup
        # cost (load/store semantics, no DMA descriptor round-trip).
        MediaDevice("cxl", 64e9, 48e9, 0.6e-6, queue_depth=8),
        # Datacenter NVMe (Gen4 x4 class): the deepest, cheapest swap device;
        # long setup, deep queues.
        MediaDevice("nvme", 7e9, 5e9, 10e-6, queue_depth=32),
    )
}

# Media string (TierSpec.media) -> default device binding.
DEFAULT_FOR_MEDIA: Dict[str, str] = {"hbm": "hbm", "host": "host_dram_pcie"}


def get(name: str) -> MediaDevice:
    try:
        return DEVICES[name]
    except KeyError:
        raise KeyError(
            f"unknown media device {name!r}; catalog: {sorted(DEVICES)}"
        ) from None


class MediaQueue:
    """Virtual-time transfer queue for one device.

    ``submit`` places a transfer on the earliest-free of ``queue_depth``
    channels and returns ``(start_s, done_s)``; cumulative ``busy_s`` /
    ``bytes_total`` / ``queue_wait_s`` are the per-device bandwidth charges
    the TCO report and the arbiter consume. Purely arithmetic — identical
    submissions produce identical accounting.
    """

    def __init__(self, device: MediaDevice):
        self.device = device
        self._channels: List[float] = [0.0] * device.queue_depth
        self.busy_s = 0.0
        self.queue_wait_s = 0.0
        self.bytes_total = 0
        self.ops = 0

    def submit(
        self, n_bytes: int, now: float = 0.0, write: bool = False, ops: int = 1
    ) -> Tuple[float, float]:
        """Charge one aggregate transfer of ``n_bytes`` spanning ``ops``
        device operations (each op pays the fixed setup cost)."""
        svc = self.device.batch_service_time_s(n_bytes, ops=ops, write=write)
        ch = min(range(len(self._channels)), key=lambda i: self._channels[i])
        start = max(now, self._channels[ch])
        done = start + svc
        self._channels[ch] = done
        self.busy_s += svc
        self.queue_wait_s += start - now
        self.bytes_total += int(n_bytes)
        self.ops += ops
        return start, done

    def utilization(self, elapsed_s: float) -> float:
        """Fraction of one channel's time spent transferring (can exceed 1
        on multi-channel devices under heavy load; callers clip)."""
        return self.busy_s / max(elapsed_s, 1e-30)


def make_queues(names) -> Dict[str, MediaQueue]:
    """One MediaQueue per distinct device name (shared across callers of one
    substrate — that sharing IS the contention being modeled)."""
    return {n: MediaQueue(get(n)) for n in dict.fromkeys(names)}
