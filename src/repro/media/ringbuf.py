"""Pinned staging ring buffer with watermark-based credit flow.

Host-tier payloads do not jump between device pools and host dicts for free:
on real hardware they transit a pinned (page-locked) staging arena that the
DMA engine reads/writes, and the daemon recycles staging slots under a
credit protocol so a slow consumer back-pressures the producer instead of
overrunning the arena. This module models exactly that, deterministically:

  * the arena is one contiguous numpy buffer carved into fixed-size slots
    (the shared-memory layout a host daemon would mmap),
  * producers ``try_acquire`` slot credits and ``stage`` raw payload bytes
    into them; consumers ``read`` and ``release``,
  * credit flow is watermark-hysteretic: when free credits fall to the low
    watermark the ring enters backpressure and refuses new acquisitions
    until frees climb back above the high watermark — the classic
    stop/resume protocol that avoids thrashing around a single threshold.

Credit classes: ``try_acquire`` serves two producers. *Demand* credits (the
default) follow the watermark protocol above. *Speculative* credits — used
by the prefetch/readahead path — are capped to a reserved slice of the ring
(``spec_reserve``) and are additionally refused whenever granting them would
drop free credits to the high watermark: speculation can therefore never
push the ring into backpressure, so it can never starve a demand migration.
A speculative producer that is refused simply retries later (prefetch is
best-effort by construction).

Invariants (tested):
  free + held == n_slots at all times; a slot is never handed out twice;
  double-release raises; backpressure engages at ``low_watermark`` and
  clears only at ``high_watermark``; speculative holds never exceed the
  reserved slice and never engage backpressure.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


class PinnedRing:
    def __init__(
        self,
        n_slots: int,
        slot_bytes: int,
        low_watermark: float = 0.125,
        high_watermark: float = 0.5,
        spec_reserve: float = 0.25,
    ):
        if n_slots < 1 or slot_bytes < 1:
            raise ValueError("ring needs at least one slot of at least one byte")
        if not 0.0 <= low_watermark < high_watermark <= 1.0:
            raise ValueError("need 0 <= low_watermark < high_watermark <= 1")
        if not 0.0 <= spec_reserve <= 1.0:
            raise ValueError("need 0 <= spec_reserve <= 1")
        self.n_slots = n_slots
        self.slot_bytes = slot_bytes
        # The pinned arena. One allocation, slot-strided — the layout a
        # host-side daemon would place in shared memory and register with
        # the DMA engine.
        self.buf = np.zeros((n_slots, slot_bytes), dtype=np.uint8)
        self._fill = np.zeros(n_slots, dtype=np.int64)  # valid bytes per slot
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._held: set = set()
        self.low_slots = int(np.floor(low_watermark * n_slots))
        self.high_slots = max(int(np.ceil(high_watermark * n_slots)), self.low_slots + 1)
        self.backpressured = False
        # Speculative credit class: the prefetch path may hold at most this
        # many slots concurrently (the reserved slice).
        self.spec_slots = int(np.floor(spec_reserve * n_slots))
        self._spec_held: set = set()
        # Telemetry for the pipeline's stall accounting.
        self.acquires = 0
        self.stalls = 0
        self.spec_acquires = 0
        self.spec_rejects = 0

    # ------------------------------------------------------------- credits
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def held_slots(self) -> int:
        return len(self._held)

    def can_acquire(self, n: int) -> bool:
        if self.backpressured:
            return False
        return n <= len(self._free)

    @property
    def spec_held_slots(self) -> int:
        return len(self._spec_held)

    def try_acquire(self, n: int, speculative: bool = False) -> Optional[List[int]]:
        """Claim ``n`` slot credits, or None under backpressure / shortage.

        Demand class: a failed acquire that found the ring short engages
        backpressure (the producer must wait for the consumer to drain past
        the high watermark); a successful acquire that lands free credits at
        or below the low watermark engages it for the *next* producer.

        Speculative class: refused (without engaging backpressure) when the
        ring is already backpressured, when the reserved slice is full, or
        when granting would drop free credits to the high watermark — so
        speculation can never starve a demand producer.
        """
        if speculative:
            self.spec_acquires += 1
            if (
                self.backpressured
                or len(self._spec_held) + n > self.spec_slots
                or len(self._free) - n < self.high_slots
            ):
                self.spec_rejects += 1
                return None
            slots = [self._free.pop() for _ in range(n)]
            self._held.update(slots)
            self._spec_held.update(slots)
            return slots
        self.acquires += 1
        if self.backpressured or n > len(self._free):
            if n <= self.n_slots:  # a satisfiable request blocked on credits
                self.stalls += 1
            if n > len(self._free):
                self.backpressured = True
            return None
        slots = [self._free.pop() for _ in range(n)]
        self._held.update(slots)
        if len(self._free) <= self.low_slots:
            self.backpressured = True
        return slots

    def release(self, slots: Sequence[int]) -> None:
        for s in slots:
            if s not in self._held:
                raise ValueError(f"slot {s} released without being held")
            self._held.discard(s)
            self._spec_held.discard(s)
            self._fill[s] = 0
            self._free.append(s)
        if self.backpressured and len(self._free) >= self.high_slots:
            self.backpressured = False

    # ---------------------------------------------------------------- data
    def stage(self, slot: int, payload: bytes) -> None:
        """Copy raw payload bytes into a held slot (the pinned write)."""
        if slot not in self._held:
            raise ValueError(f"stage into unheld slot {slot}")
        n = len(payload)
        if n > self.slot_bytes:
            raise ValueError(f"payload of {n}B exceeds slot size {self.slot_bytes}B")
        self.buf[slot, :n] = np.frombuffer(payload, dtype=np.uint8)
        self._fill[slot] = n

    def read(self, slot: int) -> bytes:
        if slot not in self._held:
            raise ValueError(f"read from unheld slot {slot}")
        return self.buf[slot, : int(self._fill[slot])].tobytes()
