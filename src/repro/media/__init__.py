"""Backing-media subsystem: software-defined swap devices.

The paper defines a tier by (codec x pool x media); this package makes the
third axis a first-class object instead of a latency constant:

  * ``devices``  — the ``MediaDevice`` catalog (HBM, host-DRAM-over-PCIe,
    CXL, NVMe) with a bandwidth / queue-depth / fixed-latency cost model and
    a deterministic virtual-time ``MediaQueue`` for contention accounting,
  * ``ringbuf``  — the pinned staging ring buffer (numpy shared-memory
    layout, watermark-based credit flow) through which all host-tier
    payloads transit,
  * ``pipeline`` — the async, double-buffered migration pipeline that splits
    migration cohorts into stage -> transcode -> commit phases and overlaps
    them with engine decode steps.
"""

from repro.media.devices import (  # noqa: F401
    DEFAULT_FOR_MEDIA,
    DEVICES,
    MediaDevice,
    MediaQueue,
    get,
)
from repro.media.pipeline import MigrationPipeline  # noqa: F401
from repro.media.ringbuf import PinnedRing  # noqa: F401
