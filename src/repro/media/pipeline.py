"""Async, double-buffered migration pipeline over a phase-split executor.

``migrate_batch`` executes a window's migration plan as (src, dst) cohorts;
this pipeline splits each cohort into three phases and spreads them across
engine decode steps instead of blocking the window boundary:

  stage     — gather the cohort's payloads out of the source tier (device
              pool rows or host-tier dict), retire them from the source page
              tables, and pin them in the staging ring buffer (host-media
              cohorts) or a device staging hold (HBM-to-HBM cohorts). Charges
              the source device's read bandwidth.
  transcode — run the fused transcode kernel over the staged batch (skipped
              on the same-codec fast path).
  commit    — scatter into the destination tier, update placement, release
              ring credits. Charges the destination device's write bandwidth.

One ``tick()`` — called by the engine after every decode step — advances the
oldest incomplete cohort by one phase and, double-buffer style, stages the
next cohort while the head is mid-flight, so at most two cohorts hold
staging resources and a cohort commits every other tick in steady state.
Ring-credit shortage stalls the stage phase (counted, never dropped).

The executor contract (implemented by ``serving.kv_cache.TieredKVCache``):

  stage_cohort(rids, src) -> {k_pay, k_sc, v_pay, v_sc} numpy arrays
  transcode_cohort(payload, src, dst) -> payload
  commit_cohort(rids, payload, src, dst) -> per-rid landed levels
  page_stored_bytes(level) -> int        # media bytes of one page at level
  device_of(level) -> str                # media-device name for a level
  on_pipeline_drained() -> None          # reconcile hook after a full drain

A page is unreadable between stage and commit (it has left the source tier
and not yet entered the destination): decode steps skip it exactly the way
host-tier pages are always skipped in-step. That brief access-skip is the
migration's quality cost; the serial oracle pays it as a blocked boundary
instead.

``serial=True`` is the equivalence oracle: ``submit`` runs every phase to
completion inline (the blocking window-boundary semantics), through the very
same phase callbacks — final placements must be bit-identical to the async
schedule, which the media tests assert.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.media.devices import MediaQueue
from repro.media.ringbuf import PinnedRing

# Payload keys in staging order; pack/unpack relies on this ordering.
_PAYLOAD_KEYS = ("k_pay", "k_sc", "v_pay", "v_sc")


@dataclasses.dataclass
class _Cohort:
    rids: np.ndarray
    src: int
    dst: int
    phase: str = "pending"  # pending -> staged -> transcoded -> (committed)
    payload: Optional[Dict[str, np.ndarray]] = None  # device staging hold
    ring_slots: Optional[List[int]] = None  # host staging (pinned ring)
    meta: Optional[List[Tuple[Tuple[int, ...], np.dtype]]] = None  # per-key


class MigrationPipeline:
    def __init__(
        self,
        executor,
        ring: PinnedRing,
        queues: Dict[str, MediaQueue],
        step_period_s: float = 50e-6,
        serial: bool = False,
    ):
        self.executor = executor
        self.ring = ring
        self.queues = queues
        self.step_period_s = step_period_s
        self.serial = serial
        self._queue: Deque[_Cohort] = deque()
        self._step = 0
        # Stats the overlap benchmark and tests consume.
        self.cohorts_done = 0
        self.pages_moved = 0
        self.busy_ticks = 0
        self.stall_ticks = 0

    # ------------------------------------------------------------------ API
    @property
    def busy(self) -> bool:
        return bool(self._queue)

    def submit(self, cohorts: Sequence[Tuple[np.ndarray, int, int]]) -> int:
        """Enqueue phase-ordered (rids, src, dst) cohorts; returns pages
        queued. Cohorts larger than half the staging ring are chunked so two
        chunks can be in flight at once (the double buffer) and a single
        cohort can never wedge the ring."""
        chunk = max(self.ring.n_slots // 2, 1)
        n = 0
        for rids, src, dst in cohorts:
            rids = np.asarray(rids, np.int64)
            for lo in range(0, rids.size, chunk):
                part = rids[lo : lo + chunk]
                if part.size:
                    self._queue.append(_Cohort(part, int(src), int(dst)))
                    n += int(part.size)
        if self.serial:
            self.drain()
        return n

    def tick(self) -> bool:
        """Advance one decode step's worth of migration work. Returns True
        if any phase progressed (False = idle or stalled on ring credits)."""
        self._step += 1
        if not self._queue:
            return False
        self.busy_ticks += 1
        now = self._step * self.step_period_s
        head = self._queue[0]
        progressed = False
        if head.phase == "transcoded":
            self._commit(head, now)
            self._queue.popleft()
            progressed = True
            if not self._queue:
                # Batch fully drained: reconcile desired vs physical state.
                self.executor.on_pipeline_drained()
        elif head.phase == "staged":
            self._transcode(head)
            progressed = True
        else:  # pending
            progressed = self._stage(head, now)
        # Double buffer: while the head is mid-flight, stage the next
        # pending cohort so its payload is ready the moment the head
        # commits. At most two cohorts ever hold staging resources.
        in_flight = sum(1 for c in self._queue if c.phase != "pending")
        if in_flight == 1 and len(self._queue) > 1:
            nxt = self._queue[1]
            if nxt.phase == "pending":
                progressed = self._stage(nxt, now) or progressed
        if not progressed:
            self.stall_ticks += 1
        return progressed

    def drain(self) -> int:
        """Run the queue to completion (the blocking fallback). Returns
        pages committed."""
        budget = 4 * len(self._queue) + 8
        before = self.pages_moved
        while self._queue:
            budget -= 1
            if budget < 0:
                raise RuntimeError("migration pipeline failed to drain")
            self.tick()
        return self.pages_moved - before

    # --------------------------------------------------------------- phases
    def _uses_ring(self, c: _Cohort) -> bool:
        """Host-media payloads transit the pinned ring; moves between
        accelerator-local pools stage in device scratch. Index 0 is the
        uncompressed accelerator tier, so its device defines "local"."""
        local = self.executor.device_of(0)
        return (
            self.executor.device_of(c.src) != local
            or self.executor.device_of(c.dst) != local
        )

    def _stage(self, c: _Cohort, now: float) -> bool:
        use_ring = self._uses_ring(c)
        slots = None
        if use_ring:
            slots = self.ring.try_acquire(int(c.rids.size))
            if slots is None:
                return False  # backpressured: retry next tick
        payload = self.executor.stage_cohort(c.rids, c.src)
        src_dev = self.queues[self.executor.device_of(c.src)]
        src_dev.submit(
            self.executor.page_stored_bytes(c.src) * int(c.rids.size),
            now=now,
            write=False,
            ops=int(c.rids.size),
        )
        if use_ring:
            c.ring_slots = slots
            c.meta = self._pack(payload, slots)
            c.payload = None
        else:
            c.payload = payload
        c.phase = "staged"
        return True

    def _transcode(self, c: _Cohort) -> None:
        payload = self._unpack(c) if c.ring_slots is not None else c.payload
        payload = self.executor.transcode_cohort(payload, c.src, c.dst)
        if c.ring_slots is not None:
            c.meta = self._pack(payload, c.ring_slots)
        else:
            c.payload = payload
        c.phase = "transcoded"

    def _commit(self, c: _Cohort, now: float) -> None:
        payload = self._unpack(c) if c.ring_slots is not None else c.payload
        actual = self.executor.commit_cohort(c.rids, payload, c.src, c.dst)
        # Bill the devices that really absorbed the writes — commit-time
        # spills may have landed pages below the planned destination.
        for level in np.unique(np.asarray(actual, np.int64)):
            n = int((np.asarray(actual) == level).sum())
            self.queues[self.executor.device_of(int(level))].submit(
                self.executor.page_stored_bytes(int(level)) * n,
                now=now,
                write=True,
                ops=n,
            )
        if c.ring_slots is not None:
            self.ring.release(c.ring_slots)
            c.ring_slots = None
        c.payload = None
        c.phase = "committed"
        self.cohorts_done += 1
        self.pages_moved += int(c.rids.size)

    # ------------------------------------------------------- ring transit
    def _pack(
        self, payload: Dict[str, np.ndarray], slots: List[int]
    ) -> List[Tuple[Tuple[int, ...], np.dtype]]:
        """Serialize each page's four arrays into its pinned ring slot."""
        arrs = [np.asarray(payload[k]) for k in _PAYLOAD_KEYS]
        meta = [(a.shape[1:], a.dtype) for a in arrs]
        for i, slot in enumerate(slots):
            self.ring.stage(slot, b"".join(a[i].tobytes() for a in arrs))
        return meta

    def _unpack(self, c: _Cohort) -> Dict[str, np.ndarray]:
        out: Dict[str, List[np.ndarray]] = {k: [] for k in _PAYLOAD_KEYS}
        assert c.meta is not None and c.ring_slots is not None
        for slot in c.ring_slots:
            raw = self.ring.read(slot)
            off = 0
            for key, (shape, dtype) in zip(_PAYLOAD_KEYS, c.meta):
                nb = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
                out[key].append(
                    np.frombuffer(raw[off : off + nb], dtype=dtype).reshape(shape)
                )
                off += nb
        return {k: np.stack(v) for k, v in out.items()}

    # ---------------------------------------------------------------- views
    def media_busy_s(self) -> Dict[str, float]:
        return {name: q.busy_s for name, q in self.queues.items()}

    def media_bytes(self) -> Dict[str, int]:
        return {name: q.bytes_total for name, q in self.queues.items()}
