"""Async, double-buffered migration pipeline over a phase-split executor.

``migrate_batch`` executes a window's migration plan as (src, dst) cohorts;
this pipeline splits each cohort into three phases and spreads them across
engine decode steps instead of blocking the window boundary:

  stage     — gather the cohort's payloads out of the source tier (device
              pool rows or host-tier dict), retire them from the source page
              tables, and pin them in the staging ring buffer (host-media
              cohorts) or a device staging hold (HBM-to-HBM cohorts). Charges
              the source device's read bandwidth.
  transcode — run the fused transcode kernel over the staged batch (skipped
              on the same-codec fast path).
  commit    — scatter into the destination tier, update placement, release
              ring credits. Charges the destination device's write bandwidth.

One ``tick()`` — called by the engine after every decode step — advances the
oldest incomplete cohort by one phase and, double-buffer style, stages the
next cohort while the head is mid-flight, so at most two cohorts hold
staging resources and a cohort commits every other tick in steady state.
Ring-credit shortage stalls the stage phase (counted, never dropped).

Speculative prefetch (the readahead path): ``submit_prefetch`` queues
low-priority cohorts that stage *shadow copies* of warming pages — the
source copy stays resident and readable, exactly like OS readahead into the
page cache. Speculative cohorts only advance on ticks where no demand work
exists, acquire ring credits from the reserved speculative slice (so they
can never starve a demand migration), pay their source-device read
mid-window (the latency being hidden), and park the page's *raw
source-codec bytes* in a held store — deliberately untranscoded, so a held
page serves any destination the boundary plan later picks. At the window
boundary the executor ``claim``s held pages the plan decided to move —
those ride their demand cohort as ``prestaged`` rows, merged back into the
cohort's payload at stage time so the transcode input batch is exactly the
no-prefetch oracle's (bit-identity by construction) while skipping the
source re-read — and ``discard``s the rest (mispredictions: credits return,
but the speculative bandwidth was genuinely spent and stays billed on the
device queues).

The executor contract (implemented by ``serving.kv_cache.TieredKVCache``):

  stage_cohort(rids, src, dst=None) -> {k_pay, k_sc, v_pay, v_sc} numpy
      arrays — or, for device moves within one codec class, a
      ``{"class_rows": rows}`` marker: the payload never leaves the shared
      class buffer, so the pipeline bills no read bytes for the stage and
      no write bytes for the table-edit commit (real spills still bill)
  peek_cohort(rids, src) -> payload       # non-destructive speculative read
  drop_source_copies(rids, src) -> None   # retire sources of prestaged pages
  transcode_cohort(payload, src, dst) -> payload
  commit_cohort(rids, payload, src, dst) -> per-rid landed levels
  page_stored_bytes(level) -> int        # media bytes of one page at level
  device_of(level) -> str                # media-device name for a level
  on_pipeline_drained() -> None          # reconcile hook after a full drain

A page is unreadable between stage and commit (it has left the source tier
and not yet entered the destination): decode steps skip it exactly the way
host-tier pages are always skipped in-step. That brief access-skip is the
migration's quality cost; the serial oracle pays it as a blocked boundary
instead.

``serial=True`` is the equivalence oracle: ``submit`` runs every phase to
completion inline (the blocking window-boundary semantics), through the very
same phase callbacks — final placements must be bit-identical to the async
schedule, which the media tests assert. Prefetch is async-only (the serial
oracle has no mid-window steps to hide latency behind).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.media.devices import MediaQueue
from repro.media.ringbuf import PinnedRing

# Payload keys in staging order; pack/unpack relies on this ordering.
_PAYLOAD_KEYS = ("k_pay", "k_sc", "v_pay", "v_sc")


@dataclasses.dataclass
class _Cohort:
    rids: np.ndarray
    src: int
    dst: int
    phase: str = "pending"  # pending -> staged -> transcoded -> (committed)
    payload: Optional[Dict[str, np.ndarray]] = None  # device staging hold
    ring_slots: Optional[List[int]] = None  # host staging (pinned ring)
    meta: Optional[List[Tuple[Tuple[int, ...], np.dtype]]] = None  # per-key
    speculative: bool = False
    # Demand cohorts only: positions in ``rids`` whose payload was prefetched
    # (source read already paid mid-window) and the raw source-codec rows for
    # them — merged back into the cohort's payload at stage time.
    pre_idx: Optional[np.ndarray] = None
    pre_payload: Optional[Dict[str, np.ndarray]] = None


class MigrationPipeline:
    def __init__(
        self,
        executor,
        ring: PinnedRing,
        queues: Dict[str, MediaQueue],
        step_period_s: float = 50e-6,
        serial: bool = False,
    ):
        self.executor = executor
        self.ring = ring
        self.queues = queues
        self.step_period_s = step_period_s
        self.serial = serial
        self._queue: Deque[_Cohort] = deque()
        self._step = 0
        # Speculative prefetch state: queued staging cohorts + the held
        # store of fully-transcoded pages awaiting the window boundary.
        self._spec: Deque[_Cohort] = deque()
        # rid -> (src, ring slot, per-key meta of the source-codec bytes,
        #         this page's share of the speculative read service time)
        self._held: Dict[int, Tuple[int, int, list, float]] = {}
        # Stats the overlap benchmark and tests consume.
        self.cohorts_done = 0
        self.pages_moved = 0
        self.busy_ticks = 0
        self.stall_ticks = 0
        # Prefetch stats (hit-rate benchmark + mispredict billing report).
        self.prefetch_staged = 0  # pages that reached the held store
        self.prefetch_hits = 0  # held pages claimed by a boundary plan
        self.prefetch_misses = 0  # held pages the plan contradicted
        self.prefetch_cancelled = 0  # invalidated / dropped before staging
        self.prefetch_bytes = 0  # speculative source-read bytes (billed)
        self.prefetch_read_s = 0.0  # speculative source-read service time
        # Gross per-device speculative charges (never decremented — the
        # report view; hits and misses alike).
        self.prefetch_bytes_by_device: Dict[str, int] = {}
        self.prefetch_read_s_by_device: Dict[str, float] = {}
        # Per-device speculative busy time: billed on the shared queues (so
        # it appears in the TCO/media report and consumes arbiter budget).
        # A *claimed* page's share is transferred back out of this dict —
        # its read was demand work shifted earlier in the window — so the
        # contention feedback that shapes placement sees the same work a
        # prefetch-free run would; only mispredicted reads stay excluded
        # (they are overhead the oracle never paid, reported but not
        # allowed to perturb placement).
        self.prefetch_busy_by_device: Dict[str, float] = {}
        # Decode-visible swap-in stall proxy: source-read service time paid
        # at the window boundary for off-device (host-media) demand stages.
        self.demand_swapin_s = 0.0

    # ------------------------------------------------------------------ API
    @property
    def busy(self) -> bool:
        return bool(self._queue)

    def submit(
        self,
        cohorts: Sequence[Tuple[np.ndarray, int, int]],
        prestaged: Optional[Dict[int, Dict[str, np.ndarray]]] = None,
    ) -> int:
        """Enqueue phase-ordered (rids, src, dst) cohorts; returns pages
        queued. Cohorts larger than half the staging ring are chunked so two
        chunks can be in flight at once (the double buffer) and a single
        cohort can never wedge the ring. ``prestaged`` maps rid -> raw
        source-codec payload row for pages whose bytes were already
        prefetched (claimed from the held store) — those skip the source
        read at stage time."""
        chunk = max(self.ring.n_slots // 2, 1)
        n = 0
        for rids, src, dst in cohorts:
            rids = np.asarray(rids, np.int64)
            for lo in range(0, rids.size, chunk):
                part = rids[lo : lo + chunk]
                if not part.size:
                    continue
                c = _Cohort(part, int(src), int(dst))
                if prestaged:
                    idx = np.array(
                        [i for i, r in enumerate(part) if int(r) in prestaged],
                        np.int64,
                    )
                    if idx.size:
                        rows = [prestaged[int(part[i])] for i in idx]
                        c.pre_idx = idx
                        c.pre_payload = {
                            k: np.stack([r[k] for r in rows]) for k in _PAYLOAD_KEYS
                        }
                self._queue.append(c)
                n += int(part.size)
        if self.serial:
            self.drain()
        return n

    def tick(self) -> bool:
        """Advance one decode step's worth of migration work. Demand cohorts
        take absolute priority; speculative staging only advances on ticks
        where no demand work exists. Returns True if any phase progressed
        (False = idle or stalled on ring credits)."""
        self._step += 1
        now = self._step * self.step_period_s
        if not self._queue:
            if self._spec:
                return self._tick_spec(now)
            return False
        self.busy_ticks += 1
        head = self._queue[0]
        progressed = False
        if head.phase == "transcoded":
            self._commit(head, now)
            self._queue.popleft()
            progressed = True
            if not self._queue:
                # Batch fully drained: reconcile desired vs physical state.
                self.executor.on_pipeline_drained()
        elif head.phase == "staged":
            self._transcode(head)
            progressed = True
        else:  # pending
            progressed = self._stage(head, now)
        # Double buffer: while the head is mid-flight, stage the next
        # pending cohort so its payload is ready the moment the head
        # commits. At most two cohorts ever hold staging resources.
        in_flight = sum(1 for c in self._queue if c.phase != "pending")
        if in_flight == 1 and len(self._queue) > 1:
            nxt = self._queue[1]
            if nxt.phase == "pending":
                progressed = self._stage(nxt, now) or progressed
        if not progressed:
            self.stall_ticks += 1
        return progressed

    def drain(self) -> int:
        """Run the demand queue to completion (the blocking fallback).
        Returns pages committed. Speculative cohorts are untouched — they
        belong to the window boundary's claim/discard pass."""
        budget = 4 * len(self._queue) + 8
        before = self.pages_moved
        while self._queue:
            budget -= 1
            if budget < 0:
                raise RuntimeError("migration pipeline failed to drain")
            self.tick()
        return self.pages_moved - before

    # --------------------------------------------------------------- phases
    def _uses_ring(self, c: _Cohort) -> bool:
        """Host-media payloads transit the pinned ring; moves between
        accelerator-local pools stage in device scratch. Index 0 is the
        uncompressed accelerator tier, so its device defines "local"."""
        local = self.executor.device_of(0)
        return (
            self.executor.device_of(c.src) != local
            or self.executor.device_of(c.dst) != local
        )

    def _stage(self, c: _Cohort, now: float) -> bool:
        """Gather the cohort's payload (source codec). Prefetched rows —
        their source read already paid mid-window — are merged back into the
        payload at their original positions, so everything downstream
        (transcode input batch, commit order, ring residency) is exactly the
        no-prefetch oracle's; only the boundary's source read shrinks."""
        use_ring = self._uses_ring(c)
        slots = None
        if use_ring:
            slots = self.ring.try_acquire(int(c.rids.size))
            if slots is None:
                return False  # backpressured: retry next tick
        if c.pre_idx is not None and c.pre_idx.size:
            fresh_mask = np.ones(c.rids.size, bool)
            fresh_mask[c.pre_idx] = False
            fresh_idx = np.where(fresh_mask)[0]
            # Prefetched rows: retire the now-stale source copies without
            # re-reading them (the zero-cost part of the commit).
            self.executor.drop_source_copies(c.rids[c.pre_idx], c.src)
            fresh_payload = (
                self.executor.stage_cohort(c.rids[fresh_idx], c.src)
                if fresh_idx.size
                else None
            )
            payload = {}
            n = int(c.rids.size)
            for k in _PAYLOAD_KEYS:
                ref = c.pre_payload[k]
                arr = np.zeros((n,) + ref.shape[1:], ref.dtype)
                arr[c.pre_idx] = ref
                if fresh_payload is not None:
                    arr[fresh_idx] = fresh_payload[k]
                payload[k] = arr
            c.pre_payload = None
            n_read = int(fresh_idx.size)
        else:
            payload = self.executor.stage_cohort(c.rids, c.src, c.dst)
            # Same-class table-edit staging moves no payload bytes.
            n_read = 0 if "class_rows" in payload else int(c.rids.size)
        if n_read:
            src_dev = self.queues[self.executor.device_of(c.src)]
            nb = self.executor.page_stored_bytes(c.src) * n_read
            src_dev.submit(nb, now=now, write=False, ops=n_read)
            if self.executor.device_of(c.src) != self.executor.device_of(0):
                # Off-device source read paid at the boundary: the decode-
                # visible swap-in stall prefetch exists to hide.
                self.demand_swapin_s += src_dev.device.batch_service_time_s(
                    nb, ops=n_read
                )
        if use_ring:
            c.ring_slots = slots
            c.meta = self._pack(payload, slots)
            c.payload = None
        else:
            c.payload = payload
        c.phase = "staged"
        return True

    def _transcode(self, c: _Cohort) -> None:
        payload = self._unpack(c) if c.ring_slots is not None else c.payload
        payload = self.executor.transcode_cohort(payload, c.src, c.dst)
        if c.ring_slots is not None:
            c.meta = self._pack(payload, c.ring_slots)
        else:
            c.payload = payload
        c.phase = "transcoded"

    def _commit(self, c: _Cohort, now: float) -> None:
        payload = self._unpack(c) if c.ring_slots is not None else c.payload
        marker = "class_rows" in payload
        actual = self.executor.commit_cohort(c.rids, payload, c.src, c.dst)
        # Bill the devices that really absorbed the writes — commit-time
        # spills may have landed pages below the planned destination.
        for level in np.unique(np.asarray(actual, np.int64)):
            if marker and int(level) in (c.dst, c.src):
                # Table-edit landing: row ownership moved, no bytes written.
                continue
            n = int((np.asarray(actual) == level).sum())
            self.queues[self.executor.device_of(int(level))].submit(
                self.executor.page_stored_bytes(int(level)) * n,
                now=now,
                write=True,
                ops=n,
            )
        if c.ring_slots is not None:
            self.ring.release(c.ring_slots)
            c.ring_slots = None
        c.payload = None
        c.phase = "committed"
        self.cohorts_done += 1
        self.pages_moved += int(c.rids.size)

    # ------------------------------------------------- speculative prefetch
    def submit_prefetch(self, cohorts: Sequence[Tuple[np.ndarray, int]]) -> int:
        """Queue speculative (rids, src) staging cohorts. The bytes stay in
        source codec — a held page serves whatever destination the boundary
        plan later picks. Chunked to the ring's reserved speculative slice;
        pages that cannot stage before the boundary are simply dropped
        (best-effort). No-op in serial mode — there are no mid-window steps
        to hide latency behind."""
        if self.serial:
            return 0
        chunk = max(self.ring.spec_slots, 1)
        n = 0
        for rids, src in cohorts:
            rids = np.asarray(rids, np.int64)
            for lo in range(0, rids.size, chunk):
                part = rids[lo : lo + chunk]
                if part.size:
                    self._spec.append(
                        _Cohort(part, int(src), int(src), speculative=True)
                    )
                    n += int(part.size)
        return n

    def _tick_spec(self, now: float) -> bool:
        """Advance the oldest speculative cohort by one phase (only called
        when the demand queue is idle)."""
        c = self._spec[0]
        if c.phase == "pending":
            slots = self.ring.try_acquire(int(c.rids.size), speculative=True)
            if slots is None:
                return False  # reserved slice busy: retry on a later tick
            payload = self.executor.peek_cohort(c.rids, c.src)
            dev_name = self.executor.device_of(c.src)
            dev = self.queues[dev_name]
            nb = self.executor.page_stored_bytes(c.src) * int(c.rids.size)
            dev.submit(nb, now=now, write=False, ops=int(c.rids.size))
            svc = dev.device.batch_service_time_s(nb, ops=int(c.rids.size))
            self.prefetch_read_s += svc
            self.prefetch_bytes += nb
            self.prefetch_busy_by_device[dev_name] = (
                self.prefetch_busy_by_device.get(dev_name, 0.0) + svc
            )
            self.prefetch_bytes_by_device[dev_name] = (
                self.prefetch_bytes_by_device.get(dev_name, 0) + nb
            )
            self.prefetch_read_s_by_device[dev_name] = (
                self.prefetch_read_s_by_device.get(dev_name, 0.0) + svc
            )
            c.ring_slots = slots
            c.meta = self._pack(payload, slots)
            c.phase = "staged"
            return True
        # staged -> held: park per-page entries for the boundary claim.
        self._spec.popleft()
        dev = self.queues[self.executor.device_of(c.src)].device
        svc_page = dev.batch_service_time_s(self.executor.page_stored_bytes(c.src))
        for i, rid in enumerate(c.rids):
            self._held[int(rid)] = (c.src, c.ring_slots[i], c.meta, svc_page)
        self.prefetch_staged += int(c.rids.size)
        return True

    def finish_speculative(self) -> None:
        """Window boundary: run staged speculative cohorts to the held store.
        Cohorts that never acquired credits are dropped — staging them now
        would pay the read synchronously, defeating the point."""
        budget = 4 * len(self._spec) + 8
        while self._spec:
            c = self._spec[0]
            if c.phase == "pending":
                self._spec.popleft()
                self.prefetch_cancelled += int(c.rids.size)
                continue
            budget -= 1
            if budget < 0:
                raise RuntimeError("speculative staging failed to finish")
            self._tick_spec(self._step * self.step_period_s)

    def claim_prefetched(
        self, rids: np.ndarray, src: int
    ) -> Dict[int, Dict[str, np.ndarray]]:
        """Hand over held pages the boundary plan decided to move out of
        ``src``: returns rid -> raw source-codec payload row and releases
        the ring credits (the demand cohort re-pins the full payload, so
        ring residency matches the oracle). Claimed pages are prefetch hits
        — their demand stage pays no source read."""
        out: Dict[int, Dict[str, np.ndarray]] = {}
        for rid in np.asarray(rids, np.int64):
            ent = self._held.get(int(rid))
            if ent is None or ent[0] != int(src):
                continue
            _, slot, meta, svc_page = self._held.pop(int(rid))
            out[int(rid)] = self._unpack_slot(slot, meta)
            self.ring.release([slot])
            # A claimed read was demand work shifted earlier: hand its busy
            # share back so the contention feedback sees the same total work
            # a prefetch-free run would.
            dev_name = self.executor.device_of(int(src))
            self.prefetch_busy_by_device[dev_name] = (
                self.prefetch_busy_by_device.get(dev_name, 0.0) - svc_page
            )
            self.prefetch_hits += 1
        return out

    def discard_speculative(self, rids=None, cancelled: bool = False) -> int:
        """Discard held prefetched pages (all of them when ``rids`` is None),
        returning their ring credits. Boundary discards are mispredictions
        (``prefetch_misses``); invalidations — the source page moved or was
        freed out from under the shadow copy — count as cancelled. The
        speculative read bandwidth stays billed either way: mispredictions
        show up in the media report, they do not disappear."""
        if rids is None:
            targets = list(self._held)
        else:
            targets = [int(r) for r in np.atleast_1d(np.asarray(rids, np.int64))]
        n = 0
        for rid in targets:
            ent = self._held.pop(rid, None)
            if ent is None:
                continue
            self.ring.release([ent[1]])  # credits return; busy stays billed
            n += 1
        if cancelled:
            self.prefetch_cancelled += n
        else:
            self.prefetch_misses += n
        # Invalidation must also reach queued speculative cohorts, or a
        # recycled rid could later claim a stale shadow copy.
        if rids is not None and self._spec:
            rset = set(targets)
            for c in list(self._spec):
                keep = np.array([int(r) not in rset for r in c.rids], bool)
                if keep.all():
                    continue
                if c.ring_slots is not None:
                    drop_slots = [s for s, k in zip(c.ring_slots, keep) if not k]
                    self.ring.release(drop_slots)
                    c.ring_slots = [s for s, k in zip(c.ring_slots, keep) if k]
                self.prefetch_cancelled += int((~keep).sum())
                c.rids = c.rids[keep]
                if c.rids.size == 0:
                    self._spec.remove(c)
        return n

    def speculative_rids(self) -> set:
        """Rids currently held or queued on the speculative path."""
        out = set(self._held)
        for c in self._spec:
            out.update(int(r) for r in c.rids)
        return out

    # ------------------------------------------------------- ring transit
    def _pack(
        self, payload: Dict[str, np.ndarray], slots: List[int]
    ) -> List[Tuple[Tuple[int, ...], np.dtype]]:
        """Serialize each page's four arrays into its pinned ring slot."""
        arrs = [np.asarray(payload[k]) for k in _PAYLOAD_KEYS]
        meta = [(a.shape[1:], a.dtype) for a in arrs]
        for i, slot in enumerate(slots):
            self.ring.stage(slot, b"".join(a[i].tobytes() for a in arrs))
        return meta

    def _unpack_slot(self, slot: int, meta) -> Dict[str, np.ndarray]:
        """Deserialize one page's four arrays out of its ring slot."""
        raw = self.ring.read(slot)
        off = 0
        out: Dict[str, np.ndarray] = {}
        for key, (shape, dtype) in zip(_PAYLOAD_KEYS, meta):
            nb = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
            out[key] = np.frombuffer(raw[off : off + nb], dtype=dtype).reshape(shape)
            off += nb
        return out

    def _unpack(self, c: _Cohort) -> Dict[str, np.ndarray]:
        out: Dict[str, List[np.ndarray]] = {k: [] for k in _PAYLOAD_KEYS}
        assert c.meta is not None and c.ring_slots is not None
        for slot in c.ring_slots:
            row = self._unpack_slot(slot, c.meta)
            for k in _PAYLOAD_KEYS:
                out[k].append(row[k])
        return {k: np.stack(v) for k, v in out.items()}

    # ---------------------------------------------------------------- views
    def media_busy_s(self) -> Dict[str, float]:
        return {name: q.busy_s for name, q in self.queues.items()}

    def media_bytes(self) -> Dict[str, int]:
        return {name: q.bytes_total for name, q in self.queues.items()}

    def prefetch_hit_rate(self) -> float:
        """Hits / (hits + misses) over everything that reached the held
        store and met a window boundary."""
        denom = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / denom if denom else 0.0
