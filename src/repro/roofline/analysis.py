"""Three-term roofline analysis from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` reports *per-device* flops/bytes for an SPMD
program, so the per-chip terms divide by the single-chip peaks; the global
numbers in the report multiply back by chip count.

collective_bytes comes from parsing the post-SPMD HLO: every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute instruction's
result shape (per-device), weighted by the wire factor of the algorithm
(ring all-reduce moves ~2x its payload; the others ~1x). Instructions are
attributed to ICI vs the pod axis by replica-group span when available.

Hardware constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(TPU v5e; see core/hw.py).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.core import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_WIRE_FACTOR = {
    "all-reduce": 2.0,  # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, from post-SPMD HLO text."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        nbytes = n * _DTYPE_BYTES[dtype] * _WIRE_FACTOR[kind]
        out[kind] = out.get(kind, 0.0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


_UPCAST_RE = re.compile(
    r"=\s*f32\[([0-9,]+)\][^\n]*?(?:wrapped_convert|\sconvert)\("
)


def cpu_upcast_bytes(hlo_text: str, min_bytes: int = 1 << 28) -> float:
    """Bytes of bf16->f32 whole-buffer converts the CPU backend hoists.

    XLA:CPU upcasts bf16 dot operands to f32 and hoists loop-invariant
    converts above the layer scan, materializing f32 copies of e.g. the
    whole KV cache. TPUs execute bf16 dots natively — these buffers do not
    exist in the TPU memory plan, so the fits-HBM check subtracts them
    (both raw and corrected numbers are reported).
    Only large (>256MB) converts are counted to avoid nibbling at real
    working-set converts.
    """
    total = 0.0
    seen = set()
    for m in _UPCAST_RE.finditer(hlo_text):
        dims = m.group(1)
        n = 1
        for d in dims.split(","):
            n *= int(d)
        nbytes = n * 4
        if nbytes >= min_bytes:
            # f32 copy replaces reading the bf16 original: net extra = f32
            # buffer itself.
            key = (dims, m.start() // 4096)  # cheap dedupe of near-identical
            if key not in seen:
                seen.add(key)
                total += nbytes
    return total


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device
    flops_pd: float
    bytes_pd: float
    coll_bytes_pd: float
    coll_by_kind: Dict[str, float]
    # seconds
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    # memory feasibility
    args_bytes_pd: float
    temps_bytes_pd: float
    cpu_upcast_bytes_pd: float  # CPU-backend bf16->f32 artifacts (not on TPU)
    fits_hbm: bool
    # usefulness
    model_flops: float  # 6*N*D (train) / 2*N*D (inference) — global
    hlo_flops_global: float
    useful_ratio: float
    notes: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """How close the *useful* compute is to the machine roofline at the
        modeled step time: (MODEL_FLOPS / chips / step_time) / peak."""
        if self.step_time_s <= 0:
            return 0.0
        ach = self.model_flops / self.chips / self.step_time_s
        return ach / hw.V5E.peak_bf16_flops


def analyze_compiled(
    compiled,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops: float,
    hlo_text: Optional[str] = None,
    notes: str = "",
) -> RooflineReport:
    from repro.roofline import hlo_stats

    ca = compiled.cost_analysis() or {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # Loop-trip-corrected static analysis (XLA cost_analysis counts while
    # bodies once — useless for scanned programs; see hlo_stats docstring).
    st = hlo_stats.analyze(text)
    flops_pd = st.flops
    bytes_pd = st.traffic_bytes
    coll = st.coll_by_kind
    notes = notes + f" | raw_cost_analysis flops={ca.get('flops', 0):.3e}"
    mem = compiled.memory_analysis()
    args_b = float(getattr(mem, "argument_size_in_bytes", 0))
    temp_b = float(getattr(mem, "temp_size_in_bytes", 0))
    out_b = float(getattr(mem, "output_size_in_bytes", 0))
    alias_b = float(getattr(mem, "alias_size_in_bytes", 0))

    compute_s = flops_pd / hw.V5E.peak_bf16_flops
    memory_s = bytes_pd / hw.V5E.hbm_bw
    collective_s = coll["total"] / hw.V5E.ici_link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    upcast_b = cpu_upcast_bytes(text)
    hlo_global = flops_pd * chips
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_pd=flops_pd,
        bytes_pd=bytes_pd,
        coll_bytes_pd=coll["total"],
        coll_by_kind=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        args_bytes_pd=args_b,
        temps_bytes_pd=temp_b,
        cpu_upcast_bytes_pd=upcast_b,
        # donated args alias outputs; peak residency ~ args + temps + non-
        # aliased out, minus the CPU-backend f32-upcast artifacts that have
        # no TPU counterpart (bf16 dots are native there).
        fits_hbm=(
            args_b + max(temp_b - upcast_b, 0.0) + max(out_b - alias_b - args_b, 0.0)
        ) <= hw.V5E.hbm_bytes,
        model_flops=model_flops,
        hlo_flops_global=hlo_global,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0,
        notes=notes,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for inference steps.

    N = active params (MoE counts routed experts only). D = tokens processed
    by one lowered step: global_batch*seq for train/prefill, global_batch
    for decode (one token each).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch
    flops = 2.0 * n * d
    if cfg.has_attention:
        # Decode attention reads the KV cache: 2*2*kv*hd per cached token per
        # layer (QK and PV) — dominant at long context, so count it as useful.
        la = cfg.n_layers if cfg.family != "hybrid" else -(-cfg.n_layers // cfg.hybrid_attn_every)
        kvdim = cfg.n_kv_heads * cfg.head_dim_()
        flops += 4.0 * d * shape.seq_len * kvdim * la * (cfg.n_heads // max(cfg.n_kv_heads, 1))
    return flops


def save_report(report: RooflineReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
