"""Static analyzer for post-SPMD HLO text with while-loop trip correction.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scanned programs (layer scans, grad-accum scans, attention block
scans) by their trip factors. This parser rebuilds the numbers from the HLO
text itself:

  1. split the module into computations,
  2. build the call graph: ``while(...) body=%b condition=%c`` edges carry
     the trip count (the s32 constant in the condition's compare), ``calls=``
     / fusion edges carry x1,
  3. propagate execution counts from ENTRY,
  4. per computation, accumulate:
       * dot flops: 2 * prod(result dims) * prod(lhs contracting dim sizes),
       * collective wire bytes (result size x wire factor; ring all-reduce
         counts 2x),
       * memory-traffic proxy: 2 x sum of instruction result bytes
         (write + read-back estimate; bitcast/tuple plumbing excluded),
  5. totals = sum(count(comp) * per-comp stats).

All sizes are per-device (SPMD module). Exact for matmul flops and
collective bytes; the traffic proxy is a documented estimate (EXPERIMENTS.md
§Roofline, "HLO_bytes").
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "c64": 8, "c128": 16,
}

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# Computation header: "%name (params...) -> type {"; params may contain
# nested parens (tuple types), so match greedily to the "->".
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$")
_SHAPE_RE = re.compile(r"%([\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
# Operand lists may carry explicit types ("dot(f32[4,128]{1,0} %a, ... %b)")
# and while() wraps a nested tuple type — match lazily up to the markers.
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w\.\-]+)")
_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\sdot\([^%]*%([\w\.\-]+),[^%]*%([\w\.\-]+)\)"
    r".*?lhs_contracting_dims=\{([0-9,]*)\}"
)
_COLL_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SKIP_RESULT_OPS = (
    "parameter(", "get-tuple-element(", "tuple(", "constant(", "bitcast(",
    "after-all(", "partition-id(", "replica-id(",
)


def _dims(s: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in s.split(",")) if s else ()


def _prod(dims) -> int:
    out = 1
    for d in dims:
        out *= d
    return out


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    traffic_bytes: float = 0.0
    calls: List[Tuple[str, float, str]] = dataclasses.field(default_factory=list)  # (callee, mult, kind)


@dataclasses.dataclass
class HloStats:
    flops: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    traffic_bytes: float
    n_computations: int
    n_whiles: int


def _split_computations(text: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur = None
    for line in text.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(sym, name: str, f32_as_bf16: bool = False) -> float:
    ent = sym.get(name)
    if ent is None:
        return 0.0
    dt, dims = ent
    nb = _DTYPE_BYTES.get(dt, 4)
    if f32_as_bf16 and dt == "f32":
        nb = 2
    return _prod(dims) * nb


def _line_traffic(line: str, sym) -> float:
    """HBM bytes moved by one instruction under the fused-TPU model.

    Dot operands/results count f32 at 2 bytes: on TPU the f32 values exist
    only in MXU accumulators/VMEM — HBM-resident tensors are bf16 (this is
    the "bf16-resident" napkin model; see module docstring).
    """
    sm = _SHAPE_RE.search(line)
    if sm is None:
        return 0.0
    _, dt, dims = sm.group(1), sm.group(2), sm.group(3)
    out_bytes = _prod(_dims(dims)) * _DTYPE_BYTES.get(dt, 4)
    dm = _DOT_RE.search(line)
    if dm:
        out_b = _prod(_dims(dims)) * (2 if dt == "f32" else _DTYPE_BYTES.get(dt, 4))
        return (
            out_b
            + _shape_bytes(sym, dm.group(3), f32_as_bf16=True)
            + _shape_bytes(sym, dm.group(4), f32_as_bf16=True)
        )
    if " gather(" in line or " scatter(" in line:
        return 2.0 * out_bytes
    if " dynamic-update-slice(" in line:
        # In-place update: traffic ~= the update operand, not the full buffer.
        ops = _OPERANDS_RE.findall(line.split("dynamic-update-slice(", 1)[1])
        upd = _shape_bytes(sym, ops[1]) if len(ops) > 1 else 0.0
        return 2.0 * upd
    if " dynamic-slice(" in line:
        return 2.0 * out_bytes
    if _COLL_RE.search(line):
        return 2.0 * out_bytes
    return 0.0


def _trip_count(cond_lines: List[str]) -> float:
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"s32\[\]\s*constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return float(best)


def analyze(text: str) -> HloStats:
    comps, entry = _split_computations(text)

    stats: Dict[str, CompStats] = {}
    n_whiles = 0
    for name, lines in comps.items():
        cs = CompStats()
        # Per-computation symbol table for operand shape lookups.
        sym: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
        for line in lines:
            sm = _SHAPE_RE.search(line)
            if sm:
                sym[sm.group(1)] = (sm.group(2), _dims(sm.group(3)))
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                n_whiles += 1
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                cs.calls.append((body, trips, "while"))
                cs.calls.append((cond, trips, "while"))
                continue
            dm = _DOT_RE.search(line)
            if dm:
                out_dims = _dims(dm.group(2))
                lhs = sym.get(dm.group(3))
                cdims = _dims(dm.group(5))
                if lhs is not None:
                    k = _prod(lhs[1][i] for i in cdims)
                else:
                    k = 1
                cs.dot_flops += 2.0 * _prod(out_dims) * k
            cm = _COLL_RE.search(line)
            if cm:
                dt, dims, kind = cm.group(1), _dims(cm.group(2)), cm.group(3)
                # bf16-resident convention: XLA:CPU upcasts bf16 dots to f32,
                # so f32 activation/grad collectives here are bf16 on TPU.
                nb = 2 if dt == "f32" else _DTYPE_BYTES.get(dt, 4)
                nbytes = _prod(dims) * nb * _WIRE_FACTOR[kind]
                cs.coll_bytes += nbytes
                cs.coll_by_kind[kind] = cs.coll_by_kind.get(kind, 0.0) + nbytes
            # HBM traffic model (TPU assumption: elementwise chains fuse into
            # the matmuls/data movers, so HBM bytes ~= dot operands+results,
            # gathers/scatters, dynamic slices, and collective results).
            cs.traffic_bytes += _line_traffic(line, sym)
            for m in _CALLS_RE.finditer(line):
                if "while(" not in line:
                    cs.calls.append((m.group(1), 1.0, "call"))
        stats[name] = cs

    # Propagate execution counts from ENTRY through the call DAG.
    # ``counts``   : all edges — scales dot flops and collective bytes.
    # ``counts_mem``: while edges only (the control skeleton) — scales the
    #   HBM-traffic proxy. Fusion sub-computations stay out of the traffic
    #   sum: their internal temporaries live in registers/VMEM, and the
    #   fusion call site's result bytes are already counted in the parent.
    def propagate(edge_filter) -> Dict[str, float]:
        counts = {name: 0.0 for name in comps}
        if entry:
            counts[entry] = 1.0
        for _ in range(64):
            new_counts = {name: 0.0 for name in comps}
            if entry:
                new_counts[entry] = 1.0
            for name, cs in stats.items():
                c = counts[name]
                if c <= 0:
                    continue
                for callee, mult, kind in cs.calls:
                    if callee in new_counts and edge_filter(kind):
                        new_counts[callee] += c * mult
            if all(abs(new_counts[k] - counts[k]) <= 0.5 for k in counts):
                counts = new_counts
                break
            counts = new_counts
        return counts

    counts = propagate(lambda kind: True)
    counts_mem = propagate(lambda kind: kind == "while")

    flops = sum(counts[n] * s.dot_flops for n, s in stats.items())
    coll = sum(counts[n] * s.coll_bytes for n, s in stats.items())
    traffic = sum(counts_mem[n] * s.traffic_bytes for n, s in stats.items())
    by_kind: Dict[str, float] = {}
    for n, s in stats.items():
        for k, v in s.coll_by_kind.items():
            by_kind[k] = by_kind.get(k, 0.0) + counts[n] * v
    by_kind["total"] = coll
    return HloStats(
        flops=flops,
        coll_bytes=coll,
        coll_by_kind=by_kind,
        traffic_bytes=traffic,
        n_computations=len(comps),
        n_whiles=n_whiles,
    )
