from repro.roofline.analysis import RooflineReport, analyze_compiled, collective_bytes

__all__ = ["analyze_compiled", "collective_bytes", "RooflineReport"]
