"""Tiered paged KV cache: device pools + host tiers + page tables.

The KV cache is the serving analogue of the paper's application heap:

  placement levels for a KV page (region):
    1 = warm  device pool, int8-HBM   (C5/C6-class tier: low latency)
    2 = cold  device pool, int4-HBM   (C9-class: denser, mid latency)
    3 = host  int8 behind PCIe        (C7-class)
    4 = host  int4 behind PCIe        (C10/C12-class: best TCO)

  The dense *recent window* plays DRAM's role for the newest tokens and is
  hotness-exempt (always uncompressed). Pages in device pools are read by
  every decode step through the paged-attention kernel, which returns exact
  per-page softmax mass — the hotness telemetry. Host pages are not read
  in-step (the access-skip is the "fault cost": quality + swap latency);
  the manager re-promotes them on waterfall/analytical recommendation and
  the engine swaps payloads through the warm pool.

All placement state is host-side numpy (daemon side); page payloads move
through small jitted transcode helpers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import tco
from repro.core.manager import ManagerConfig, TierScapeManager
from repro.core.tiers import TierSet, get as get_tier
from repro.kernels import ref as kref
from repro.runtime.serve import TieredKVState, init_tiered_kv_state

# Placement indices (0 stays "uncompressed DRAM" for cost-model parity with
# the paper; KV pages never occupy it — the recent window does).
WARM, COLD, HOST8, HOST4 = 1, 2, 3, 4
KV_TIER_IDS = ("C5", "C9", "C7", "C10")  # int8-HBM, int4-HBM, int8-host, int4-host


def kv_tierset(page_elems: int) -> TierSet:
    return TierSet(tiers=tuple(get_tier(t) for t in KV_TIER_IDS), block_elems=page_elems)


@dataclasses.dataclass
class PageMeta:
    layer: int
    seq_slot: int
    page_idx: int  # logical page index within the sequence
    pool_slot: int = -1  # slot within its current pool


class TieredKVCache:
    """Host-side controller for one attention-layer-group x batch of slots."""

    def __init__(
        self,
        cfg: ModelConfig,
        n_attn_layers: int,
        batch_slots: int,
        page_tokens: int,
        max_seq_len: int,
        recent_window: int,
        manager_cfg: ManagerConfig,
        warm_frac: float = 0.5,
    ):
        self.cfg = cfg
        self.la = n_attn_layers
        self.bs = batch_slots
        self.pt = page_tokens
        self.max_pages = max_seq_len // page_tokens
        self.recent_window = recent_window
        hd = cfg.head_dim_()
        kv = cfg.n_kv_heads
        self.page_elems = page_tokens * kv * hd * 2  # K and V
        total_pages = self.la * self.bs * self.max_pages
        warm_cap = max(int(total_pages * warm_frac), 8)
        cold_cap = max(total_pages, 8)

        self.state = init_tiered_kv_state(
            cfg,
            batch_slots,
            page_tokens=page_tokens,
            warm_pages=warm_cap,
            cold_pages=cold_cap,
            max_pages_per_seq=self.max_pages,
            recent_window=recent_window,
            n_attn_layers=n_attn_layers,
        )
        # Host tier pools: dict slot -> (k_pay, k_sc, v_pay, v_sc) numpy.
        self.host_pages: Dict[int, Tuple[np.ndarray, ...]] = {}

        # Region space: (layer, slot, page) flattened.
        self.n_regions = total_pages
        self.manager = TierScapeManager(
            kv_tierset(self.page_elems),
            self.n_regions,
            region_bytes=self.page_elems * 2,
            cfg=manager_cfg,
        )
        # KV pages never sit in DRAM; block the option by pricing it out.
        self._page_exists = np.zeros(self.n_regions, bool)
        self._free_warm = list(range(warm_cap - 1, -1, -1))
        self._free_cold = list(range(cold_cap - 1, -1, -1))
        self._pool_slot = np.full(self.n_regions, -1, np.int64)
        self.quality_skipped_mass = 0.0  # cumulative mass of host-excluded pages

    # ------------------------------------------------------------- helpers
    def rid(self, layer: int, slot: int, page: int) -> int:
        return (layer * self.bs + slot) * self.max_pages + page

    def _quant_page(self, kpage, vpage, bits: int):
        kp, ks = kref.quant_kv_page(kpage, bits)
        vp, vs = kref.quant_kv_page(vpage, bits)
        return kp, ks, vp, vs

    # -------------------------------------------------- page ingestion path
    def append_page(self, layer: int, slot: int, page: int, kpage, vpage) -> None:
        """New page exits the recent window -> warm tier (T1-first, like the
        paper's waterfall: everything starts in the low-latency tier). Falls
        through to the cold tier under warm-pool pressure with nothing left
        to demote (all warm slots held by in-flight migrations)."""
        rid = self.rid(layer, slot, page)
        if not self._free_warm:
            self._evict_coldest_warm()
        if not self._free_warm:
            self._page_exists[rid] = True
            self._insert(rid, layer, slot, page, kpage, vpage, COLD)
            return
        ps = self._free_warm.pop()
        kp, ks, vp, vs = self._quant_page(kpage, vpage, 8)
        st = self.state
        st = dataclasses.replace(
            st,
            warm_k=st.warm_k.at[layer, ps].set(kp),
            warm_k_scales=st.warm_k_scales.at[layer, ps].set(ks),
            warm_v=st.warm_v.at[layer, ps].set(vp),
            warm_v_scales=st.warm_v_scales.at[layer, ps].set(vs),
        )
        n = int(st.warm_n[layer, slot])
        st = dataclasses.replace(
            st,
            warm_table=st.warm_table.at[layer, slot, n].set(ps),
            warm_n=st.warm_n.at[layer, slot].set(n + 1),
        )
        self.state = st
        self.manager.placement[rid] = WARM
        self._page_exists[rid] = True
        self._pool_slot[rid] = ps
        # Live compressibility feedback (paper: measured ratios drive the
        # analytical model).
        self.manager.update_measured_ratio(WARM, 2.0 * kp.size / (kp.size + 4 * ks.size) * 1.0)

    def _evict_coldest_warm(self) -> bool:
        """Warm pool pressure: demote the coldest warm page to cold pool.
        Returns False when there is nothing demotable."""
        hot = self.manager.telemetry.averaged_hotness(2)
        warm_rids = np.where((self.manager.placement == WARM) & self._page_exists)[0]
        if warm_rids.size == 0:
            return False
        victim = warm_rids[np.argmin(hot[warm_rids])]
        self.migrate(int(victim), COLD)
        return True

    # ------------------------------------------------------------ migration
    def migrate(self, rid: int, dst: int) -> None:
        src = int(self.manager.placement[rid])
        if src == dst or not self._page_exists[rid]:
            return
        layer = rid // (self.bs * self.max_pages)
        slot = (rid // self.max_pages) % self.bs
        page = rid % self.max_pages
        k, v = self._fetch_dense(rid, layer, slot, page)
        self._remove(rid, layer, slot, page)
        self._insert(rid, layer, slot, page, k, v, dst)

    def _fetch_dense(self, rid, layer, slot, page):
        """Decompress a page from wherever it lives (f32)."""
        src = int(self.manager.placement[rid])
        ps = int(self._pool_slot[rid])
        st = self.state
        if src == WARM:
            k = kref.dequant_kv_page(st.warm_k[layer, ps], st.warm_k_scales[layer, ps], 8)
            v = kref.dequant_kv_page(st.warm_v[layer, ps], st.warm_v_scales[layer, ps], 8)
        elif src == COLD:
            k = kref.dequant_kv_page(st.cold_k[layer, ps], st.cold_k_scales[layer, ps], 4)
            v = kref.dequant_kv_page(st.cold_v[layer, ps], st.cold_v_scales[layer, ps], 4)
        else:
            kp, ks, vp, vs = self.host_pages[rid]
            bits = 8 if src == HOST8 else 4
            k = kref.dequant_kv_page(jnp.asarray(kp), jnp.asarray(ks), bits)
            v = kref.dequant_kv_page(jnp.asarray(vp), jnp.asarray(vs), bits)
        return k, v

    def _remove(self, rid, layer, slot, page):
        src = int(self.manager.placement[rid])
        ps = int(self._pool_slot[rid])
        st = self.state
        if src == WARM:
            # Drop from table by swapping with the last entry.
            self._table_remove("warm", layer, slot, ps)
            self._free_warm.append(ps)
        elif src == COLD:
            self._table_remove("cold", layer, slot, ps)
            self._free_cold.append(ps)
        else:
            self.host_pages.pop(rid, None)
        self._pool_slot[rid] = -1

    def _table_remove(self, pool: str, layer: int, slot: int, pool_slot: int):
        st = self.state
        table = getattr(st, f"{pool}_table")
        n = int(getattr(st, f"{pool}_n")[layer, slot])
        row = np.array(table[layer, slot][:n])  # writable copy
        idx = int(np.where(row == pool_slot)[0][0])
        row[idx] = row[n - 1]
        row[n - 1] = 0
        new_table = table.at[layer, slot, :n].set(jnp.asarray(row))
        kw = {f"{pool}_table": new_table,
              f"{pool}_n": getattr(st, f"{pool}_n").at[layer, slot].set(n - 1)}
        self.state = dataclasses.replace(st, **kw)

    def _insert(self, rid, layer, slot, page, k, v, dst):
        st = self.state
        if dst == WARM and not self._free_warm:
            if not self._evict_coldest_warm():
                dst = COLD  # nothing demotable; spill to the next tier
            st = self.state
        if dst == WARM:
            ps = self._free_warm.pop()
            kp, ks, vp, vs = self._quant_page(k, v, 8)
            st = dataclasses.replace(
                st,
                warm_k=st.warm_k.at[layer, ps].set(kp),
                warm_k_scales=st.warm_k_scales.at[layer, ps].set(ks),
                warm_v=st.warm_v.at[layer, ps].set(vp),
                warm_v_scales=st.warm_v_scales.at[layer, ps].set(vs),
            )
            n = int(st.warm_n[layer, slot])
            st = dataclasses.replace(
                st,
                warm_table=st.warm_table.at[layer, slot, n].set(ps),
                warm_n=st.warm_n.at[layer, slot].set(n + 1),
            )
        elif dst == COLD:
            ps = self._free_cold.pop()
            kp, ks, vp, vs = self._quant_page(k, v, 4)
            st = dataclasses.replace(
                st,
                cold_k=st.cold_k.at[layer, ps].set(kp),
                cold_k_scales=st.cold_k_scales.at[layer, ps].set(ks),
                cold_v=st.cold_v.at[layer, ps].set(vp),
                cold_v_scales=st.cold_v_scales.at[layer, ps].set(vs),
            )
            n = int(st.cold_n[layer, slot])
            st = dataclasses.replace(
                st,
                cold_table=st.cold_table.at[layer, slot, n].set(ps),
                cold_n=st.cold_n.at[layer, slot].set(n + 1),
            )
        else:
            bits = 8 if dst == HOST8 else 4
            kp, ks, vp, vs = self._quant_page(k, v, bits)
            self.host_pages[rid] = tuple(np.asarray(x) for x in (kp, ks, vp, vs))
            ps = -2
        self.state = st
        self.manager.placement[rid] = dst
        self._pool_slot[rid] = ps

    # ------------------------------------------------------------ telemetry
    def record_telemetry(self, telemetry: Dict[str, jax.Array]) -> None:
        """Fold per-step page masses into region hotness counts.

        telemetry[pool] : [L, B, MP] normalized masses; map each table entry
        back to its region id via the logical page order of the table.
        """
        counts = np.zeros(self.n_regions)
        st = self.state
        for pool, placement in (("warm", WARM), ("cold", COLD)):
            mass = np.asarray(telemetry[pool])  # [L,B,MP]
            table = np.asarray(getattr(st, f"{pool}_table"))
            nvec = np.asarray(getattr(st, f"{pool}_n"))
            slot_to_rid = {}
            pl = self.manager.placement
            for rid in np.where((pl == placement) & self._page_exists)[0]:
                layer = rid // (self.bs * self.max_pages)
                slot = (rid // self.max_pages) % self.bs
                slot_to_rid[(layer, slot, int(self._pool_slot[rid]))] = rid
            for layer in range(self.la):
                for slot in range(self.bs):
                    n = int(nvec[layer, slot])
                    for j in range(n):
                        rid = slot_to_rid.get((layer, slot, int(table[layer, slot, j])))
                        if rid is not None:
                            counts[rid] += mass[layer, slot, j]
        # Host pages are never read in-step: their skipped mass is the
        # quality cost of the best-TCO tiers (tracked, reported).
        self.manager.record_access_counts(counts * 1000.0)  # scale to count-like

    # --------------------------------------------------------- window logic
    def end_window(self):
        """Run the placement model over existing pages; execute migrations."""
        plan = self.manager.end_window()
        moved = 0
        for rid, dst in zip(plan.regions, plan.dst):
            if self._page_exists[rid] and dst != 0:
                self.migrate(int(rid), int(dst))
                moved += 1
        # Manager may recommend DRAM(0) for hot pages; KV pages instead go
        # warm (the closest legal tier — recent window plays DRAM's role).
        for rid in plan.regions[plan.dst == 0]:
            if self._page_exists[rid]:
                self.migrate(int(rid), WARM)
                moved += 1
        return plan, moved

    # ------------------------------------------------------------- metrics
    def hbm_bytes(self) -> int:
        st = self.state
        tot = 0
        for name in ("warm_k", "warm_k_scales", "warm_v", "warm_v_scales",
                     "cold_k", "cold_k_scales", "cold_v", "cold_v_scales",
                     "recent_k", "recent_v"):
            a = getattr(st, name)
            tot += a.size * a.dtype.itemsize
        return tot

    def tco_usd(self) -> float:
        """Memory TCO of *existing* pages under the current placement."""
        exists = self._page_exists
        if not exists.any():
            return 0.0
        costs = tco.usd_per_region(
            self.manager.tierset, self.manager.region_bytes, self.manager.measured_ratios
        )
        return float(costs[self.manager.placement[exists]].sum())

    def tco_savings_pct(self) -> float:
        """Savings vs holding every existing page uncompressed in HBM."""
        exists = self._page_exists
        n = int(exists.sum())
        if n == 0:
            return 0.0
        mx = tco.tco_max(n, self.manager.region_bytes)
        return 100.0 * (mx - self.tco_usd()) / mx
