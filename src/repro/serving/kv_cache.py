"""Tiered paged KV cache: device pools + host tiers + page tables.

The KV cache is the serving analogue of the paper's application heap:

  placement levels for a KV page (region):
    1 = warm  device pool, int8-HBM   (C5/C6-class tier: low latency)
    2 = cold  device pool, int4-HBM   (C9-class: denser, mid latency)
    3 = host  int8 behind PCIe        (C7-class)
    4 = host  int4 behind PCIe        (C10/C12-class: best TCO)

  Storage is codec-class-major: device payloads live in one shared buffer
  per codec class (``c8_*`` int8, ``c4_*`` int4) and page tables hold GLOBAL
  class-buffer rows, so N pools of the same class need zero per-step payload
  concatenation and same-class migrations are pure table edits
  (``exchange_slots`` moves row ownership, not bytes). Each pool's
  ``SlotAllocator`` starts with a contiguous row range of its class
  partition (``ClassPartition``); exchanges interleave the ranges over time.

  The dense *recent window* plays DRAM's role for the newest tokens and is
  hotness-exempt (always uncompressed). Pages in device pools are read by
  every decode step through the paged-attention kernel, which returns exact
  per-page softmax mass — the hotness telemetry. Host pages are not read
  in-step (the access-skip is the "fault cost": quality + swap latency);
  the manager re-promotes them on waterfall/analytical recommendation and
  the engine swaps payloads through the warm pool. Host pages DO appear to
  the decode step as *sentinel rows*: a per-page key centroid in
  ``state.host_summary`` plus ``host_table``/``host_n``, which the fused
  attention launch scores into a "would-have-touched" softmax mass — the
  in-engine hotness signal that feeds the prefetch predictor directly
  (``manager.record_host_mass``) without ever fetching a payload or
  perturbing placement-driving telemetry.

All placement state is host-side numpy (daemon side). Two placement vectors
exist on purpose:

  * ``manager.placement`` — the policy's *desired* placement (what the
    TierScape model computed at the window boundary),
  * ``self.physical``     — where each page's payload *actually* lives.

``migrate_batch`` reconciles the two: it groups the migration plan into
(src, dst) cohorts, gathers each cohort's pages into one [P, T, KV, hd]
batch, and executes the cohort with a single fused ``transcode_pages``
kernel dispatch (or a raw media copy on the same-codec fast path) — turning
the per-window migration cost from O(pages) kernel dispatches into
O(cohorts). The legacy per-page ``migrate`` path is kept as the equivalence
oracle and for single-page evictions.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import tco
from repro.core.manager import ManagerConfig, TierScapeManager
from repro.core.pools import ClassPartition, SlotAllocator, exchange_slots
from repro.core.tiers import TierSet, get as get_tier
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.media.devices import adaptive_devices, make_queues
from repro.media.pipeline import MigrationPipeline
from repro.media.ringbuf import PinnedRing
from repro.runtime.serve import TieredKVState, init_tiered_kv_state

# Placement indices (0 stays "uncompressed DRAM" for cost-model parity with
# the paper; KV pages never occupy it — the recent window does).
WARM, COLD, HOST8, HOST4 = 1, 2, 3, 4
KV_TIER_IDS = ("C5", "C9", "C7", "C10")  # default: int8-HBM, int4-HBM, int8-host, int4-host
# Codec widths of the default pool split; instance widths live in
# ``self._bits`` (``pool_bits`` can make both device pools share a codec
# class, in which case they share one class buffer).
_BITS = {WARM: 8, COLD: 4, HOST8: 8, HOST4: 4}
_DEVICE = (WARM, COLD)
_POOL = {WARM: "warm", COLD: "cold"}
# Characterized tier ids by (pool, codec width) for the device pools.
_DEVICE_TIER_IDS = {
    ("warm", 8): "C5",  # SL-I8-HB
    ("warm", 4): "C8",  # SL-I4-HB
    ("cold", 8): "C6",  # PK-I8-HB
    ("cold", 4): "C9",  # PK-I4-HB
}
# A page staged out of its source tier but not yet committed to its
# destination by the async migration pipeline. Every placement mask in this
# module is a positive-level comparison, so in-flight pages drop out of
# telemetry folds, eviction scans and capacity pre-passes automatically.
INFLIGHT = -1


def kv_tierset(
    page_elems: int,
    warm_bits: int = 8,
    cold_bits: int = 4,
    host_device: str = "",
) -> TierSet:
    """TierSet for a device-pool codec split. Defaults reproduce
    ``KV_TIER_IDS``; same-width splits (e.g. warm_bits=cold_bits=8) pick the
    matching characterized tiers so byte/latency accounting follows the
    deployed codecs. ``host_device`` rebinds the two host tiers onto another
    media device from the catalog (e.g. ``"cxl_hw"`` for the
    hardware-compressed CXL expander) without changing their codec/pool
    identity — payload layout and migration semantics stay byte-identical;
    only media billing and service times move."""
    ids = (
        _DEVICE_TIER_IDS[("warm", int(warm_bits))],
        _DEVICE_TIER_IDS[("cold", int(cold_bits))],
        "C7",
        "C10",
    )
    ts = tuple(get_tier(t) for t in ids)
    if host_device:
        ts = ts[:2] + tuple(
            dataclasses.replace(t, media_device=host_device) for t in ts[2:]
        )
    return TierSet(tiers=ts, block_elems=page_elems)


@dataclasses.dataclass
class PageMeta:
    layer: int
    seq_slot: int
    page_idx: int  # logical page index within the sequence
    pool_slot: int = -1  # slot within its current pool


@dataclasses.dataclass
class ParkedPage:
    """One preempted page lifted out of the region space: the exact stored
    host-tier bytes plus where to land it on resume."""

    layer: int
    page: int  # logical page index within the sequence
    host_level: int  # HOST8 | HOST4 — codec of the parked payload
    restore_level: int  # pre-preemption placement to swap back to on resume
    payload: Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass
class ParkedSlot:
    """A preempted batch slot's full KV state, detached from the cache.

    ``park_slot`` produces one after ``demote_slot_to_host`` has pushed every
    device-resident page to its same-codec host tier (a raw media copy, no
    transcode — so payload bytes round-trip bit-exactly). The parked request
    can later be restored into ANY free slot of ANY engine with the same
    geometry via ``restore_slot``; pages re-enter through the normal swap-in
    cohort machinery, billed like every other promotion."""

    tenant: int
    pages: List[ParkedPage]
    recent_k: np.ndarray  # [L, R, KV, hd] — slot row of the recent window
    recent_v: np.ndarray
    recent_len: int
    total_len: int


class _TableEditor:
    """Batched host-side edits of the device page tables.

    All table mutations of one migrate/append batch happen on numpy copies;
    ``commit`` writes each table back to the device exactly once, instead of
    one ``.at[].set`` dispatch per page. Covers the host sentinel table too
    (it has the same row layout as the device pool tables)."""

    _POOLS = ("warm", "cold", "host")

    def __init__(self, state: TieredKVState):
        self.tables = {p: np.array(getattr(state, f"{p}_table")) for p in self._POOLS}
        self.counts = {p: np.array(getattr(state, f"{p}_n")) for p in self._POOLS}

    def remove(self, pool: str, layers, slots, pool_slots) -> None:
        t, c = self.tables[pool], self.counts[pool]
        for la, sl, ps in zip(layers, slots, pool_slots):
            n = int(c[la, sl])
            row = t[la, sl]
            idx = int(np.where(row[:n] == ps)[0][0])
            row[idx] = row[n - 1]
            row[n - 1] = 0
            c[la, sl] = n - 1

    def insert(self, pool: str, layers, slots, pool_slots) -> None:
        t, c = self.tables[pool], self.counts[pool]
        for la, sl, ps in zip(layers, slots, pool_slots):
            n = int(c[la, sl])
            t[la, sl, n] = ps
            c[la, sl] = n + 1

    def commit(self, state: TieredKVState) -> TieredKVState:
        kw = {}
        for p in self._POOLS:
            kw[f"{p}_table"] = jnp.asarray(self.tables[p])
            kw[f"{p}_n"] = jnp.asarray(self.counts[p])
        return dataclasses.replace(state, **kw)


class TieredKVCache:
    """Host-side controller for one attention-layer-group x batch of slots."""

    def __init__(
        self,
        cfg: ModelConfig,
        n_attn_layers: int,
        batch_slots: int,
        page_tokens: int,
        max_seq_len: int,
        recent_window: int,
        manager_cfg: ManagerConfig,
        warm_frac: float = 0.5,
        tenant_quota: Optional[Dict[str, Dict[int, int]]] = None,
        async_migration: bool = False,
        ring_slots: int = 64,
        media_step_s: float = 50e-6,
        prefetch: bool = False,
        prefetch_max_pages: int = 8,
        pool_bits: Optional[Dict[str, int]] = None,
        host_media_device: str = "",
    ):
        """``tenant_quota`` maps pool name ("warm"/"cold") -> {tenant id ->
        max concurrently held slots}. When a pool carries a quota, every
        tenant that allocates from it must appear in the dict (the
        ``SlotAllocator`` hard contract) — quota exhaustion spills that
        tenant's pages down-tier instead of letting it drain the shared
        free list. ``async_migration`` routes window migration plans
        through the double-buffered media pipeline instead of the blocking
        ``migrate_batch`` path. ``prefetch`` (async-only) speculatively
        stages warming host pages through the ring's reserved slice so a
        boundary promotion commits without paying the swap-in read;
        placements stay bit-identical to a prefetch-free run. ``pool_bits``
        maps pool name -> codec width (8 or 4) for the device pools,
        default ``{"warm": 8, "cold": 4}``; pools of the same width share
        one codec-class buffer and same-class migrations move no payload
        bytes. ``host_media_device`` rebinds the two host tiers onto a
        different media-catalog device (e.g. ``"cxl_hw"``): payload layout
        is untouched, but host-page traffic is billed/serviced on that
        device, and adaptive devices get fed real encoded sizes at every
        window boundary."""
        self.cfg = cfg
        self.la = n_attn_layers
        self.bs = batch_slots
        self.pt = page_tokens
        self.max_pages = max_seq_len // page_tokens
        self.recent_window = recent_window
        hd = cfg.head_dim_()
        kv = cfg.n_kv_heads
        self.page_elems = page_tokens * kv * hd * 2  # K and V
        total_pages = self.la * self.bs * self.max_pages
        warm_cap = max(int(total_pages * warm_frac), 8)
        cold_cap = max(total_pages, 8)

        # Codec-class-major storage: each device pool is a codec width over a
        # shared class buffer. ``self._cls[pool]`` names the class fields the
        # pool's pages live in; ``self._bits[level]`` the codec width per
        # placement level. The default (8, 4) split keeps both pools alone in
        # their class, so buffers, row numbering and allocation order are
        # identical to the pre-class-major layout.
        pool_bits = dict(pool_bits or {})
        wb = int(pool_bits.get("warm", 8))
        cb = int(pool_bits.get("cold", 4))
        if wb not in (8, 4) or cb not in (8, 4):
            raise ValueError(f"pool_bits must be 8 or 4, got warm={wb} cold={cb}")
        self._pool_bits = {"warm": wb, "cold": cb}
        self._cls = {"warm": "c8" if wb == 8 else "c4", "cold": "c8" if cb == 8 else "c4"}
        self._bits = {WARM: wb, COLD: cb, HOST8: 8, HOST4: 4}
        part = ClassPartition([("warm", wb, warm_cap), ("cold", cb, cold_cap)])

        self.state = init_tiered_kv_state(
            cfg,
            batch_slots,
            page_tokens=page_tokens,
            warm_pages=warm_cap,
            cold_pages=cold_cap,
            max_pages_per_seq=self.max_pages,
            recent_window=recent_window,
            n_attn_layers=n_attn_layers,
            host_slots=self.bs * self.max_pages,
            warm_bits=wb,
            cold_bits=cb,
        )
        # Host tier pools: dict slot -> (k_pay, k_sc, v_pay, v_sc) numpy.
        self.host_pages: Dict[int, Tuple[np.ndarray, ...]] = {}

        # Region space: (layer, slot, page) flattened.
        self.n_regions = total_pages
        self.host_media_device = str(host_media_device)
        self.manager = TierScapeManager(
            kv_tierset(self.page_elems, wb, cb, host_device=self.host_media_device),
            self.n_regions,
            region_bytes=self.page_elems * 2,
            cfg=manager_cfg,
        )
        # KV pages never sit in DRAM; block the option by pricing it out.
        self._page_exists = np.zeros(self.n_regions, bool)
        # Where the payload actually lives (manager.placement is the desired
        # placement the policy computed; the executor reconciles them).
        self.physical = np.zeros(self.n_regions, np.int64)
        # Device-pool slot management. SlotAllocators (daemon side) own the
        # free lists; ``tenant_quota`` caps per-tenant residency so one
        # tenant cannot exhaust a shared pool (the MaxMem failure mode).
        # Slots are GLOBAL class-buffer rows: each pool's allocator starts
        # with its contiguous ``ClassPartition`` range (base offset); with
        # the default split both bases are 0, reproducing per-pool numbering.
        tenant_quota = tenant_quota or {}
        self._alloc = {
            "warm": SlotAllocator(warm_cap, tenant_quota.get("warm"), base=part.base("warm")),
            "cold": SlotAllocator(cold_cap, tenant_quota.get("cold"), base=part.base("cold")),
        }
        # Host sentinel summary slots (device-side key centroids for the
        # fused kernel's would-have-touched rows): PER-LAYER free lists —
        # a layer can host at most bs*max_pages pages, so per-layer sizing
        # keeps ``host_summary`` at [L, bs*max_pages, ...] instead of
        # replicating the global slot space per layer. Allocation can
        # never fail.
        self._host_alloc = [
            SlotAllocator(self.bs * self.max_pages) for _ in range(self.la)
        ]
        self._pool_slot = np.full(self.n_regions, -1, np.int64)
        # Summary slot of each host-resident page (-1 = no sentinel).
        self._host_slot = np.full(self.n_regions, -1, np.int64)
        # Multi-tenancy: each batch slot is owned by one tenant; a page's
        # tenant is its slot's tenant (pages are keyed by (layer, slot, page),
        # so slot ownership is the isolation boundary).
        self.slot_tenant = np.zeros(self.bs, np.int64)
        self._rid_slot = (np.arange(self.n_regions) // self.max_pages) % self.bs
        self.quality_skipped_mass = 0.0  # cumulative mass of host-excluded pages
        # Compute-kernel dispatch accounting for the migration/ingestion path
        # (quant / dequant / transcode launches — the daemon-tax proxy).
        self.kernel_dispatches = 0
        # Decode-side attention launch accounting: ``record_telemetry`` is
        # called once per decode step and bills the step's actual launch
        # structure via ``kops.decode_launches_per_step`` — 1 launch/layer on
        # the fused path regardless of tier count, O(tiers) on the per-pool
        # oracle — so WindowStats/TCO reports stop billing O(tiers) launches
        # once fusion is on.
        self.attn_launches = 0
        self.decode_steps_recorded = 0

        # --- backing-media subsystem -----------------------------------
        # One MediaQueue per distinct device (shared-bandwidth accounting),
        # a pinned staging ring sized for the fattest page representation
        # (int8 payload + f32 scales, K and V), and the async migration
        # pipeline. serial=True (async_migration off) keeps the blocking
        # window-boundary semantics as the equivalence oracle.
        ts = self.manager.tierset
        self._dev_names = [d.name for d in ts.media_devices()]
        self._page_stored_bytes = np.array(
            [self.page_elems * 2]
            + [t.stored_bytes(self.page_elems, 2) for t in ts.tiers],
            np.int64,
        )
        hd8 = page_tokens * kv * hd  # int8 payload bytes per K (or V) page
        sc = 4 * page_tokens * kv  # f32 scale bytes per K (or V) page
        self.staging_ring = PinnedRing(max(ring_slots, 2), 2 * (hd8 + sc))
        self.media_queues = make_queues(self._dev_names)
        self.async_migration = async_migration
        self.pipeline = MigrationPipeline(
            self, self.staging_ring, self.media_queues,
            step_period_s=media_step_s, serial=not async_migration,
        )
        self._pending_reconcile: List[np.ndarray] = []
        self._media_busy_snapshot: Dict[str, float] = {}
        # Speculative prefetch: only meaningful on the async path (there are
        # no mid-window decode steps to hide the swap-in read behind in
        # serial mode). At most one cohort emission per profile window.
        self.prefetch_enabled = bool(prefetch and async_migration)
        self.prefetch_max_pages = prefetch_max_pages
        self._prefetch_window_emitted = False

    # ------------------------------------------------------------- helpers
    def rid(self, layer: int, slot: int, page: int) -> int:
        return (layer * self.bs + slot) * self.max_pages + page

    def rid_coords(self, rid: int) -> Tuple[int, int, int]:
        layer = rid // (self.bs * self.max_pages)
        slot = (rid // self.max_pages) % self.bs
        page = rid % self.max_pages
        return layer, slot, page

    # ---------------------------------------------------------- multi-tenant
    def set_slot_tenant(self, slot: int, tenant: int) -> None:
        """Tag a batch slot (and all pages it will hold) with a tenant id."""
        self.slot_tenant[slot] = tenant

    def tenant_mask(self, tenant: int) -> np.ndarray:
        """(n_regions,) bool: regions owned by ``tenant`` via their slot."""
        return self.slot_tenant[self._rid_slot] == tenant

    # ------------------------------------------------- pool slot accounting
    # The raw free lists stay visible (tests and tools introspect them), but
    # every mutation goes through the SlotAllocators so per-tenant quota
    # accounting can never drift from the lists.
    @property
    def _free_warm(self) -> List[int]:
        return self._alloc["warm"]._free

    @property
    def _free_cold(self) -> List[int]:
        return self._alloc["cold"]._free

    def _tenant_of_rid(self, rid: int) -> int:
        return int(self.slot_tenant[int(self._rid_slot[rid])])

    def _quota_headroom(self, pool: str, tenant: int) -> int:
        """Slots ``tenant`` may still claim under its quota alone (ignores
        the global free list — the capacity pre-passes handle that)."""
        a = self._alloc[pool]
        if a.tenant_quota is None:
            return a.capacity
        if tenant not in a.tenant_quota:
            raise KeyError(
                f"tenant {tenant!r} allocates from quota'd pool {pool!r} "
                f"but has no quota entry"
            )
        return max(a.tenant_quota[tenant] - a.used_by(tenant), 0)

    def _pool_headroom(self, pool: str, tenant: Optional[int] = None) -> int:
        """Slots allocatable right now: global free list, clipped by the
        tenant's quota when the pool is quota-managed."""
        a = self._alloc[pool]
        free = len(a._free)
        if a.tenant_quota is None or tenant is None:
            return free
        return min(free, self._quota_headroom(pool, tenant))

    def _alloc_slot(self, pool: str, rid: int) -> int:
        a = self._alloc[pool]
        tenant = self._tenant_of_rid(rid) if a.tenant_quota is not None else None
        return a.alloc(int(rid), tenant)

    def _free_slot(self, pool: str, pool_slot: int) -> None:
        self._alloc[pool].free(int(pool_slot))

    # ------------------------------------------------ class-major addressing
    def _same_class(self, src: int, dst: int) -> bool:
        """Device->device move within one codec class: payload bytes stay in
        place in the shared class buffer; only row ownership moves."""
        return src in _DEVICE and dst in _DEVICE and self._bits[src] == self._bits[dst]

    def _gather_rows(self, pool: str, layers, ps):
        """Gather a pool cohort's payload/scale rows from its class buffer."""
        st = self.state
        cls = self._cls[pool]
        return (
            getattr(st, f"{cls}_k")[layers, ps],
            getattr(st, f"{cls}_k_scales")[layers, ps],
            getattr(st, f"{cls}_v")[layers, ps],
            getattr(st, f"{cls}_v_scales")[layers, ps],
        )

    def _scatter_rows(self, pool: str, layers, ps, k_pay, k_sc, v_pay, v_sc) -> None:
        st = self.state
        cls = self._cls[pool]
        self.state = dataclasses.replace(
            st,
            **{
                f"{cls}_k": getattr(st, f"{cls}_k").at[layers, ps].set(k_pay),
                f"{cls}_k_scales": getattr(st, f"{cls}_k_scales").at[layers, ps].set(k_sc),
                f"{cls}_v": getattr(st, f"{cls}_v").at[layers, ps].set(v_pay),
                f"{cls}_v_scales": getattr(st, f"{cls}_v_scales").at[layers, ps].set(v_sc),
            },
        )

    def _exchange_rows(self, src: int, dst: int, rids, ps) -> None:
        """Transfer class-row ownership for a same-class cohort: each page's
        row leaves the src allocator and joins the dst allocator (which
        donates a free row back), enforcing dst tenant quota like alloc.
        ``_pool_slot`` is untouched — the rows are global, the page stays
        physically where it is."""
        sa, da = self._alloc[_POOL[src]], self._alloc[_POOL[dst]]
        for r, x in zip(rids, ps):
            tenant = (
                self._tenant_of_rid(int(r)) if da.tenant_quota is not None else None
            )
            exchange_slots(sa, da, int(x), int(r), tenant)

    def _quant_page(self, kpage, vpage, bits: int):
        self.kernel_dispatches += 2
        kp, ks = kref.quant_kv_page(kpage, bits)
        vp, vs = kref.quant_kv_page(vpage, bits)
        return kp, ks, vp, vs

    def _set_placement(self, rids, level) -> None:
        self.physical[rids] = level
        self.manager.placement[rids] = level

    def _invalidate_prefetch(self, rids) -> None:
        """A host page moved or was freed out from under its speculative
        shadow copy: the staged bytes are stale and must never be claimed
        (rids get recycled). Ring credits return; counts as cancelled."""
        if self.prefetch_enabled:
            self.pipeline.discard_speculative(rids, cancelled=True)

    # ------------------------------------------------- host sentinel rows
    # Every page living on a host tier carries a sentinel: its key centroid
    # (mean over the page's T tokens of the dequantized stored K payload —
    # deterministic from the stored bytes) in ``state.host_summary`` plus a
    # ``host_table`` row entry. The fused attention launch scores sentinels
    # for would-have-touched mass without fetching any payload.
    def _host_sentinel_insert(
        self, rids, layers, slots, k_pay, k_sc, bits: int,
        editor: Optional[_TableEditor] = None,
    ) -> None:
        rids = np.asarray(rids, np.int64)
        if rids.size == 0:
            return
        # One dequant dispatch to derive the centroids (daemon-tax billed
        # like every other quant/dequant on the migration path).
        self.kernel_dispatches += 1
        summ = np.asarray(
            kref.dequant_kv_page(jnp.asarray(k_pay), jnp.asarray(k_sc), bits)
        ).mean(axis=1)  # [P, KV, hd]
        hs = np.array(
            [self._host_alloc[int(la)].alloc(int(r)) for la, r in zip(layers, rids)],
            np.int64,
        )
        st = self.state
        self.state = dataclasses.replace(
            st, host_summary=st.host_summary.at[layers, hs].set(jnp.asarray(summ))
        )
        own = editor is None
        editor = editor or _TableEditor(self.state)
        editor.insert("host", layers, slots, hs)
        if own:
            self.state = editor.commit(self.state)
        self._host_slot[rids] = hs

    def _host_sentinel_remove(
        self, rids, layers, slots, editor: Optional[_TableEditor] = None
    ) -> None:
        rids = np.asarray(rids, np.int64)
        if rids.size == 0:
            return
        hs = self._host_slot[rids]
        own = editor is None
        editor = editor or _TableEditor(self.state)
        editor.remove("host", layers, slots, hs)
        if own:
            self.state = editor.commit(self.state)
        for la, x in zip(layers, hs):
            self._host_alloc[int(la)].free(int(x))
        self._host_slot[rids] = -1

    # -------------------------------------------------- page ingestion path
    def append_page(self, layer: int, slot: int, page: int, kpage, vpage) -> None:
        """Single-page ingestion (the batched path is ``append_pages``).
        New page exits the recent window -> warm tier (T1-first, like the
        paper's waterfall: everything starts in the low-latency tier). Falls
        through to the cold tier under warm-pool pressure with nothing left
        to demote (all warm slots held by in-flight migrations)."""
        rid = self.rid(layer, slot, page)
        tenant = self._tenant_of_rid(rid)
        if self._pool_headroom("warm", tenant) == 0:
            # Under a pure quota shortage only this tenant's own warm pages
            # free quota; under global pressure any warm page will do.
            scoped = tenant if self._quota_headroom("warm", tenant) == 0 else None
            self._evict_coldest_warm(tenant=scoped)
        if self._pool_headroom("warm", tenant) == 0:
            self._page_exists[rid] = True
            self._insert(rid, layer, slot, page, kpage, vpage, COLD)
            return
        ps = self._alloc_slot("warm", rid)
        kp, ks, vp, vs = self._quant_page(kpage, vpage, self._bits[WARM])
        self._scatter_rows("warm", layer, ps, kp, ks, vp, vs)
        st = self.state
        n = int(st.warm_n[layer, slot])
        self.state = dataclasses.replace(
            st,
            warm_table=st.warm_table.at[layer, slot, n].set(ps),
            warm_n=st.warm_n.at[layer, slot].set(n + 1),
        )
        self._set_placement(rid, WARM)
        self._page_exists[rid] = True
        self._pool_slot[rid] = ps
        # Live compressibility feedback (paper: measured ratios drive the
        # analytical model).
        self.manager.update_measured_ratio(WARM, 2.0 * kp.size / (kp.size + 4 * ks.size) * 1.0)

    def append_pages(self, entries: Sequence[Tuple[int, int, int]], kpages, vpages) -> None:
        """Batched ingestion: quantize all N new pages with one kernel
        dispatch per destination tier (K and V stacked into one batch) and
        commit the page tables once. ``entries`` is [(layer, slot, page)];
        kpages/vpages are [N, T, KV, hd] float."""
        n = len(entries)
        if n == 0:
            return
        rids = np.array([self.rid(*e) for e in entries], np.int64)
        layers = np.array([e[0] for e in entries], np.int64)
        slots = np.array([e[1] for e in entries], np.int64)
        tenants = self.slot_tenant[slots]

        deficit = n - len(self._free_warm)
        if deficit > 0:
            # Warm pressure: demote the coldest existing warm pages, batched.
            hot = self.manager.telemetry.averaged_hotness(2)
            cand = np.where((self.physical == WARM) & self._page_exists)[0]
            take = cand[np.argsort(hot[cand])][:deficit]
            if take.size:
                self.migrate_batch(take, np.full(take.size, COLD, np.int64))
        if self._alloc["warm"].tenant_quota is not None:
            # Per-tenant pressure: a tenant at quota frees headroom only by
            # demoting its OWN coldest warm pages.
            hot = self.manager.telemetry.averaged_hotness(2)
            for t in np.unique(tenants):
                want = int((tenants == t).sum())
                q_deficit = want - self._quota_headroom("warm", int(t))
                if q_deficit <= 0:
                    continue
                cand = np.where(
                    (self.physical == WARM) & self._page_exists & self.tenant_mask(int(t))
                )[0]
                take = cand[np.argsort(hot[cand])][:q_deficit]
                if take.size:
                    self.migrate_batch(take, np.full(take.size, COLD, np.int64))

        # Per-entry destination: warm while global + tenant headroom lasts,
        # then cold, then (cold quota exhausted) the int4 host tier. With no
        # quotas this degenerates to the first-N-warm split.
        dst_of = np.full(n, HOST4, np.int64)
        warm_fit = self._claim_fits("warm", rids)
        dst_of[warm_fit] = WARM
        rest = np.where(~warm_fit)[0]
        if rest.size:
            cold_fit = self._claim_fits("cold", rids[rest])
            dst_of[rest[cold_fit]] = COLD

        editor = _TableEditor(self.state)
        for dst in (WARM, COLD, HOST4):
            sel = np.where(dst_of == dst)[0]
            if sel.size == 0:
                continue
            p = sel.size
            bits = self._bits[dst]
            pay, sc = kops.quant_pages(jnp.concatenate([kpages[sel], vpages[sel]]), bits)
            self.kernel_dispatches += 1
            if dst in _DEVICE:
                self._scatter_device(
                    dst, rids[sel], layers[sel], slots[sel],
                    pay[:p], sc[:p], pay[p:], sc[p:], editor,
                )
            else:
                kp, ks = np.asarray(pay[:p]), np.asarray(sc[:p])
                vp, vs = np.asarray(pay[p:]), np.asarray(sc[p:])
                for j, r in enumerate(rids[sel]):
                    self.host_pages[int(r)] = (kp[j], ks[j], vp[j], vs[j])
                self._pool_slot[rids[sel]] = -2
                self._set_placement(rids[sel], dst)
                self._host_sentinel_insert(
                    rids[sel], layers[sel], slots[sel], kp, ks, bits, editor
                )
            if dst == WARM:
                kp_sz = int(np.prod(pay[:p].shape))
                sc_sz = int(np.prod(sc[:p].shape))
                for _ in range(p):
                    self.manager.update_measured_ratio(
                        WARM, 2.0 * (kp_sz / p) / (kp_sz / p + 4 * sc_sz / p)
                    )
        self.state = editor.commit(self.state)
        self._page_exists[rids] = True

    def _evict_coldest_warm(self, tenant: Optional[int] = None) -> bool:
        """Warm pool pressure: demote the coldest warm page to cold pool.
        ``tenant`` scopes the victim search to one tenant's pages (quota
        pressure frees quota only by evicting the quota holder's own pages).
        Returns False when there is nothing demotable."""
        hot = self.manager.telemetry.averaged_hotness(2)
        mask = (self.physical == WARM) & self._page_exists
        if tenant is not None:
            mask &= self.tenant_mask(tenant)
        warm_rids = np.where(mask)[0]
        if warm_rids.size == 0:
            return False
        victim = warm_rids[np.argmin(hot[warm_rids])]
        self.migrate(int(victim), COLD)
        return True

    # ------------------------------------------------- batched migration
    def plan_cohorts(
        self, rids: np.ndarray, dsts: np.ndarray
    ) -> List[Tuple[np.ndarray, int, int]]:
        """Normalize a migration batch into ordered (rids, src, dst) cohorts.

        Shared by the blocking executor (``migrate_batch``) and the async
        media pipeline. Dedups (last entry wins, the per-page loop's
        semantics), drops no-ops/missing/in-flight pages, runs the warm
        capacity + tenant-quota pre-passes, and phase-orders the cohorts so
        frees land before re-claims: device->host swap-outs first, then
        warm->cold demotions, cold->warm promotions, host->device swap-ins,
        and finally host<->host retranscodes.
        """
        rids = np.asarray(rids, np.int64)
        dsts = np.asarray(dsts, np.int64)
        if rids.size and np.unique(rids).size != rids.size:
            _, rev_first = np.unique(rids[::-1], return_index=True)
            idx = np.sort(rids.size - 1 - rev_first)
            rids, dsts = rids[idx], dsts[idx]
        keep = (
            self._page_exists[rids]
            & (self.physical[rids] != dsts)
            & (self.physical[rids] != INFLIGHT)
        )
        rids, dsts = rids[keep], dsts[keep]
        if rids.size == 0:
            return []
        srcs = self.physical[rids].copy()

        # Warm-capacity pre-pass.
        inflow = int((dsts == WARM).sum())
        freed = int((srcs == WARM).sum())
        deficit = inflow - (len(self._free_warm) + freed)
        if deficit > 0:
            hot = self.manager.telemetry.averaged_hotness(2)
            in_batch = np.zeros(self.n_regions, bool)
            in_batch[rids] = True
            cand = np.where((self.physical == WARM) & self._page_exists & ~in_batch)[0]
            take = cand[np.argsort(hot[cand])][:deficit]
            if take.size:
                rids = np.concatenate([take, rids])
                srcs = np.concatenate([np.full(take.size, WARM, np.int64), srcs])
                dsts = np.concatenate([np.full(take.size, COLD, np.int64), dsts])
                deficit -= take.size
            if deficit > 0:
                # Still short: the coldest warm-bound pages spill to cold.
                warm_bound = np.where(dsts == WARM)[0]
                spill = warm_bound[np.argsort(hot[rids[warm_bound]])][:deficit]
                dsts[spill] = COLD
                still = dsts != srcs
                rids, srcs, dsts = rids[still], srcs[still], dsts[still]
        rids, srcs, dsts = self._quota_pre_pass(rids, srcs, dsts)
        if rids.size == 0:
            return []

        def phase(s: int, d: int) -> int:
            if s in _DEVICE and d not in _DEVICE:
                return 0  # device -> host: frees pool slots first
            if s == WARM and d == COLD:
                return 1
            if s == COLD and d == WARM:
                return 2
            if s not in _DEVICE and d in _DEVICE:
                return 3  # host -> device swap-in (through the pools)
            return 4  # host <-> host retranscode

        pairs = sorted(
            {(int(s), int(d)) for s, d in zip(srcs, dsts)},
            key=lambda p: (phase(*p), p),
        )
        return [
            (rids[(srcs == s) & (dsts == d)], s, d) for s, d in pairs
        ]

    def _quota_pre_pass(self, rids, srcs, dsts):
        """Tenant-quota capacity pre-pass for the device pools.

        Warm: a tenant whose warm inflow exceeds its remaining quota (plus
        its own in-batch warm frees) first demotes its own coldest
        non-batch warm pages, then spills its coldest warm-bound pages to
        the cold pool. Cold: overflow past the tenant's cold quota (after
        in-batch cold frees) spills straight to the int4 host tier — same
        direction the single-page ``_insert`` path takes, so the blocking
        executor can never hit a quota-exhausted alloc mid-cohort."""
        if self._alloc["warm"].tenant_quota is not None and (dsts == WARM).any():
            hot = self.manager.telemetry.averaged_hotness(2)
            tenants_r = self.slot_tenant[self._rid_slot[rids]]
            for t in np.unique(tenants_r[dsts == WARM]):
                t = int(t)
                mine = tenants_r == t
                inflow = int(((dsts == WARM) & mine).sum())
                freed = int(((srcs == WARM) & mine).sum())
                deficit = inflow - (self._quota_headroom("warm", t) + freed)
                if deficit <= 0:
                    continue
                in_batch = np.zeros(self.n_regions, bool)
                in_batch[rids] = True
                cand = np.where(
                    (self.physical == WARM)
                    & self._page_exists
                    & self.tenant_mask(t)
                    & ~in_batch
                )[0]
                take = cand[np.argsort(hot[cand])][:deficit]
                if take.size:
                    rids = np.concatenate([take, rids])
                    srcs = np.concatenate([np.full(take.size, WARM, np.int64), srcs])
                    dsts = np.concatenate([np.full(take.size, COLD, np.int64), dsts])
                    tenants_r = self.slot_tenant[self._rid_slot[rids]]
                    deficit -= take.size
                if deficit > 0:
                    mine = tenants_r == t
                    warm_bound = np.where((dsts == WARM) & mine)[0]
                    spill = warm_bound[np.argsort(hot[rids[warm_bound]])][:deficit]
                    dsts[spill] = COLD
                    still = dsts != srcs
                    rids, srcs, dsts = rids[still], srcs[still], dsts[still]
                    tenants_r = self.slot_tenant[self._rid_slot[rids]]
        if self._alloc["cold"].tenant_quota is not None and (dsts == COLD).any():
            hot = self.manager.telemetry.averaged_hotness(2)
            tenants_r = self.slot_tenant[self._rid_slot[rids]]
            for t in np.unique(tenants_r[dsts == COLD]):
                t = int(t)
                mine = tenants_r == t
                inflow = int(((dsts == COLD) & mine).sum())
                freed = int(((srcs == COLD) & mine).sum())
                deficit = inflow - (self._quota_headroom("cold", t) + freed)
                if deficit <= 0:
                    continue
                cold_bound = np.where((dsts == COLD) & mine)[0]
                spill = cold_bound[np.argsort(hot[rids[cold_bound]])][:deficit]
                dsts[spill] = HOST4
                still = dsts != srcs
                rids, srcs, dsts = rids[still], srcs[still], dsts[still]
                tenants_r = self.slot_tenant[self._rid_slot[rids]]
        return rids, srcs, dsts

    def migrate_batch(self, rids: np.ndarray, dsts: np.ndarray) -> int:
        """Execute a migration batch cohort-by-cohort, blocking (the serial
        oracle the async pipeline is equivalence-tested against). When
        promotions would overflow the warm pool even after in-batch frees,
        the coldest non-batch warm pages are demoted first; any remaining
        overflow lands in the cold pool (the per-page path's spill
        semantics). Returns pages actually moved."""
        cohorts = self.plan_cohorts(rids, dsts)
        if not cohorts:
            return 0
        editor = _TableEditor(self.state)
        moved = 0
        for crids, s, d in cohorts:
            self._exec_cohort(crids, s, d, editor)
            moved += int(crids.size)
        self.state = editor.commit(self.state)
        return moved

    def _exec_cohort(self, rids: np.ndarray, src: int, dst: int, editor: _TableEditor) -> None:
        """Move one (src, dst) cohort: gather -> (transcode | copy) -> scatter.
        Same-class device moves skip all three: row ownership transfers
        between the pools' allocators and the page tables are re-pointed —
        zero payload bytes move."""
        p = rids.size
        layers = rids // (self.bs * self.max_pages)
        slots = (rids // self.max_pages) % self.bs

        if self._same_class(src, dst):
            ps = self._pool_slot[rids]
            editor.remove(_POOL[src], layers, slots, ps)
            self._exchange_rows(src, dst, rids, ps)
            editor.insert(_POOL[dst], layers, slots, ps)
            self._set_placement(rids, dst)
            return

        # Gather all pages of the cohort into one [2P, T, KV, hd'] batch
        # (K pages then V pages, so one kernel dispatch covers both).
        if src in _DEVICE:
            pool = _POOL[src]
            ps = self._pool_slot[rids]
            k_pay, k_sc, v_pay, v_sc = self._gather_rows(pool, layers, ps)
            editor.remove(pool, layers, slots, ps)
            for x in ps:
                self._free_slot(pool, int(x))
        else:
            self._invalidate_prefetch(rids)
            self._host_sentinel_remove(rids, layers, slots, editor)
            hp = [self.host_pages.pop(int(r)) for r in rids]
            k_pay = jnp.asarray(np.stack([h[0] for h in hp]))
            k_sc = jnp.asarray(np.stack([h[1] for h in hp]))
            v_pay = jnp.asarray(np.stack([h[2] for h in hp]))
            v_sc = jnp.asarray(np.stack([h[3] for h in hp]))

        if self._bits[src] != self._bits[dst]:
            pay, sc = kops.transcode_pages(
                jnp.concatenate([k_pay, v_pay]), jnp.concatenate([k_sc, v_sc]),
                self._bits[src], self._bits[dst],
            )
            self.kernel_dispatches += 1
            k_pay, v_pay = pay[:p], pay[p:]
            k_sc, v_sc = sc[:p], sc[p:]
        # else: same-codec fast path — raw media copy, no transcode dispatch.

        if dst in _DEVICE:
            self._scatter_device(dst, rids, layers, slots, k_pay, k_sc, v_pay, v_sc, editor)
        else:
            kp, ks = np.asarray(k_pay), np.asarray(k_sc)
            vp, vs = np.asarray(v_pay), np.asarray(v_sc)
            for i, r in enumerate(rids):
                self.host_pages[int(r)] = (kp[i], ks[i], vp[i], vs[i])
            self._pool_slot[rids] = -2
            self._set_placement(rids, dst)
            self._host_sentinel_insert(rids, layers, slots, kp, ks, self._bits[dst], editor)

    def _scatter_device(self, dst, rids, layers, slots, k_pay, k_sc, v_pay, v_sc, editor):
        pool = _POOL[dst]
        new_ps = np.array([self._alloc_slot(pool, int(r)) for r in rids], np.int64)
        self._scatter_rows(pool, layers, new_ps, k_pay, k_sc, v_pay, v_sc)
        editor.insert(pool, layers, slots, new_ps)
        self._pool_slot[rids] = new_ps
        self._set_placement(rids, dst)

    # ------------------------------------- phase-split executor (pipeline)
    # The async media pipeline drives one cohort through these three
    # callbacks across successive engine decode steps. Payloads cross the
    # phase boundaries as numpy dicts so host-media cohorts can round-trip
    # through the pinned staging ring bit-exactly.
    def stage_cohort(
        self, rids: np.ndarray, src: int, dst: Optional[int] = None
    ) -> Dict[str, np.ndarray]:
        """Phase 1: gather the cohort's payloads and retire them from the
        source tier. Pages go in-flight: out of every placement mask until
        ``commit_cohort`` lands them, and — like host-tier pages always are
        — unreadable by decode steps for those few ticks. That bounded
        access-skip is the async pipeline's quality cost; the serial oracle
        pays a blocked window boundary instead.

        When ``dst`` is known and shares the source's codec class, staging
        degenerates to a table edit: the payload rows stay in place (and
        allocated to src) in the shared class buffer and a ``class_rows``
        marker rides the pipeline instead of bytes."""
        rids = np.asarray(rids, np.int64)
        layers = rids // (self.bs * self.max_pages)
        slots = (rids // self.max_pages) % self.bs
        st = self.state
        if dst is not None and self._same_class(src, dst):
            ps = self._pool_slot[rids]
            editor = _TableEditor(st)
            editor.remove(_POOL[src], layers, slots, ps)
            self.state = editor.commit(st)
            # Rows remain owned by src's allocator until commit exchanges
            # them; ``_pool_slot`` keeps pointing at the resident rows.
            self.physical[rids] = INFLIGHT
            return {"class_rows": ps.copy()}
        if src in _DEVICE:
            pool = _POOL[src]
            ps = self._pool_slot[rids]
            kp, ks, vp, vs = self._gather_rows(pool, layers, ps)
            payload = {
                "k_pay": np.asarray(kp),
                "k_sc": np.asarray(ks),
                "v_pay": np.asarray(vp),
                "v_sc": np.asarray(vs),
            }
            editor = _TableEditor(st)
            editor.remove(pool, layers, slots, ps)
            self.state = editor.commit(st)
            for x in ps:
                self._free_slot(pool, int(x))
        else:
            self._invalidate_prefetch(rids)
            self._host_sentinel_remove(rids, layers, slots)
            hp = [self.host_pages.pop(int(r)) for r in rids]
            payload = {
                "k_pay": np.stack([h[0] for h in hp]),
                "k_sc": np.stack([h[1] for h in hp]),
                "v_pay": np.stack([h[2] for h in hp]),
                "v_sc": np.stack([h[3] for h in hp]),
            }
        self.physical[rids] = INFLIGHT
        self._pool_slot[rids] = -3
        return payload

    def peek_cohort(self, rids: np.ndarray, src: int) -> Dict[str, np.ndarray]:
        """Non-destructive gather for speculative staging: the source copy
        stays resident and readable — prefetch is a shadow copy, exactly
        like OS readahead into the page cache. Host tiers only (the swap-in
        latency being hidden is the host-media round trip)."""
        assert src not in _DEVICE, "prefetch sources are host tiers"
        rids = np.asarray(rids, np.int64)
        hp = [self.host_pages[int(r)] for r in rids]
        return {
            "k_pay": np.stack([h[0] for h in hp]),
            "k_sc": np.stack([h[1] for h in hp]),
            "v_pay": np.stack([h[2] for h in hp]),
            "v_sc": np.stack([h[3] for h in hp]),
        }

    def drop_source_copies(self, rids: np.ndarray, src: int) -> None:
        """Retire the source copies of prestaged (prefetched) pages at
        commit time: their shadow copy — already read and transcoded
        mid-window — replaces the boundary's source read entirely."""
        assert src not in _DEVICE, "prefetch sources are host tiers"
        rids = np.asarray(rids, np.int64)
        layers = rids // (self.bs * self.max_pages)
        slots = (rids // self.max_pages) % self.bs
        self._host_sentinel_remove(rids, layers, slots)
        for r in rids:
            self.host_pages.pop(int(r), None)
        self.physical[rids] = INFLIGHT
        self._pool_slot[rids] = -3

    def transcode_cohort(
        self, payload: Dict[str, np.ndarray], src: int, dst: int
    ) -> Dict[str, np.ndarray]:
        """Phase 2: one fused transcode dispatch for the whole cohort (K and
        V stacked); the same-codec fast path is a raw media copy, and a
        same-class ``class_rows`` marker passes through untouched (the
        payload never left the class buffer)."""
        if "class_rows" in payload:
            return payload
        if self._bits[src] == self._bits[dst]:
            return payload
        p = payload["k_pay"].shape[0]
        pay, sc = kops.transcode_pages(
            jnp.concatenate([jnp.asarray(payload["k_pay"]), jnp.asarray(payload["v_pay"])]),
            jnp.concatenate([jnp.asarray(payload["k_sc"]), jnp.asarray(payload["v_sc"])]),
            self._bits[src], self._bits[dst],
        )
        self.kernel_dispatches += 1
        return {
            "k_pay": np.asarray(pay[:p]), "k_sc": np.asarray(sc[:p]),
            "v_pay": np.asarray(pay[p:]), "v_sc": np.asarray(sc[p:]),
        }

    def _claim_fits(self, pool: str, rids: np.ndarray) -> np.ndarray:
        """Greedy in-order claim check: True where the rid could take a
        ``pool`` slot right now, honoring both the global free list and the
        rid's tenant quota. Shared by batched ingestion and the async
        commit phase so the two fit/spill decisions cannot drift."""
        a = self._alloc[pool]
        glob = len(a._free)
        claimed: Dict[int, int] = {}
        out = np.zeros(len(rids), bool)
        for i, r in enumerate(rids):
            t = self._tenant_of_rid(int(r))
            c = claimed.get(t, 0)
            if glob > 0 and self._pool_headroom(pool, t) - c > 0:
                out[i] = True
                claimed[t] = c + 1
                glob -= 1
        return out

    def commit_cohort(
        self, rids: np.ndarray, payload: Dict[str, np.ndarray], src: int, dst: int
    ) -> np.ndarray:
        """Phase 3: scatter into the destination tier. Device headroom is
        re-checked at commit time (appends may have raced the in-flight
        cohort); pages that no longer fit spill down-tier, re-transcoding
        the spilled sub-batch when the spill crosses codecs. Returns the
        per-rid level actually landed (spills included) so the pipeline can
        bill the devices that really absorbed the writes."""
        rids = np.asarray(rids, np.int64)
        if "class_rows" in payload:
            return self._commit_class_rows(rids, payload["class_rows"], src, dst)
        actual = np.full(rids.size, dst, np.int64)
        if dst in _DEVICE:
            fits = self._claim_fits(_POOL[dst], rids)
            fi = np.where(fits)[0]
            if fi.size:
                frids = rids[fi]
                layers = frids // (self.bs * self.max_pages)
                slots = (frids // self.max_pages) % self.bs
                editor = _TableEditor(self.state)
                self._scatter_device(
                    dst, frids, layers, slots,
                    payload["k_pay"][fi], payload["k_sc"][fi],
                    payload["v_pay"][fi], payload["v_sc"][fi], editor,
                )
                self.state = editor.commit(self.state)
            sp = np.where(~fits)[0]
            if sp.size:
                sub = {k: v[sp] for k, v in payload.items()}
                spill_dst = COLD if dst == WARM else HOST4
                sub = self.transcode_cohort(sub, dst, spill_dst)
                actual[sp] = self.commit_cohort(rids[sp], sub, src, spill_dst)
            return actual
        kp, ks = np.asarray(payload["k_pay"]), np.asarray(payload["k_sc"])
        vp, vs = np.asarray(payload["v_pay"]), np.asarray(payload["v_sc"])
        for i, r in enumerate(rids):
            self.host_pages[int(r)] = (kp[i], ks[i], vp[i], vs[i])
        self._pool_slot[rids] = -2
        self._set_placement(rids, dst)
        layers = rids // (self.bs * self.max_pages)
        slots = (rids // self.max_pages) % self.bs
        self._host_sentinel_insert(rids, layers, slots, kp, ks, self._bits[dst])
        return actual

    def _commit_class_rows(
        self, rids: np.ndarray, ps: np.ndarray, src: int, dst: int
    ) -> np.ndarray:
        """Commit a same-class marker cohort: exchange row ownership into the
        destination pool and re-point the page tables — zero payload motion.
        Pages that no longer fit at commit time (appends raced the cohort)
        fall back to the byte-moving path: their rows are gathered, freed
        from src and the sub-batch spills down-tier exactly like a regular
        commit overflow."""
        ps = np.asarray(ps, np.int64)
        actual = np.full(rids.size, dst, np.int64)
        fits = self._claim_fits(_POOL[dst], rids)
        fi = np.where(fits)[0]
        if fi.size:
            frids, fps = rids[fi], ps[fi]
            layers = frids // (self.bs * self.max_pages)
            slots = (frids // self.max_pages) % self.bs
            editor = _TableEditor(self.state)
            self._exchange_rows(src, dst, frids, fps)
            editor.insert(_POOL[dst], layers, slots, fps)
            self.state = editor.commit(self.state)
            self._set_placement(frids, dst)
        sp = np.where(~fits)[0]
        if sp.size:
            srids, sps = rids[sp], ps[sp]
            spill_dst = COLD if dst == WARM else HOST4
            if spill_dst == src:
                # Spilling back into the source pool: the rows never left it;
                # reinsert the table entries and the move becomes a no-op.
                layers = srids // (self.bs * self.max_pages)
                slots = (srids // self.max_pages) % self.bs
                editor = _TableEditor(self.state)
                editor.insert(_POOL[src], layers, slots, sps)
                self.state = editor.commit(self.state)
                self._set_placement(srids, src)
                actual[sp] = src
            else:
                layers = srids // (self.bs * self.max_pages)
                slots = (srids // self.max_pages) % self.bs
                kp, ks, vp, vs = self._gather_rows(_POOL[src], layers, sps)
                sub = {
                    "k_pay": np.asarray(kp), "k_sc": np.asarray(ks),
                    "v_pay": np.asarray(vp), "v_sc": np.asarray(vs),
                }
                for x in sps:
                    self._free_slot(_POOL[src], int(x))
                self._pool_slot[srids] = -3
                sub = self.transcode_cohort(sub, src, spill_dst)
                actual[sp] = self.commit_cohort(srids, sub, src, spill_dst)
        return actual

    def device_of(self, level: int) -> str:
        """Backing-media device name for a placement level."""
        return self._dev_names[int(level)]

    def page_stored_bytes(self, level: int) -> int:
        """Media bytes one page occupies at a placement level."""
        return int(self._page_stored_bytes[int(level)])

    def on_pipeline_drained(self) -> None:
        """Pipeline hook after a batch fully commits: reconcile the
        policy's desired placement with physical reality (spills included)
        and feed the executed media busy time back to the manager as
        contention pressure."""
        for rids in self._pending_reconcile:
            ex = rids[self._page_exists[rids] & (self.physical[rids] != INFLIGHT)]
            self.manager.placement[ex] = self.physical[ex]
        self._pending_reconcile.clear()
        # Speculative traffic is billed on the queues (TCO/media report,
        # arbiter budgets) but excluded from the contention feedback that
        # shapes placement: prefetch must never change where pages land,
        # only when their bytes move.
        spec = self.pipeline.prefetch_busy_by_device
        busy = {
            n: q.busy_s - spec.get(n, 0.0) for n, q in self.media_queues.items()
        }
        delta = {
            n: busy[n] - self._media_busy_snapshot.get(n, 0.0) for n in busy
        }
        self._media_busy_snapshot = busy
        window_s = self.manager.cfg.window_steps * self.pipeline.step_period_s
        self.manager.note_media_charges(delta, window_s)

    def drain_migrations(self) -> int:
        """Block until every in-flight migration cohort commits."""
        if self.pipeline.busy:
            return self.pipeline.drain()
        return 0

    # ------------------------------------------------ speculative prefetch
    def prefetch_tick(self) -> bool:
        """One decode step's worth of speculative work: emit this window's
        warming-page cohort (at most one non-empty emission per window) and
        advance speculative staging by one phase. Strictly lower priority
        than demand migration: a no-op while demand cohorts are in flight."""
        if not self.prefetch_enabled or self.pipeline.busy:
            return False
        if not self._prefetch_window_emitted:
            # Retry until the accumulating window shows a rising cohort
            # (telemetry grows step by step); one emission per window.
            if self._emit_prefetch():
                self._prefetch_window_emitted = True
        return self.pipeline.tick()

    def _emit_prefetch(self) -> int:
        """Ask the predictor for warming host pages and queue their raw
        bytes for speculative staging. No destination is predicted — the
        staged copy is source-codec, so it serves whatever tier the
        boundary plan picks (promotion, demotion or retranscode)."""
        eligible = (
            ((self.physical == HOST8) | (self.physical == HOST4)) & self._page_exists
        )
        for rid in self.pipeline.speculative_rids():
            eligible[rid] = False
        if not eligible.any():
            return 0
        fast = int((((self.physical == WARM) | (self.physical == COLD))).sum())
        cand = self.manager.prefetch_candidates(
            eligible, top_k=max(fast, 1), max_regions=self.prefetch_max_pages
        )
        if cand.size == 0:
            return 0
        cohorts = [
            (cand[self.physical[cand] == s], int(s))
            for s in (HOST8, HOST4)
            if bool((self.physical[cand] == s).any())
        ]
        return self.pipeline.submit_prefetch(cohorts)

    # ------------------------------------------------- per-page migration
    def migrate(self, rid: int, dst: int) -> None:
        """Per-page migration path (equivalence oracle + single evictions)."""
        src = int(self.physical[rid])
        if src == dst or src == INFLIGHT or not self._page_exists[rid]:
            return
        layer, slot, page = self.rid_coords(rid)
        k, v = self._fetch_dense(rid, layer, slot, page)
        self._remove(rid, layer, slot, page)
        self._insert(rid, layer, slot, page, k, v, dst)

    def _fetch_dense(self, rid, layer, slot, page):
        """Decompress a page from wherever it lives (f32)."""
        src = int(self.physical[rid])
        ps = int(self._pool_slot[rid])
        st = self.state
        self.kernel_dispatches += 2
        if src in _DEVICE:
            cls, bits = self._cls[_POOL[src]], self._bits[src]
            k = kref.dequant_kv_page(
                getattr(st, f"{cls}_k")[layer, ps],
                getattr(st, f"{cls}_k_scales")[layer, ps], bits,
            )
            v = kref.dequant_kv_page(
                getattr(st, f"{cls}_v")[layer, ps],
                getattr(st, f"{cls}_v_scales")[layer, ps], bits,
            )
        else:
            kp, ks, vp, vs = self.host_pages[rid]
            bits = 8 if src == HOST8 else 4
            k = kref.dequant_kv_page(jnp.asarray(kp), jnp.asarray(ks), bits)
            v = kref.dequant_kv_page(jnp.asarray(vp), jnp.asarray(vs), bits)
        return k, v

    def _remove(self, rid, layer, slot, page):
        src = int(self.physical[rid])
        ps = int(self._pool_slot[rid])
        if src == WARM:
            # Drop from table by swapping with the last entry.
            self._table_remove("warm", layer, slot, ps)
            self._free_slot("warm", ps)
        elif src == COLD:
            self._table_remove("cold", layer, slot, ps)
            self._free_slot("cold", ps)
        else:
            self._invalidate_prefetch(np.array([rid], np.int64))
            self._host_sentinel_remove(
                np.array([rid], np.int64), np.array([layer]), np.array([slot])
            )
            self.host_pages.pop(rid, None)
        self._pool_slot[rid] = -1

    def _table_remove(self, pool: str, layer: int, slot: int, pool_slot: int):
        st = self.state
        table = getattr(st, f"{pool}_table")
        n = int(getattr(st, f"{pool}_n")[layer, slot])
        row = np.array(table[layer, slot][:n])  # writable copy
        idx = int(np.where(row == pool_slot)[0][0])
        row[idx] = row[n - 1]
        row[n - 1] = 0
        new_table = table.at[layer, slot, :n].set(jnp.asarray(row))
        kw = {f"{pool}_table": new_table,
              f"{pool}_n": getattr(st, f"{pool}_n").at[layer, slot].set(n - 1)}
        self.state = dataclasses.replace(st, **kw)

    def _insert(self, rid, layer, slot, page, k, v, dst):
        st = self.state
        tenant = self._tenant_of_rid(rid)
        if dst == WARM and self._pool_headroom("warm", tenant) == 0:
            scoped = tenant if self._quota_headroom("warm", tenant) == 0 else None
            if not self._evict_coldest_warm(tenant=scoped):
                dst = COLD  # nothing demotable; spill to the next tier
            elif self._pool_headroom("warm", tenant) == 0:
                dst = COLD  # eviction freed no usable headroom
            st = self.state
        if dst == COLD and self._pool_headroom("cold", tenant) == 0:
            dst = HOST4  # cold quota exhausted; spill to the host tier
        if dst in _DEVICE:
            pool = _POOL[dst]
            ps = self._alloc_slot(pool, rid)
            kp, ks, vp, vs = self._quant_page(k, v, self._bits[dst])
            self._scatter_rows(pool, layer, ps, kp, ks, vp, vs)
            st = self.state
            n = int(getattr(st, f"{pool}_n")[layer, slot])
            st = dataclasses.replace(
                st,
                **{
                    f"{pool}_table": getattr(st, f"{pool}_table").at[layer, slot, n].set(ps),
                    f"{pool}_n": getattr(st, f"{pool}_n").at[layer, slot].set(n + 1),
                },
            )
        else:
            bits = self._bits[dst]
            kp, ks, vp, vs = self._quant_page(k, v, bits)
            self.host_pages[rid] = tuple(np.asarray(x) for x in (kp, ks, vp, vs))
            ps = -2
        self.state = st
        self._set_placement(rid, dst)
        self._pool_slot[rid] = ps
        if dst not in _DEVICE:
            self._host_sentinel_insert(
                np.array([rid], np.int64), np.array([layer]), np.array([slot]),
                np.asarray(kp)[None], np.asarray(ks)[None], bits,
            )

    # ------------------------------------------------------------ release
    def release_slot_pages(self, slot: int) -> None:
        """Request finished: free all of one batch slot's pages, batched.
        If any of THIS slot's pages ride an in-flight migration cohort the
        pipeline is drained first (they must not strand in the staging
        ring); other slots' cohorts keep overlapping undisturbed."""
        if self.pipeline.busy and bool(
            (self.physical[self._rid_slot == slot] == INFLIGHT).any()
        ):
            self.pipeline.drain()
        rids = np.array(
            [self.rid(layer, slot, page)
             for layer in range(self.la) for page in range(self.max_pages)],
            np.int64,
        )
        rids = rids[self._page_exists[rids]]
        self._invalidate_prefetch(rids)
        for r in rids:
            src = int(self.physical[r])
            ps = int(self._pool_slot[r])
            if src == WARM:
                self._free_slot("warm", ps)
            elif src == COLD:
                self._free_slot("cold", ps)
            else:
                if self._host_slot[r] >= 0:
                    layer = int(r) // (self.bs * self.max_pages)
                    self._host_alloc[layer].free(int(self._host_slot[r]))
                self.host_pages.pop(int(r), None)
        self._pool_slot[rids] = -1
        self._host_slot[rids] = -1
        self._page_exists[rids] = False
        self.physical[rids] = 0
        self.manager.placement[rids] = 0
        st = self.state
        self.state = dataclasses.replace(
            st,
            warm_n=st.warm_n.at[:, slot].set(0),
            cold_n=st.cold_n.at[:, slot].set(0),
            host_n=st.host_n.at[:, slot].set(0),
        )

    # ------------------------------------------- preemption-to-host-tier
    # The serving frontend parks a victim slot's KV on the host tier when a
    # higher-SLA request needs its batch slot, and swaps it back in on
    # resume — zero re-prefill. Three phases: demote (device pages -> same
    # codec host tier through the media pipeline, billed like any other
    # demotion), park (lift payloads + recent window out of the region
    # space), restore (re-register under a free slot, swap device-bound
    # pages back in through the pipeline).
    def slot_rids(self, slot: int) -> np.ndarray:
        """All live region ids currently owned by ``slot``."""
        return np.where(self._page_exists & (self._rid_slot == slot))[0]

    def demote_slot_to_host(self, slot: int) -> Dict[int, int]:
        """Preemption phase 1: demote every device-resident page of ``slot``
        to the host tier of its OWN codec class (warm int8 -> HOST8, cold
        int4 -> HOST4 — a raw media copy with no transcode dispatch, so the
        stored payload survives bit-exactly). Runs through the media
        pipeline, so media-queue bytes and kernel dispatches are billed
        exactly like a window boundary's demotion cohorts. Returns
        rid -> pre-demotion placement (``restore_slot``'s swap-in plan)."""
        if self.pipeline.busy:
            self.pipeline.drain()
        rids = self.slot_rids(slot)
        orig = {int(r): int(self.physical[r]) for r in rids}
        on_dev = rids[np.isin(self.physical[rids], _DEVICE)]
        if on_dev.size:
            bits = np.array([self._bits[int(s)] for s in self.physical[on_dev]])
            dsts = np.where(bits == 8, HOST8, HOST4).astype(np.int64)
            cohorts = self.plan_cohorts(on_dev, dsts)
            self.pipeline.submit(cohorts)
            self.pipeline.drain()
        return orig

    def park_slot(
        self, slot: int, restore_levels: Optional[Dict[int, int]] = None
    ) -> ParkedSlot:
        """Preemption phase 2: detach the slot's (now host-resident) pages
        and its recent-window rows from the cache entirely. Host payload
        slots, sentinel rows and region ids all free — the batch slot is
        immediately reusable by another request. ``restore_levels`` (from
        ``demote_slot_to_host``) records where each page lives again after
        resume; pages it omits stay on their parked host tier."""
        if self.pipeline.busy:
            self.pipeline.drain()
        rids = self.slot_rids(slot)
        if bool(np.isin(self.physical[rids], _DEVICE).any()):
            raise ValueError(
                f"park_slot({slot}): device-resident pages remain — call "
                "demote_slot_to_host first"
            )
        restore_levels = restore_levels or {}
        self._invalidate_prefetch(rids)
        layers = rids // (self.bs * self.max_pages)
        slots_v = (rids // self.max_pages) % self.bs
        self._host_sentinel_remove(rids, layers, slots_v)
        pages = []
        for r in rids:
            r = int(r)
            layer, _, page = self.rid_coords(r)
            lvl = int(self.physical[r])
            pages.append(ParkedPage(
                layer=layer, page=page, host_level=lvl,
                restore_level=int(restore_levels.get(r, lvl)),
                payload=self.host_pages.pop(r),
            ))
        self._page_exists[rids] = False
        self.physical[rids] = 0
        self.manager.placement[rids] = 0
        self._pool_slot[rids] = -1
        self._host_slot[rids] = -1
        st = self.state
        parked = ParkedSlot(
            tenant=int(self.slot_tenant[slot]),
            pages=pages,
            recent_k=np.asarray(st.recent_k[:, slot]),
            recent_v=np.asarray(st.recent_v[:, slot]),
            recent_len=int(st.recent_len[slot]),
            total_len=int(st.total_len[slot]),
        )
        self.state = dataclasses.replace(
            st,
            host_n=st.host_n.at[:, slot].set(0),
            recent_len=st.recent_len.at[slot].set(0),
            total_len=st.total_len.at[slot].set(0),
        )
        return parked

    def restore_slot(self, slot: int, parked: ParkedSlot) -> int:
        """Resume phase: re-register a parked request's pages under ``slot``
        (which must hold none) and swap the previously device-resident ones
        back in through the media pipeline — same-codec raw copies again, so
        every payload lands bit-exactly where its codec class stores it.
        The recent window and positions restore verbatim; the next decode
        step continues as if the preemption never happened. Returns the
        number of pages restored."""
        if self.slot_rids(slot).size:
            raise ValueError(f"restore_slot({slot}): target slot still holds pages")
        if self.pipeline.busy:
            self.pipeline.drain()
        self.set_slot_tenant(slot, parked.tenant)
        # Re-insert host payloads in layer-major logical page order so table
        # rows append in the same order an uninterrupted run built them.
        pages = sorted(parked.pages, key=lambda pg: (pg.layer, pg.page))
        rids = np.array([self.rid(pg.layer, slot, pg.page) for pg in pages], np.int64)
        if rids.size:
            if bool(self._page_exists[rids].any()):
                raise ValueError(f"restore_slot({slot}): region ids already live")
            levels = np.array([pg.host_level for pg in pages], np.int64)
            for r, pg in zip(rids, pages):
                self.host_pages[int(r)] = pg.payload
            self._page_exists[rids] = True
            self._pool_slot[rids] = -2
            self.physical[rids] = levels
            self.manager.placement[rids] = levels
            layers = rids // (self.bs * self.max_pages)
            slots_v = (rids // self.max_pages) % self.bs
            for lvl in (HOST8, HOST4):
                sel = np.where(levels == lvl)[0]
                if sel.size:
                    kp = np.stack([pages[i].payload[0] for i in sel])
                    ks = np.stack([pages[i].payload[1] for i in sel])
                    self._host_sentinel_insert(
                        rids[sel], layers[sel], slots_v[sel], kp, ks, self._bits[lvl]
                    )
        # Recent window + positions land exactly as parked.
        st = self.state
        self.state = dataclasses.replace(
            st,
            recent_k=st.recent_k.at[:, slot].set(
                jnp.asarray(parked.recent_k).astype(st.recent_k.dtype)),
            recent_v=st.recent_v.at[:, slot].set(
                jnp.asarray(parked.recent_v).astype(st.recent_v.dtype)),
            recent_len=st.recent_len.at[slot].set(parked.recent_len),
            total_len=st.total_len.at[slot].set(parked.total_len),
        )
        swap = np.array(
            [i for i, pg in enumerate(pages) if pg.restore_level in _DEVICE],
            np.int64,
        )
        if swap.size:
            dsts = np.array([pages[i].restore_level for i in swap], np.int64)
            cohorts = self.plan_cohorts(rids[swap], dsts)
            self.pipeline.submit(cohorts)
            self.pipeline.drain()
        return int(rids.size)

    # ------------------------------------------------------------ telemetry
    def record_telemetry(self, telemetry: Dict[str, jax.Array]) -> None:
        """Fold per-step page masses into region hotness counts.

        telemetry[pool] : [L, B, MP] normalized masses; map each table entry
        back to its region id via the logical page order of the table.
        Vectorized with the same table->rid mapping trick as ``_plan``:
        a (layer, pool_slot) -> rid lookup array turns the per-page python
        loop into one fancy-indexed gather + ``np.add.at`` per pool.
        ``_fold_telemetry_loop`` is the per-page equivalence oracle.

        A "host" key (the fused kernel's would-have-touched sentinel mass)
        routes to ``manager.record_host_mass`` — the prefetch predictor's
        in-engine signal — NOT into the placement-driving access counts:
        host pages are never read in-step, so their skipped mass is the
        quality cost of the best-TCO tiers (tracked, reported) and feeding
        it to the placement model would break oracle-identical placements.
        """
        self.manager.record_access_counts(self._fold_telemetry(telemetry) * 1000.0)
        host_mass = telemetry.get("host")
        if host_mass is not None:
            folded = self._fold_host_mass(host_mass)
            self.quality_skipped_mass += float(folded.sum())
            self.manager.record_host_mass(folded * 1000.0)
        # Decode-side dispatch proxy: one fused launch per layer per step,
        # O(tiers) only when the per-pool oracle path is toggled on.
        self.attn_launches += self.la * kops.decode_launches_per_step(
            n_pools=len(_POOL)
        )
        self.decode_steps_recorded += 1

    def _fold_table_mass(self, counts, mass, table, nvec, cap, live, slot_of) -> None:
        """Accumulate per-table-entry ``mass`` [L, B, M] onto region ids.

        Builds the (layer, pool_slot) -> rid lookup from ``live`` rids and
        their ``slot_of`` slots (slots come from one free list per layer
        scope, so a slot maps to at most one live rid), then gathers +
        ``np.add.at``s in one shot. The validity mask is threefold: prefix
        count (entries past n are stale), mapped rid exists, and the rid
        must belong to this (layer, slot) row (slot-identity guard)."""
        rid_of = np.full((self.la, cap), -1, np.int64)
        rid_of[live // (self.bs * self.max_pages), slot_of[live]] = live
        m = min(mass.shape[2], table.shape[2])
        entry = table[:, :, :m]  # [L,B,m]
        cand = rid_of[np.arange(self.la)[:, None, None], entry]
        valid = np.arange(m)[None, None, :] < nvec[..., None]
        valid &= cand >= 0
        valid &= ((cand // self.max_pages) % self.bs) == np.arange(self.bs)[None, :, None]
        np.add.at(counts, cand[valid], mass[:, :, :m][valid])

    def _fold_telemetry(self, telemetry: Dict[str, jax.Array]) -> np.ndarray:
        counts = np.zeros(self.n_regions)
        st = self.state
        for pool, placement in (("warm", WARM), ("cold", COLD)):
            live = np.where((self.physical == placement) & self._page_exists)[0]
            if live.size == 0:
                continue
            self._fold_table_mass(
                counts,
                np.asarray(telemetry[pool]),
                np.asarray(getattr(st, f"{pool}_table")),
                np.asarray(getattr(st, f"{pool}_n")),
                # Slots are global class-buffer rows; the lookup spans the
                # whole class buffer (ranges interleave after exchanges).
                getattr(st, f"{self._cls[pool]}_k").shape[1],
                live,
                self._pool_slot,
            )
        return counts

    def _fold_host_mass(self, mass) -> np.ndarray:
        """Fold sentinel would-have-touched masses [L, B, MPh] into region
        counts — the same gather as ``_fold_telemetry``, against the host
        sentinel table."""
        counts = np.zeros(self.n_regions)
        live = np.where(
            ((self.physical == HOST8) | (self.physical == HOST4)) & self._page_exists
        )[0]
        if live.size:
            st = self.state
            self._fold_table_mass(
                counts, np.asarray(mass), np.asarray(st.host_table),
                np.asarray(st.host_n), st.host_summary.shape[1], live,
                self._host_slot,
            )
        return counts

    def _fold_telemetry_loop(self, telemetry: Dict[str, jax.Array]) -> np.ndarray:
        """Per-page reference semantics for ``_fold_telemetry`` (oracle)."""
        counts = np.zeros(self.n_regions)
        st = self.state
        for pool, placement in (("warm", WARM), ("cold", COLD)):
            mass = np.asarray(telemetry[pool])  # [L,B,MP]
            table = np.asarray(getattr(st, f"{pool}_table"))
            nvec = np.asarray(getattr(st, f"{pool}_n"))
            slot_to_rid = {}
            pl = self.physical
            for rid in np.where((pl == placement) & self._page_exists)[0]:
                layer, slot, _ = self.rid_coords(rid)
                slot_to_rid[(layer, slot, int(self._pool_slot[rid]))] = rid
            for layer in range(self.la):
                for slot in range(self.bs):
                    n = int(nvec[layer, slot])
                    for j in range(n):
                        rid = slot_to_rid.get((layer, slot, int(table[layer, slot, j])))
                        if rid is not None:
                            counts[rid] += mass[layer, slot, j]
        return counts

    def _observe_adaptive_media(self) -> None:
        """Feed compressibility-adaptive media devices real encoded sizes.

        Runs at the window boundary only, after the pipeline has drained —
        both the serial oracle and the async path reach this point with
        byte-identical ``host_pages``, so the observations (and therefore
        the device's post-commit effective bandwidth and the manager's
        measured ratios) are mode-independent by construction. Mid-window
        decode steps never call this, honoring the ``AdaptiveMediaDevice``
        contract that in-window service times are fixed.

        The observation is the real line-compressibility of resident host
        payloads. The inline compressor is codec-agnostic — it sees byte
        streams, and narrows any 64-byte hardware line whose bytes (as
        two's-complement codewords) fit int4 range — so int8 and packed
        int4 payloads both narrow exactly when their content does (e.g.
        zero pad-tail pages halve; dense full-range pages don't). Scales
        ride uncompressed."""
        adaptive = adaptive_devices(self.media_queues)
        if not adaptive:
            return
        for name, dev in adaptive.items():
            levels = [
                lvl for lvl in (HOST8, HOST4) if self._dev_names[lvl] == name
            ]
            if not levels:
                dev.commit_window()
                continue
            nominal = 0
            wire = 0
            for lvl in levels:
                rids = np.nonzero((self.physical == lvl) & self._page_exists)[0]
                for rid in rids:
                    kp, ks, vp, vs = self.host_pages[int(rid)]
                    for pay in (kp, vp):
                        b = int(pay.size) * int(pay.dtype.itemsize)
                        nominal += b
                        q = np.ascontiguousarray(pay).reshape(-1).view(np.int8)
                        n_lines = q.size // kref.CXL_LINE_ELEMS
                        head = q[: n_lines * kref.CXL_LINE_ELEMS]
                        if n_lines:
                            lines = head.reshape(-1, kref.CXL_LINE_ELEMS)
                            narrow = (
                                np.abs(lines.astype(np.int32)).max(axis=1)
                                <= kref.CXL_NARROW_QMAX
                            )
                            n_narrow = int(narrow.sum())
                            wire += (
                                n_narrow * (kref.CXL_LINE_ELEMS // 2)
                                + (n_lines - n_narrow) * kref.CXL_LINE_ELEMS
                            )
                        wire += q.size - n_lines * kref.CXL_LINE_ELEMS
                    for sc in (ks, vs):
                        b = int(sc.size) * int(sc.dtype.itemsize)
                        nominal += b
                        wire += b
            if nominal > 0:
                dev.observe(float(nominal), float(wire))
                ratio = float(nominal) / float(max(wire, 1))
                self.manager.note_media_ratio(name, ratio)
                nominal_ratios = self.manager.tierset.ratios()
                for lvl in levels:
                    self.manager.update_measured_ratio(
                        lvl, nominal_ratios[lvl] * ratio
                    )
            dev.commit_window()

    # --------------------------------------------------------- window logic
    def end_window(self):
        """Run the placement model over existing pages and execute the plan.

        Serial mode (the oracle): the batched cohort executor runs the plan
        to completion before returning — the window boundary blocks.

        Async mode: cohorts are submitted to the media pipeline and the
        boundary returns immediately; decode steps tick the pipeline and
        the desired/physical reconcile happens when the batch drains. A
        previous window's stragglers are drained first so the placement
        model never plans over in-flight pages.
        """
        if self.pipeline.busy:
            self.pipeline.drain()
        if self.prefetch_enabled:
            # Speculation meets reality: finish staged speculative cohorts
            # into the held store before the plan is computed.
            self.pipeline.finish_speculative()
        self._observe_adaptive_media()
        plan = self.manager.end_window()
        self._prefetch_window_emitted = False
        if plan.regions.size == 0:
            if self.prefetch_enabled:
                self.pipeline.discard_speculative()  # nothing to claim: all misses
            return plan, 0
        # Manager may recommend DRAM(0) for hot pages; KV pages instead go
        # warm (the closest legal tier — recent window plays DRAM's role).
        dst = plan.dst.copy()
        dst[dst == 0] = WARM
        if self.async_migration:
            cohorts = self.plan_cohorts(plan.regions, dst)
            prestaged: Dict[int, Dict[str, np.ndarray]] = {}
            if self.prefetch_enabled:
                # Claim held pages the plan confirmed (hits — their demand
                # stage pays no source read); everything else was
                # mispredicted and is discarded, returning the ring credits.
                for crids, s, _d in cohorts:
                    if s not in _DEVICE:
                        prestaged.update(self.pipeline.claim_prefetched(crids, s))
                self.pipeline.discard_speculative()
            self._pending_reconcile.append(np.asarray(plan.regions, np.int64))
            queued = self.pipeline.submit(cohorts, prestaged=prestaged or None)
            if not self.pipeline.busy:
                # Empty plan after pre-passes: reconcile immediately.
                self.on_pipeline_drained()
            return plan, queued
        moved = self.migrate_batch(plan.regions, dst)
        # The executor wrote actual placements (incl. spills) back into
        # manager.placement so the cost model prices reality; also reconcile
        # planned no-ops (e.g. DRAM-recommended pages already sitting warm).
        ex = plan.regions[self._page_exists[plan.regions]]
        self.manager.placement[ex] = self.physical[ex]
        return plan, moved

    # ------------------------------------------------------------- metrics
    def hbm_bytes(self) -> int:
        st = self.state
        tot = 0
        for name in ("c8_k", "c8_k_scales", "c8_v", "c8_v_scales",
                     "c4_k", "c4_k_scales", "c4_v", "c4_v_scales",
                     "recent_k", "recent_v"):
            a = getattr(st, name)
            tot += a.size * a.dtype.itemsize
        return tot

    def tco_usd(self, tenant: Optional[int] = None) -> float:
        """Memory TCO of *existing* pages under the current placement,
        optionally restricted to one tenant's pages."""
        exists = self._page_exists
        if tenant is not None:
            exists = exists & self.tenant_mask(tenant)
        if not exists.any():
            return 0.0
        costs = tco.usd_per_region(
            self.manager.tierset, self.manager.region_bytes, self.manager.measured_ratios
        )
        return float(costs[self.manager.placement[exists]].sum())

    def tco_savings_pct(self, tenant: Optional[int] = None) -> float:
        """Savings vs holding every existing page uncompressed in HBM."""
        exists = self._page_exists
        if tenant is not None:
            exists = exists & self.tenant_mask(tenant)
        n = int(exists.sum())
        if n == 0:
            return 0.0
        mx = tco.tco_max(n, self.manager.region_bytes)
        return 100.0 * (mx - self.tco_usd(tenant)) / mx
