"""Serving engine: continuous batching over slots + tiered KV cache.

Request lifecycle: queue -> slot assignment -> prefill (dense, then pages
compress into the warm tier) -> decode steps (tiered attention, telemetry)
-> window boundary (TierScape placement) -> completion frees pages.

This engine runs smoke-scale archs end-to-end on CPU (tests, examples,
fig-benchmarks); the dry-run lowers its step function at full scale.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig, TierScapeRunConfig
from repro.core.manager import ManagerConfig
from repro.models.transformer import Model, _attn_layer_count
from repro.runtime import serve as serve_rt
from repro.serving.kv_cache import (
    ParkedSlot,
    TieredKVCache,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    tenant: int = 0  # owning tenant (engine serves interleaved tenant traffic)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class PreemptedRequest:
    """A request evicted from its batch slot with its KV parked on the host
    tier (plus SSM side-state for hybrid archs). ``TieredEngine.resume_into``
    swaps it back in with zero re-prefilled tokens."""

    request: Request
    parked: ParkedSlot
    ssm_conv: Optional[np.ndarray] = None
    ssm_state: Optional[np.ndarray] = None


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    windows: int = 0
    migrations: int = 0
    completed: int = 0
    # Frontend preemption-to-host-tier accounting: slots vacated for a
    # higher-SLA arrival, requests swapped back in from parked host pages,
    # pages restored by those swap-ins, and prompt tokens re-prefilled for
    # an already-started request (the frontend contract keeps this at 0 —
    # resume restores pages instead of recomputing them).
    preemptions: int = 0
    resumes: int = 0
    resumed_pages: int = 0
    re_prefill_tokens: int = 0
    # Decode steps retired while a migration cohort was in flight (async
    # media pipeline) — the numerator of overlap efficiency.
    overlapped_steps: int = 0
    # Speculative prefetch: pages staged ahead / confirmed / mispredicted.
    prefetch_staged: int = 0
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    # Decode-attention Pallas launches billed by the cache's dispatch proxy
    # (fused: n_layers per step, O(1) in tier count; per-pool oracle:
    # n_layers * n_pools).
    attn_launches: int = 0
    decode_s: float = 0.0
    daemon_s: float = 0.0
    tco_savings_pct: float = 0.0
    completed_by_tenant: Dict[int, int] = dataclasses.field(default_factory=dict)
    tco_savings_by_tenant: Dict[int, float] = dataclasses.field(default_factory=dict)


class TieredEngine:
    """Single-host engine for attention/hybrid archs with tiered KV."""

    def __init__(
        self,
        model: Model,
        params,
        batch_slots: int = 4,
        page_tokens: int = 16,
        max_seq_len: int = 512,
        recent_window: int = 32,
        ts: Optional[TierScapeRunConfig] = None,
        mesh=None,
    ):
        cfg = model.cfg
        assert cfg.has_attention, "tiered KV serving needs attention layers"
        self.model = model
        self.params = params
        self.cfg = cfg
        self.bs = batch_slots
        self.pt = page_tokens
        self.recent_window = recent_window
        self.max_seq_len = max_seq_len
        ts = ts or TierScapeRunConfig(enabled=True)
        self.ts = ts
        self.la = _attn_layer_count(cfg)

        mgr_cfg = ManagerConfig(
            policy=ts.policy,
            alpha=ts.alpha,
            hotness_threshold=ts.hotness_threshold,
            window_steps=ts.window_steps,
        )
        self.cache = TieredKVCache(
            cfg,
            self.la,
            batch_slots,
            page_tokens,
            max_seq_len,
            recent_window,
            mgr_cfg,
            async_migration=ts.async_migration,
            ring_slots=ts.media_ring_slots,
            prefetch=ts.prefetch,
            prefetch_max_pages=ts.prefetch_max_pages,
            pool_bits={
                "warm": getattr(ts, "warm_bits", 8),
                "cold": getattr(ts, "cold_bits", 4),
            },
        )
        from repro.launch.mesh import make_mesh

        default_mesh = mesh or make_mesh((1, 1), ("data", "model"))
        self._step_fn = jax.jit(
            serve_rt.make_tiered_decode_step(
                model, default_mesh, ParallelConfig(), ts, use_kernels=False
            )
        )
        # SSM side-state for hybrid archs.
        if cfg.family == "hybrid":
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            cconv = di + 2 * s.n_groups * s.d_state
            self.ssm_state = (
                jnp.zeros((cfg.n_layers, batch_slots, s.conv_kernel - 1, cconv), jnp.bfloat16),
                jnp.zeros(
                    (cfg.n_layers, batch_slots, s.n_heads(cfg.d_model), s.head_dim, s.d_state),
                    jnp.float32,
                ),
            )
        else:
            self.ssm_state = (jnp.zeros((0,)), jnp.zeros((0,)))

        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.slot_len = np.zeros(batch_slots, np.int64)
        self.queue: List[Request] = []
        self.stats = EngineStats()
        self._steps_in_window = 0
        # Monotonic request-id source: rids must stay unique for the whole
        # engine lifetime (frontend bookkeeping keys on them), so they can
        # never derive from the queue length.
        self._next_rid = 0

    # ----------------------------------------------------------------- API
    def make_request(self, prompt: np.ndarray, max_new_tokens: int,
                     tenant: int = 0) -> Request:
        """Mint a request with a unique monotonic rid WITHOUT enqueueing it
        (the frontend scheduler owns its own queue + slot placement)."""
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, tenant=tenant)
        self._next_rid += 1
        return req

    def submit(self, prompt: np.ndarray, max_new_tokens: int, tenant: int = 0) -> Request:
        # recent_len/total_len are per-slot vectors in the tiered state, so
        # slots hold unequal prompt lengths and decode at their own
        # positions.
        req = self.make_request(prompt, max_new_tokens, tenant)
        self.queue.append(req)
        return req

    def try_submit(self, prompt: np.ndarray, max_new_tokens: int,
                   tenant: int = 0, budget_frac: float = 1.0) -> Optional[Request]:
        """Token-budget admission: enqueue only if the projected footprint
        (prompt + full generation) fits inside ``budget_frac`` of the device
        pools' token capacity alongside everything already outstanding.
        Returns None (refused) instead of overcommitting toward OOM."""
        projected = int(len(prompt)) + int(max_new_tokens)
        if self.outstanding_tokens() + projected > budget_frac * self.token_capacity():
            return None
        return self.submit(prompt, max_new_tokens, tenant)

    # ------------------------------------------------- headroom accounting
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def token_capacity(self) -> int:
        """Sequence-token capacity of the device pools plus the dense recent
        windows (a class row stores one page of ONE layer, so pool rows
        divide by the attention layer count)."""
        rows = self._alloc_capacity("warm") + self._alloc_capacity("cold")
        return (rows // self.la) * self.pt + self.bs * self.recent_window

    def _alloc_capacity(self, pool: str) -> int:
        return int(self.cache._alloc[pool].capacity)

    def device_headroom_tokens(self) -> int:
        """Live device-tier headroom in sequence tokens (free class rows
        across both pools, layer-divided) — the admission controller's
        immediate-placement signal."""
        free = len(self.cache._free_warm) + len(self.cache._free_cold)
        return (free // self.la) * self.pt

    def outstanding_tokens(self) -> int:
        """Tokens the engine is already committed to: resident context plus
        the ungenerated remainder of active requests, plus full projected
        footprints of everything still queued."""
        out = 0
        for i, req in enumerate(self.slots):
            if req is not None:
                out += int(self.slot_len[i])
                out += max(req.max_new_tokens - len(req.out_tokens), 0)
        for req in self.queue:
            out += len(req.prompt) + req.max_new_tokens
        return out

    # ------------------------------------------------------------ stepping
    def run(self, max_steps: int = 10_000) -> EngineStats:
        while (any(s is not None for s in self.slots) or self.queue) and self.stats.steps < max_steps:
            self._fill_slots()
            self.step()
        return self.finish()

    def step(self) -> None:
        """One externally-drivable engine step: decode every active slot,
        then advance the profile window. The frontend scheduler calls this
        directly, interleaving placement/preemption between steps."""
        self._decode_step()
        self._steps_in_window += 1
        if self._steps_in_window >= self.ts.window_steps:
            self._end_window()

    def finish(self) -> EngineStats:
        """Drain in-flight cohorts and finalize the stats snapshot (idempotent
        — callable again after more stepping)."""
        t0 = time.perf_counter()
        self.cache.drain_migrations()
        self.stats.daemon_s += time.perf_counter() - t0
        self.stats.tco_savings_pct = max(
            self.stats.tco_savings_pct, self.cache.tco_savings_pct()
        )
        pipe = self.cache.pipeline
        self.stats.prefetch_staged = pipe.prefetch_staged
        self.stats.prefetch_hits = pipe.prefetch_hits
        self.stats.prefetch_misses = pipe.prefetch_misses
        self.stats.attn_launches = self.cache.attn_launches
        return self.stats

    # ----------------------------------------------- frontend slot control
    def start_request(self, slot: int, req: Request) -> None:
        """Place ``req`` into a specific FREE slot and prefill it — the
        frontend's admission-controlled alternative to the internal queue
        (``_fill_slots``) path."""
        if self.slots[slot] is not None:
            raise ValueError(f"start_request: slot {slot} is occupied")
        self.cache.set_slot_tenant(slot, req.tenant)
        self._prefill(slot, req)
        self.slots[slot] = req

    def preempt_slot(self, slot: int) -> PreemptedRequest:
        """Preemption-to-host-tier: demote the victim slot's device pages to
        their same-codec host tiers through the media pipeline (billed like
        normal demotions), park the payloads + recent window, and vacate the
        slot. The request keeps its pages — ``resume_into`` restores them
        with zero re-prefilled tokens."""
        req = self.slots[slot]
        if req is None or req.done:
            raise ValueError(f"preempt_slot: slot {slot} has no active request")
        levels = self.cache.demote_slot_to_host(slot)
        parked = self.cache.park_slot(slot, restore_levels=levels)
        pre = PreemptedRequest(request=req, parked=parked)
        if self.cfg.family == "hybrid":
            conv, sst = self.ssm_state
            pre.ssm_conv = np.asarray(conv[:, slot])
            pre.ssm_state = np.asarray(sst[:, slot])
        self.slots[slot] = None
        self.slot_len[slot] = 0
        self.stats.preemptions += 1
        return pre

    def resume_into(self, slot: int, pre: PreemptedRequest) -> Request:
        """Swap a preempted request back into a free slot: parked host pages
        re-register and the previously device-resident ones ride swap-in
        cohorts home. No prompt token is ever recomputed."""
        if self.slots[slot] is not None:
            raise ValueError(f"resume_into: slot {slot} is occupied")
        restored = self.cache.restore_slot(slot, pre.parked)
        if self.cfg.family == "hybrid" and pre.ssm_conv is not None:
            conv, sst = self.ssm_state
            self.ssm_state = (
                conv.at[:, slot].set(jnp.asarray(pre.ssm_conv).astype(conv.dtype)),
                sst.at[:, slot].set(jnp.asarray(pre.ssm_state).astype(sst.dtype)),
            )
        self.slots[slot] = pre.request
        self.slot_len[slot] = pre.parked.total_len
        self.stats.resumes += 1
        self.stats.resumed_pages += restored
        return pre.request

    # ------------------------------------------------------------ internals
    def _fill_slots(self):
        for i in range(self.bs):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.start_request(i, req)

    def _prefill(self, slot: int, req: Request):
        """Dense prefill, then page the prompt KV into the warm tier
        (batched: one quant dispatch for all layers x pages)."""
        cfg = self.cfg
        s = len(req.prompt)
        if req.out_tokens:
            # An already-started request is being prefilled again — the
            # wasted recompute the preemption path exists to avoid.
            self.stats.re_prefill_tokens += s
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        state = self.model.init_cache(1, max(s + 1, self.pt))
        logits, state = self.model.prefill(self.params, batch, state)
        # Page out everything except the tail that fits the recent window.
        n_full_pages = max((s - self.recent_window // 2) // self.pt, 0)
        k = np.asarray(state.k_cache.astype(jnp.float32))  # [L,1,S,KV,hd]
        v = np.asarray(state.v_cache.astype(jnp.float32))
        entries = [
            (layer, slot, page)
            for layer in range(self.la) for page in range(n_full_pages)
        ]
        if entries:
            kp = np.stack([k[layer, 0, page * self.pt:(page + 1) * self.pt]
                           for layer, _, page in entries])
            vp = np.stack([v[layer, 0, page * self.pt:(page + 1) * self.pt]
                           for layer, _, page in entries])
            self.cache.append_pages(entries, jnp.asarray(kp), jnp.asarray(vp))
        # Remaining tail into the recent window.
        tail = slice(n_full_pages * self.pt, s)
        tlen = s - n_full_pages * self.pt
        st = self.cache.state
        rk = st.recent_k.at[:, slot, :tlen].set(
            jnp.asarray(k[:, 0, tail]).astype(st.recent_k.dtype))
        rv = st.recent_v.at[:, slot, :tlen].set(
            jnp.asarray(v[:, 0, tail]).astype(st.recent_v.dtype))
        self.cache.state = dataclasses.replace(
            st, recent_k=rk, recent_v=rv,
            recent_len=st.recent_len.at[slot].set(tlen),
            total_len=st.total_len.at[slot].set(s),
        )
        self.slot_len[slot] = s
        req.out_tokens.append(int(jnp.argmax(logits[0, -1])))

        if cfg.family == "hybrid":
            # Recompute SSM states for this slot via recurrent prefill.
            dstate = self.model.init_cache(1, s + 1)
            dstate = self.model._prefill_recurrent(self.params, batch, dstate, serve_rt.shr
                                                   .activation_sharding(self._mesh_dummy(), ParallelConfig()))
            conv, sst = self.ssm_state
            self.ssm_state = (
                conv.at[:, slot].set(dstate.conv_state[:, 0].astype(conv.dtype)),
                sst.at[:, slot].set(dstate.ssm_state[:, 0]),
            )

    def _mesh_dummy(self):
        from repro.launch.mesh import make_mesh

        return make_mesh((1, 1), ("data", "model"))

    def _decode_step(self):
        t0 = time.perf_counter()
        tokens = np.zeros((self.bs, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                tokens[i, 0] = req.out_tokens[-1]
        logits, tkv, ssm_state, telemetry = self._step_fn(
            self.params, jnp.asarray(tokens), self.cache.state, self.ssm_state
        )
        self.cache.state = tkv
        self.ssm_state = ssm_state
        self.stats.decode_s += time.perf_counter() - t0

        t1 = time.perf_counter()
        self.cache.record_telemetry(telemetry)
        # Advance in-flight migration cohorts by one phase: decode retired a
        # step while migration ran — the overlap the async pipeline buys.
        if self.cache.pipeline.busy:
            self.cache.pipeline.tick()
            self.stats.overlapped_steps += 1
        else:
            # Idle media path: spend the step on speculative prefetch of
            # warming host pages (no-op unless ts.prefetch enabled).
            self.cache.prefetch_tick()
        self.stats.daemon_s += time.perf_counter() - t1

        next_tok = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out_tokens.append(int(next_tok[i]))
            self.slot_len[i] += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.stats.completed += 1
                self.stats.completed_by_tenant[req.tenant] = (
                    self.stats.completed_by_tenant.get(req.tenant, 0) + 1
                )
                self._release_slot(i)
        self.stats.steps += 1
        self._maybe_page_out_recent()

    def _maybe_page_out_recent(self):
        """When a slot's recent window fills, compress its oldest full
        pages. Per-slot: each slot pages out at its own fill level and its
        recent rows shift by its own amount (slots hold unequal lengths)."""
        st = self.cache.state
        rl = np.asarray(st.recent_len)  # [B]
        full = [
            i for i, req in enumerate(self.slots)
            if req is not None and int(rl[i]) >= self.recent_window
        ]
        if not full:
            return
        k = np.asarray(st.recent_k.astype(jnp.float32))  # [L,B,R,KV,hd]
        v = np.asarray(st.recent_v.astype(jnp.float32))
        # Page out all layers x full-slots x pages in one batched append.
        entries, kps, vps = [], [], []
        shift = np.zeros(self.bs, np.int64)
        for i in full:
            # Move floor(rl/pt)-1 pages out, keep the newest tokens dense
            # (n_out >= 1: the window is full, something must leave).
            n_out = max(int(rl[i]) // self.pt - 1, 1)
            shift[i] = n_out * self.pt
        for layer in range(self.la):
            for i in full:
                start_tok = int(self.slot_len[i]) - int(rl[i])
                for p in range(int(shift[i]) // self.pt):
                    page_idx = (start_tok + p * self.pt) // self.pt
                    sl = slice(p * self.pt, (p + 1) * self.pt)
                    entries.append((layer, i, page_idx))
                    kps.append(k[layer, i, sl])
                    vps.append(v[layer, i, sl])
        if entries:
            self.cache.append_pages(
                entries, jnp.asarray(np.stack(kps)), jnp.asarray(np.stack(vps))
            )
        st = self.cache.state
        # Per-slot roll, device-side: row b reads from (j + shift[b]) % R.
        r = st.recent_k.shape[2]
        idx = (jnp.arange(r, dtype=jnp.int32)[None, :]
               + jnp.asarray(shift, jnp.int32)[:, None]) % r  # [B, R]
        gidx = idx[None, :, :, None, None]
        self.cache.state = dataclasses.replace(
            st,
            recent_k=jnp.take_along_axis(st.recent_k, gidx, axis=2),
            recent_v=jnp.take_along_axis(st.recent_v, gidx, axis=2),
            recent_len=st.recent_len - jnp.asarray(shift, jnp.int32),
        )

    def _release_slot(self, slot: int):
        """Request finished: free its pages everywhere (batched)."""
        self.cache.release_slot_pages(slot)
        self.slots[slot] = None
        self.slot_len[slot] = 0
        st = self.cache.state
        self.cache.state = dataclasses.replace(
            st,
            recent_len=st.recent_len.at[slot].set(0),
            total_len=st.total_len.at[slot].set(0),
        )

    def _end_window(self):
        t0 = time.perf_counter()
        plan, moved = self.cache.end_window()
        self.stats.daemon_s += time.perf_counter() - t0
        self.stats.migrations += moved
        self.stats.windows += 1
        self._steps_in_window = 0
        # Snapshot TCO savings while pages are live (completion frees them).
        self.stats.tco_savings_pct = max(
            self.stats.tco_savings_pct, self.cache.tco_savings_pct()
        )
        for t in {r.tenant for r in self.slots if r is not None}:
            self.stats.tco_savings_by_tenant[t] = max(
                self.stats.tco_savings_by_tenant.get(t, 0.0),
                self.cache.tco_savings_pct(tenant=t),
            )
