from repro.serving.engine import Request, TieredEngine
from repro.serving.kv_cache import TieredKVCache

__all__ = ["TieredEngine", "TieredKVCache", "Request"]
