from repro.serving.engine import PreemptedRequest, Request, TieredEngine
from repro.serving.kv_cache import ParkedSlot, TieredKVCache

__all__ = [
    "TieredEngine",
    "TieredKVCache",
    "Request",
    "PreemptedRequest",
    "ParkedSlot",
]
