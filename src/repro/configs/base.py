"""Model/arch configuration schema.

One ``ModelConfig`` fully describes an architecture; ``src/repro/configs/<id>.py``
files instantiate the 10 assigned architectures (full scale) plus reduced
smoke variants. ``RunConfig`` adds the execution shape (mesh, batch, seq,
parallelism and TierScape settings) on top.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple


def _default_async_migration() -> bool:
    """Default for ``TierScapeRunConfig.async_migration``: True (the async
    media pipeline, equivalence-tested and perf-guarded since PR 3, is now
    the default path). ``REPRO_ASYNC_MIGRATION=0`` is the escape hatch back
    to the blocking window-boundary oracle; the nightly soak job exports
    ``REPRO_ASYNC_MIGRATION=1`` to force the async path explicitly."""
    v = os.environ.get("REPRO_ASYNC_MIGRATION", "1").strip().lower()
    return v not in ("0", "false", "off")


def _default_prefetch() -> bool:
    """Default for ``TierScapeRunConfig.prefetch``: True — the predictor is
    now fed in-engine (the fused decode kernel's host-page would-have-
    touched mass flows straight into ``prefetch_candidates``), closing the
    ROADMAP soak condition; placements stay bit-identical to a prefetch-free
    run by construction, so the flip is purely a latency win.
    ``REPRO_PREFETCH=0`` is the escape hatch, mirroring
    ``REPRO_ASYNC_MIGRATION``. Prefetch still requires the async path: with
    ``async_migration`` disabled the cache quietly ignores it."""
    v = os.environ.get("REPRO_PREFETCH", "1").strip().lower()
    return v not in ("0", "false", "off")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # dbrx-style fine-grained: experts formed by splitting wider FFNs. We
    # model the published (n_experts, top_k, d_ff) directly.


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128  # SSD chunk length
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # Attention flavor.
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False  # qwen2-vl multimodal 3-axis RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    causal: bool = True
    # FFN flavor.
    act: str = "swiglu"  # swiglu | gelu
    # Mixture of experts (family == "moe"): dense d_ff unused if 0.
    moe: Optional[MoEConfig] = None
    # State space (family in {"ssm","hybrid"}).
    ssm: Optional[SSMConfig] = None
    # Hybrid (zamba2): one shared attention+MLP block applied every k layers.
    hybrid_attn_every: int = 0
    # Modality frontend stub: inputs are precomputed frame/patch embeddings
    # instead of token ids ("audio" | "vision" | None).
    frontend: Optional[str] = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # Norm style: "rmsnorm" | "layernorm" (hubert uses LN).
    norm: str = "rmsnorm"

    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_decoder(self) -> bool:
        return self.family != "encoder"

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.head_dim_()
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        attn = d * n_q + 2 * d * n_kv + n_q * d
        if self.qkv_bias:
            attn += n_q + 2 * n_kv
        if self.act == "swiglu":
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        per_layer = 0
        if self.family in ("dense", "encoder", "vlm"):
            per_layer = attn + ffn
            total = self.n_layers * per_layer
        elif self.family == "moe":
            m = self.moe
            ffn_e = 3 * d * m.d_ff_expert if self.act == "swiglu" else 2 * d * m.d_ff_expert
            router = d * m.n_experts
            total = self.n_layers * (attn + m.n_experts * ffn_e + router)
        elif self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj (z,x,B,C,dt) + out_proj + conv
            in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
            total = self.n_layers * (in_proj + di * d + s.conv_kernel * (di + 2 * s.n_groups * s.d_state))
        elif self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
            mamba = self.n_layers * (in_proj + di * d + s.conv_kernel * (di + 2 * s.n_groups * s.d_state))
            shared = attn + ffn  # one shared transformer block
            total = mamba + shared
        else:
            raise ValueError(self.family)
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings and self.is_decoder:
            total += self.vocab_size * d
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model
        ffn_e = 3 * d * m.d_ff_expert if self.act == "swiglu" else 2 * d * m.d_ff_expert
        inactive = self.n_layers * (m.n_experts - m.experts_per_token) * ffn_e
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the (pod, data, model) mesh."""

    fsdp: bool = False  # shard params/opt-state over the data axis too
    remat: str = "block"  # "none" | "block" (checkpoint each layer)
    scan_layers: bool = True
    # Sequence-parallel KV sharding for decode (long context).
    shard_kv_seq: bool = False
    # Gradient compression for the cross-pod reduce (int8 + error feedback).
    grad_compress_pods: bool = False
    # Microbatching (gradient accumulation steps).
    grad_accum: int = 1


@dataclasses.dataclass(frozen=True)
class TierScapeRunConfig:
    """TierScape engagement for a run."""

    enabled: bool = False
    policy: str = "analytical"  # waterfall | analytical | 2t
    alpha: float = 0.5
    hotness_threshold: float = 8.0
    window_steps: int = 64
    kv_page_tokens: int = 64  # tokens per managed KV page
    # Device-resident tier pair used inside the jitted serve step.
    warm_tier: str = "C1"
    cold_tier: str = "C9"
    # Backing-media subsystem: route window migration plans through the
    # async double-buffered pipeline (non-blocking window boundaries) and
    # size its pinned staging ring. Defaults on (env-overridable, see
    # ``_default_async_migration``); off = blocking migrate_batch (the
    # equivalence oracle).
    async_migration: bool = dataclasses.field(
        default_factory=_default_async_migration
    )
    media_ring_slots: int = 64
    # Speculative prefetch/readahead on the media pipeline: mid-window,
    # host-resident pages whose access rate is rising toward the promotion
    # frontier are staged through a reserved slice of the pinned ring so a
    # window-boundary promotion commits without paying the swap-in read.
    # Requires the async pipeline; placements stay bit-identical to a
    # prefetch-free run (speculation hides latency, never changes policy).
    # Defaults on now that the fused decode kernel feeds the predictor
    # in-engine (env-overridable, see ``_default_prefetch``).
    prefetch: bool = dataclasses.field(default_factory=_default_prefetch)
    prefetch_max_pages: int = 8
    # Codec widths (8 or 4) of the device pools. Pools of equal width share
    # one codec-class payload buffer (class-major storage): the fused decode
    # step reads them with zero per-step concatenation and same-class
    # migrations are pure page-table edits. The (8, 4) default reproduces
    # the classic int8-warm / int4-cold split.
    warm_bits: int = 8
    cold_bits: int = 4
