"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family] — MoE decoder:
94 layers, 128 experts top-8, per-expert d_ff=1536, GQA kv=4, qk-norm."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,  # per-expert (mirrored in moe.d_ff_expert)
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    act="swiglu",
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=128, experts_per_token=8, d_ff_expert=1536),
)

SMOKE = ModelConfig(
    name="qwen3_moe_235b_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    head_dim=32,
    qk_norm=True,
    act="swiglu",
    moe=MoEConfig(n_experts=8, experts_per_token=2, d_ff_expert=96),
)
