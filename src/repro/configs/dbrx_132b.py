"""DBRX 132B [hf:databricks/dbrx-base] — fine-grained MoE decoder:
16 experts top-4, per-expert d_ff=10752, GQA kv=8."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx_132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    act="swiglu",
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, experts_per_token=4, d_ff_expert=10752),
)

SMOKE = ModelConfig(
    name="dbrx_132b_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    act="swiglu",
    moe=MoEConfig(n_experts=4, experts_per_token=2, d_ff_expert=128),
)
