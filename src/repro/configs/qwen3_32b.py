"""Qwen3-32B [hf:Qwen/Qwen3-8B family] — dense decoder, GQA kv=8,
per-head q/k RMS norm, head_dim=128."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    act="swiglu",
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen3_32b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    head_dim=32,
    qk_norm=True,
    act="swiglu",
)
