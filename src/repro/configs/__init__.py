"""Architecture registry: the 10 assigned archs (full scale) + reduced smoke
variants + the paper's TierScape tier presets.

Every entry is ``src/repro/configs/<id>.py`` exposing ``CONFIG`` (full) and
``SMOKE`` (reduced, CPU-runnable). ``get(name)`` / ``get_smoke(name)`` look
them up; ``--arch <id>`` in the launchers resolves through here.
"""

from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    ShapeConfig,
    SSMConfig,
    TierScapeRunConfig,
)

ARCH_IDS = [
    "hubert_xlarge",
    "command_r_35b",
    "qwen3_32b",
    "internlm2_20b",
    "qwen1_5_4b",
    "qwen3_moe_235b",
    "dbrx_132b",
    "mamba2_780m",
    "zamba2_1_2b",
    "qwen2_vl_72b",
]


def _module(name: str):
    name = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def arch_ids() -> List[str]:
    return list(ARCH_IDS)


# Which shape cells run per arch (None entries are recorded skips — see
# DESIGN.md §Arch-applicability).
def cells_for(name: str):
    cfg = get(name)
    cells = ["train_4k", "prefill_32k"]
    if cfg.is_decoder:
        cells.append("decode_32k")
    if cfg.family in ("ssm", "hybrid"):
        cells.append("long_500k")
    return cells


def skipped_cells_for(name: str):
    return [s for s in SHAPES if s not in cells_for(name)]


__all__ = [
    "ARCH_IDS",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "ParallelConfig",
    "TierScapeRunConfig",
    "SHAPES",
    "get",
    "get_smoke",
    "arch_ids",
    "cells_for",
    "skipped_cells_for",
]
