"""Zamba2-1.2B [arXiv:2411.15242] — hybrid: Mamba2 backbone + one
weight-shared attention+MLP block applied every 6 layers.

The shared attention keeps a full KV cache per application => long_500k runs
WITH tiered compressed KV — the flagship paper-technique cell.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2_1_2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4, chunk=128),
    hybrid_attn_every=6,
    act="gelu",  # zamba2 shared MLP uses gelu
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="zamba2_1_2b_smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4, chunk=32),
    hybrid_attn_every=2,
    act="gelu",
    tie_embeddings=True,
)
