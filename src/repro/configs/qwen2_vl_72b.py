"""Qwen2-VL 72B [arXiv:2409.12191] — VLM decoder backbone with M-RoPE
(3-axis rotary over temporal/height/width position ids).

Backbone only; the vision tower is a stub — `input_specs` supplies
precomputed patch embeddings + an embeds mask + 3-axis position ids.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    act="swiglu",
    rope_theta=1000000.0,
    frontend="vision",
)

SMOKE = ModelConfig(
    name="qwen2_vl_72b_smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    head_dim=32,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(4, 6, 6),
    act="swiglu",
    frontend="vision",
)
