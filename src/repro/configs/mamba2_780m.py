"""Mamba2-780M [arXiv:2405.21060] — attention-free SSD decoder.

O(1) decode state => long_500k runs natively. The paper's KV tiering is
inapplicable to the SSM state (nothing grows with context); TierScape still
manages its embedding/optimizer state. See DESIGN.md §Arch-applicability.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2_780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, chunk=128),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2_780m_smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4, chunk=32),
    tie_embeddings=True,
)
