"""Qwen1.5-4B [hf:Qwen/Qwen1.5 family] — dense decoder, MHA (kv=20) with
QKV biases."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1_5_4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    act="swiglu",
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen1_5_4b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=256,
    qkv_bias=True,
    act="swiglu",
)
