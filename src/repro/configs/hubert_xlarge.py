"""HuBERT X-Large [arXiv:2106.07447] — audio encoder-only transformer.

Backbone only; the conv waveform frontend is a stub (`input_specs` provides
precomputed frame embeddings). vocab=504 is the masked-prediction codebook.
Encoder-only => no decode shapes (recorded skip).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert_xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    act="gelu",
    norm="layernorm",
    causal=False,
    frontend="audio",
    rope_theta=10000.0,  # conv-positional in the original; RoPE stands in
)

SMOKE = ModelConfig(
    name="hubert_xlarge_smoke",
    family="encoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    act="gelu",
    norm="layernorm",
    causal=False,
    frontend="audio",
)
