"""Cohere Command-R 35B [hf:CohereForAI/c4ai-command-r-v01] — dense decoder,
GQA kv=8, no biases, 256k vocab (the strongest cold-embedding case for
tiered optimizer state)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command_r_35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    act="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,  # command-r ties input/output embeddings
)

SMOKE = ModelConfig(
    name="command_r_35b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    act="swiglu",
    tie_embeddings=True,
)
