"""InternLM2-20B [arXiv:2403.17297] — dense decoder, GQA kv=8."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2_20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    act="swiglu",
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="internlm2_20b_smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    act="swiglu",
)
