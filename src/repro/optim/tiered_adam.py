"""Tiered/compressed Adam moments — TierScape applied to optimizer state.

Adam's m/v for cold parameter regions (embedding rows of 150k-256k vocabs,
inactive experts) dominate training-state HBM at scale. Following the paper,
each leaf's moment storage lives in a software-defined compressed tier:

    policy[leaf_path] in {"none" (f32), "bf16", "int8", "int4"}

int8/int4 use per-group absmax scales (group=128 on the trailing axis) with
the same fixed ratio/latency trade-offs as the KV tiers. The TierScape
manager chooses the policy per profile window from update-magnitude
telemetry (hot leaves -> cheap codecs, cold leaves -> dense codecs); the
update itself decodes -> applies Adam -> re-encodes, entirely inside jit.

This is a faithful transplant of the paper's "warm data in low-latency
tiers, cold data in high-ratio tiers" to training state; §Arch-applicability
notes it is the only TierScape surface for attention-free archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

Array = jax.Array
PyTree = Any

GROUP = 128
QMAX = {"int8": 127.0, "int4": 7.0}
# Production data-axis degree: the (ng, group) reshape inside the update must
# keep ng divisible by it, or GSPMD all-gathers the (data-sharded) payload.
DP_HINT = 16


def group_for(last_dim: int) -> int:
    """Group size for a leaf whose trailing dim is ``last_dim``: prefer 128,
    fall back so that last_dim % g == 0 and (last_dim//g) % DP_HINT == 0 —
    keeps the grouped reshape local under data-axis sharding."""
    for g in (128, 96, 64, 48, 32):
        if last_dim % g == 0 and (last_dim // g) % DP_HINT == 0:
            return g
    for g in (128, 96, 64, 48, 32):
        if last_dim % g == 0:
            return g
    return GROUP


def _pad_len(n: int) -> int:
    return (-n) % GROUP


# µ-law companding constants: dynamic (logarithmic) int codes give small
# moments relative precision even in groups dominated by a large value —
# linear absmax codes stall small coordinates (this is why 8-bit Adam
# implementations use dynamic/blockwise codes, e.g. bitsandbytes).
MU = {"int8": 255.0, "int4": 15.0}


def _mulaw_enc(xn: Array, mu: float, qmax: float) -> Array:
    return jnp.sign(xn) * jnp.log1p(mu * jnp.abs(xn)) / jnp.log1p(mu) * qmax


def _mulaw_dec(q: Array, mu: float, qmax: float) -> Array:
    y = q / qmax
    return jnp.sign(y) * (jnp.expm1(jnp.abs(y) * jnp.log1p(mu))) / mu


def encode_moment(x: Array, codec: str):
    """f32 moment leaf -> (payload, scales) under ``codec``.

    Grouping happens along the LAST axis only (padded to GROUP), so every
    leading dimension — and its sharding — survives the transform. (A
    whole-tensor flatten forces GSPMD to replicate the reshape: observed
    39GB/device buffers on the 132B MoE before this.) int4 payloads are
    nibble-packed; codec-free leaves carry a zero-size scales array so the
    state pytree stays uniform.
    """
    if codec == "none":
        return x.astype(jnp.float32), jnp.zeros((0, 1), jnp.float32)
    if codec == "bf16":
        return x.astype(jnp.bfloat16), jnp.zeros((0, 1), jnp.float32)
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf.reshape(1)
    last = xf.shape[-1]
    grp = group_for(last)
    pad = (-last) % grp
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    lead = xf.shape[:-1]
    ng = xf.shape[-1] // grp
    g = xf.reshape(*lead, ng, grp)
    qmax = QMAX[codec]
    scale = jnp.maximum(jnp.max(jnp.abs(g), axis=-1), 1e-20)  # [*lead, ng]
    q = jnp.clip(jnp.round(_mulaw_enc(g / scale[..., None], MU[codec], qmax)), -qmax, qmax)
    q = q.reshape(*lead, ng * grp).astype(jnp.int32)
    if codec == "int4":
        lo = q[..., 0::2] & 0xF
        hi = q[..., 1::2] & 0xF
        return (lo | (hi << 4)).astype(jnp.uint8), scale.astype(jnp.float32)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def decode_moment(payload: Array, scales, codec: str, shape) -> Array:
    if codec in ("none", "bf16"):
        return payload.astype(jnp.float32)
    if codec == "int4":
        p = payload.astype(jnp.int32)
        lo = p & 0xF
        hi = (p >> 4) & 0xF
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)
        q = q.astype(jnp.float32)
    else:
        q = payload.astype(jnp.float32)
    lead = q.shape[:-1]
    last = shape[-1] if len(shape) else 1
    grp = group_for(last)
    ng = q.shape[-1] // grp
    g = q.reshape(*lead, ng, grp)
    x = _mulaw_dec(g, MU[codec], QMAX[codec]) * scales[..., None]
    x = x.reshape(*lead, ng * grp)
    x = x[..., :last]
    return x.reshape(shape)


@dataclasses.dataclass
class TieredAdamState:
    m: PyTree  # payloads
    m_scales: PyTree
    v: PyTree
    v_scales: PyTree
    step: Array
    policy: Dict[str, str]  # leaf-path -> codec (static per jit trace)


# policy is static metadata (it changes only at window boundaries, forcing a
# deliberate retrace — that IS the tier-migration event).
jax.tree_util.register_dataclass(
    TieredAdamState,
    data_fields=("m", "m_scales", "v", "v_scales", "step"),
    meta_fields=("policy",),
)


def _freeze_policy(policy: Dict[str, str]):
    return tuple(sorted(policy.items()))


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def default_policy(params: PyTree, cold_codec: str = "int8") -> Dict[str, str]:
    """Embedding-like leaves (vocab-scale rows) -> compressed; rest f32."""
    policy = {}

    def visit(path, leaf):
        p = _path_str(path)
        policy[p] = cold_codec if ("embed" in p or "lm_head" in p) else "none"

    jax.tree_util.tree_map_with_path(visit, params)
    return policy


def init(params: PyTree, policy: Dict[str, str]) -> TieredAdamState:
    def enc_zero(path, p, for_v=False):
        codec = policy[_path_str(path)]
        if for_v and codec == "int4":
            codec = "int8"
        return encode_moment(jnp.zeros(p.shape, jnp.float32), codec)

    paths_leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    enc = [enc_zero(path, p) for path, p in paths_leaves]
    enc_v = [enc_zero(path, p, for_v=True) for path, p in paths_leaves]
    mk = lambda es, i: jax.tree.unflatten(treedef, [e[i] for e in es])
    return TieredAdamState(
        m=mk(enc, 0),
        m_scales=mk(enc, 1),
        v=mk(enc_v, 0),
        v_scales=mk(enc_v, 1),
        step=jnp.zeros((), jnp.int32),
        policy=_freeze_policy(policy),
    )


def update(
    grads: PyTree,
    state: TieredAdamState,
    params: PyTree,
    cfg: AdamWConfig,
) -> Tuple[PyTree, TieredAdamState, Dict[str, Array]]:
    grads, gnorm = adamw.clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    paths_leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_msc = treedef.flatten_up_to(state.m_scales)
    flat_v = treedef.flatten_up_to(state.v)
    flat_vsc = treedef.flatten_up_to(state.v_scales)

    pol = dict(state.policy)
    new_p, new_m, new_msc, new_v, new_vsc = [], [], [], [], []
    # Scheduling token: chains leaf updates so XLA processes them one at a
    # time — the decode->update->encode working set of a 235B expert leaf is
    # ~4GB f32, and without the chain the scheduler overlaps all leaves.
    token = jnp.zeros((), jnp.float32)
    for (path, p), g, m_pay, m_sc, v_pay, v_sc in zip(
        paths_leaves, flat_g, flat_m, flat_msc, flat_v, flat_vsc
    ):
        codec = pol[_path_str(path)]
        # 4-bit Adam keeps the second moment at 8 bits (1/sqrt(v) blows up
        # under a 15-level code) — standard 4-bit-optimizer practice.
        codec_v = "int8" if codec == "int4" else codec
        g, token = jax.lax.optimization_barrier((g, token))
        m = decode_moment(m_pay, m_sc, codec, p.shape)
        v = decode_moment(v_pay, v_sc, codec_v, p.shape)
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        v = jnp.maximum(v, 0.0)  # quantization can introduce tiny negatives
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p.append((p.astype(jnp.float32) - lr * delta).astype(p.dtype))
        mp, msc = encode_moment(m, codec)
        vp, vsc = encode_moment(v, codec_v)
        new_m.append(mp)
        new_msc.append(msc)
        new_v.append(vp)
        new_vsc.append(vsc)
        token = token + new_p[-1].reshape(-1)[0].astype(jnp.float32) * 0.0

    mk = lambda leaves: jax.tree.unflatten(treedef, leaves)
    new_state = TieredAdamState(
        m=mk(new_m),
        m_scales=mk(new_msc),
        v=mk(new_v),
        v_scales=mk(new_vsc),
        step=step,
        policy=state.policy,
    )
    return mk(new_p), new_state, {"grad_norm": gnorm, "lr": lr}


def moment_bytes(state: TieredAdamState) -> int:
    tot = 0
    for tree in (state.m, state.m_scales, state.v, state.v_scales):
        for leaf in jax.tree.leaves(tree):
            if leaf is not None:
                tot += leaf.size * leaf.dtype.itemsize
    return tot


def repack(state: TieredAdamState, params: PyTree, new_policy: Dict[str, str]) -> TieredAdamState:
    """Tier migration for optimizer state: decode under the old policy,
    re-encode under the new one (the manager calls this between windows)."""
    paths_leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    flat_m = treedef.flatten_up_to(state.m)
    flat_msc = treedef.flatten_up_to(state.m_scales)
    flat_v = treedef.flatten_up_to(state.v)
    flat_vsc = treedef.flatten_up_to(state.v_scales)
    pol = dict(state.policy)
    new_m, new_msc, new_v, new_vsc = [], [], [], []
    for (path, p), m_pay, m_sc, v_pay, v_sc in zip(paths_leaves, flat_m, flat_msc, flat_v, flat_vsc):
        key = _path_str(path)
        old_vc = "int8" if pol[key] == "int4" else pol[key]
        new_vc = "int8" if new_policy[key] == "int4" else new_policy[key]
        m = decode_moment(m_pay, m_sc, pol[key], p.shape)
        v = decode_moment(v_pay, v_sc, old_vc, p.shape)
        mp, msc = encode_moment(m, new_policy[key])
        vp, vsc = encode_moment(v, new_vc)
        new_m.append(mp)
        new_msc.append(msc)
        new_v.append(vp)
        new_vsc.append(vsc)
    mk = lambda leaves: jax.tree.unflatten(treedef, leaves)
    return TieredAdamState(
        m=mk(new_m), m_scales=mk(new_msc), v=mk(new_v), v_scales=mk(new_vsc),
        step=state.step, policy=_freeze_policy(new_policy),
    )
