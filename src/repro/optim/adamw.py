"""AdamW over arbitrary pytrees (no optax dependency).

Moments are stored in f32 by default; ``tiered_adam`` swaps selected leaves'
moment storage to software-defined compressed tiers (int8/int4 block quant)
per the TierScape placement policy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    # schedule(step) -> multiplier on lr; default constant.
    schedule: Optional[Callable[[Array], Array]] = None


def global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def init(params: PyTree, moment_dtype=jnp.float32) -> Dict[str, PyTree]:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def update(
    grads: PyTree,
    state: Dict[str, PyTree],
    params: PyTree,
    cfg: AdamWConfig,
):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}


def cosine_schedule(warmup: int, total: int, min_frac: float = 0.1):
    def fn(step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return fn
