from repro.optim import adamw, grad_compress, tiered_adam
from repro.optim.adamw import AdamWConfig, cosine_schedule

__all__ = ["adamw", "tiered_adam", "grad_compress", "AdamWConfig", "cosine_schedule"]
