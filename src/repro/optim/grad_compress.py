"""Int8 error-feedback gradient compression for the cross-pod reduce.

The inter-pod hop (DCN / optical) is the scarcest bandwidth in a multi-pod
job, and gradients are the dominant traffic on it. This applies the paper's
idea to the wire: a software-defined compressed tier for gradients —
per-group absmax int8 (4x fewer bytes than f32) with an error-feedback
residual so compression noise becomes a delayed, not lost, contribution
(Karimireddy et al., EF-SGD).

Usage inside a shard_map whose manual axis is "pod":

    g_sum, new_resid = compressed_psum(g_local + resid, "pod")

Plain-jnp encode/decode (group=256) — the wire format, not a kernel.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

GROUP = 256
QMAX = 127.0


def _enc(x: Array) -> Tuple[Array, Array]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % GROUP
    if pad:
        flat = jnp.pad(flat, (0, pad))
    g = flat.reshape(-1, GROUP)
    scale = jnp.maximum(jnp.max(jnp.abs(g), axis=1, keepdims=True) / QMAX, 1e-20)
    q = jnp.clip(jnp.round(g / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def _dec(q: Array, scale: Array, shape) -> Array:
    n = 1
    for s in shape:
        n *= int(s)
    x = q.astype(jnp.float32) * scale
    return x.reshape(-1)[:n].reshape(shape)


def compress_roundtrip(x: Array) -> Tuple[Array, Array]:
    """Returns (quantized_value, residual): x = value + residual."""
    q, s = _enc(x)
    xq = _dec(q, s, x.shape)
    return xq, x.astype(jnp.float32) - xq


def compressed_psum_tree(
    grads: PyTree, residual: PyTree, axis_name: str
) -> Tuple[PyTree, PyTree]:
    """Error-feedback compressed all-reduce over ``axis_name``.

    Each participant quantizes (grad + residual) to int8, all-reduces the
    *quantized* values (the wire carries int8 payload + f32 group scales;
    psum of dequantized values models the reduction result exactly — the
    bytes-on-wire accounting is what the roofline uses), and keeps the
    quantization error as next step's residual.
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + (0.0 if r is None else r)
        xq, new_r = compress_roundtrip(gf)
        return jax.lax.psum(xq, axis_name), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual) if residual is not None else [None] * len(flat_g)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    summed = jax.tree.unflatten(treedef, [o[0] for o in out])
    resid = jax.tree.unflatten(treedef, [o[1] for o in out])
    return summed, resid


def init_residual(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes(params: PyTree) -> Tuple[int, int]:
    """(uncompressed f32 bytes, compressed int8+scales bytes) per reduce."""
    raw = sum(p.size * 4 for p in jax.tree.leaves(params))
    comp = sum(p.size + (p.size // GROUP + 1) * 4 for p in jax.tree.leaves(params))
    return raw, comp
