"""Pallas TPU kernels for the paper's compute hot-spots:

  * ``quant_page``      — tier compression (bf16 KV page -> int8/int4+scales)
  * ``dequant_page``    — tier decompression (the fault path)
  * ``transcode_page``  — fused tier-to-tier requantization (the migration
                          path: int8 <-> int4 with no dense HBM round-trip)
  * ``paged_attention`` — decode attention over quantized tier pools
                          (warm-data access without fault-and-decompress):
                          the per-pool kernel plus the single-launch
                          multi-tier megakernel (unified page table, host
                          sentinel rows, in-VMEM logsumexp merge)

``ops`` holds the jit'd wrappers; ``ref`` the pure-jnp oracles every kernel
is tested against (shape/dtype sweeps in tests/test_kernels.py).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
