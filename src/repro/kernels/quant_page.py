"""Pallas TPU kernel: KV-page block quantization (the tier *compress* path).

Grid over pages; each program quantizes one [T, KV, hd] page to int8 or
packed int4 with per-(token, kv-head) absmax scales. Blocks are VMEM-resident
(a 64-token x 8-head x 128-dim page is 128KB bf16 — comfortably within VMEM)
and hd=head_dim is the 128-lane axis, so the absmax reduce and the scale
multiply both vectorize cleanly on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packing import QMAX, pack_int4


def _quant_kernel(page_ref, payload_ref, scale_ref, *, bits: int):
    x = page_ref[...].astype(jnp.float32)  # [1, T, KV, hd]
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax == 0.0, 1.0, amax / QMAX[bits])
    q = jnp.clip(jnp.round(x / scale[..., None]), -QMAX[bits], QMAX[bits])
    if bits == 8:
        payload_ref[...] = q.astype(jnp.int8)
    else:
        payload_ref[...] = pack_int4(q)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quant_pages(pages: jax.Array, bits: int, interpret: bool = True):
    """pages [P, T, KV, hd] bf16 -> (payload, scales [P, T, KV])."""
    p, t, kv, hd = pages.shape
    hd_out = hd if bits == 8 else hd // 2
    out_dtype = jnp.int8 if bits == 8 else jnp.uint8
    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=(p,),
        in_specs=[pl.BlockSpec((1, t, kv, hd), lambda i: (i, 0, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, t, kv, hd_out), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, t, kv, hd_out), out_dtype),
            jax.ShapeDtypeStruct((p, t, kv), jnp.float32),
        ],
        interpret=interpret,
    )(pages)
