"""jit'd public wrappers for the Pallas kernels.

``tiered_decode_attention`` is the serving hot path. Default mode is the
single-launch megakernel (``paged_attention.fused_tiered_attention``): one
unified page table walks every compressed page of a sequence regardless of
codec, the dense recent window rides the final grid step, host-resident
pages appear as sentinel rows emitting a "would-have-touched" mass, and the
logsumexp merge happens in VMEM scratch — exactly one Pallas launch per
decode step, O(1) in tier count.

``use_fused(False)`` flips back to the legacy per-pool path (one kernel
launch per tier pool + a dense recent pass + a post-hoc jnp merge) — kept
as the equivalence oracle: outputs and normalized hotness must match the
fused path to fp32 tolerance. ``use_pallas`` independently toggles kernel
vs pure-jnp oracle (ref.py); kernels run in interpret mode on CPU (the TPU
lowering is exercised by the dry-run).

``page_hotness`` turns per-page mass telemetry into the normalized hotness
the TierScape manager consumes. ``launch_count``/``reset_launch_count``
count actual Pallas launches issued through this module (the benchmark /
baseline-guard metric); ``decode_launches_per_step`` is the modeled
launches-per-decode-step proxy the serving cache bills, valid on the ref
path too.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.dequant_page import dequant_pages as dequant_pages_kernel
from repro.kernels.paged_attention import (
    TIER_HOST,
    TIER_INT4,
    TIER_INT8,
    TIER_INVALID,
    fused_tiered_attention as fused_attn_kernel,
    paged_quant_attention as paged_attn_kernel,
)
from repro.kernels.quant_page import quant_pages as quant_pages_kernel
from repro.kernels.transcode_page import transcode_pages as transcode_pages_kernel

Array = jax.Array

_USE_PALLAS = True
_USE_FUSED = True

# Pallas launches issued through this module since the last reset (trace-time
# count; call the wrappers eagerly — as the benchmarks do — for a per-step
# reading).
_LAUNCHES = 0

# Device bytes materialized by per-step payload concatenation in
# ``_unified_operands`` since the last reset (trace-time count, same caveat
# as ``_LAUNCHES``). Zero on the class-major layout at ANY tier count —
# same-class pools share one buffer and the unified table addresses it
# directly. Non-zero only on the legacy standalone-buffer layout, which is
# kept for back-compat and the equivalence tests; the ``decode_fused``
# baseline guard pins this to 0.
_COPY_BYTES = 0


def use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def use_fused(flag: bool) -> None:
    """Toggle the single-launch megakernel (True, default) vs the per-pool
    launch loop (False — the equivalence oracle)."""
    global _USE_FUSED
    _USE_FUSED = flag


def reset_launch_count() -> None:
    global _LAUNCHES
    _LAUNCHES = 0


def launch_count() -> int:
    return _LAUNCHES


def reset_copy_bytes() -> None:
    global _COPY_BYTES
    _COPY_BYTES = 0


def concat_copy_bytes() -> int:
    """Device bytes copied by payload concatenation since the last reset."""
    return _COPY_BYTES


def _count_launch(n: int = 1) -> None:
    global _LAUNCHES
    if _USE_PALLAS:
        _LAUNCHES += n


def decode_launches_per_step(n_pools: int) -> int:
    """Modeled attention launches per (layer, decode step): 1 on the fused
    path regardless of tier count (host sentinels ride the same launch),
    one per tier pool on the legacy path. Mode-dependent, backend-agnostic:
    the jnp oracle mirrors the same launch structure, so the serving
    cache's dispatch proxy bills it identically."""
    if _USE_FUSED:
        return 1
    return int(n_pools)


def quant_pages(pages: Array, bits: int) -> Tuple[Array, Array]:
    if _USE_PALLAS:
        out = quant_pages_kernel(pages, bits)
        return out[0], out[1]
    return _ref.quant_kv_page(pages, bits)


def dequant_pages(payload: Array, scales: Array, bits: int, out_dtype=jnp.bfloat16) -> Array:
    if _USE_PALLAS:
        return dequant_pages_kernel(payload, scales, bits, out_dtype)
    return _ref.dequant_kv_page(payload, scales, bits).astype(out_dtype)


def transcode_pages(
    payload: Array, scales: Array, src_bits: int, dst_bits: int
) -> Tuple[Array, Array]:
    """Fused tier-to-tier requantization of a [P, ...] page batch — the
    batched migration executor's single dispatch per transcoding cohort."""
    if src_bits == dst_bits:
        return payload, scales
    if _USE_PALLAS:
        out = transcode_pages_kernel(payload, scales, src_bits, dst_bits)
        return out[0], out[1]
    return _ref.transcode_kv_page(payload, scales, src_bits, dst_bits)


def _pool_partials(q: Array, pool: Dict[str, Array]):
    fn = paged_attn_kernel if _USE_PALLAS else _ref.paged_quant_attention
    _count_launch()
    return fn(
        q,
        pool["k_pages"],
        pool["k_scales"],
        pool["v_pages"],
        pool["v_scales"],
        pool["page_table"],
        pool["n_pages"],
        pool["bits"],
    )


# ---------------------------------------------------------------------------
# Unified-table construction (fused path)
# ---------------------------------------------------------------------------


_CLASS_KEYS = ("k_pages", "k_scales", "v_pages", "v_scales")


def _validated_page_tokens(pools, host) -> int:
    """THE page-tokens value of a fused launch: every device pool's page
    shape and the host sentinels' ``page_tokens`` must agree, because one
    unified table walks them all and the sentinel would-have-touched mass
    multiplies by this count. A mismatch used to silently mis-scale
    sentinel mass (the host value rode a separate kernel argument); now it
    raises."""
    t = None
    src = None
    for n in sorted(pools):
        tn = int(pools[n]["k_pages"].shape[1])
        if t is None:
            t, src = tn, f"pool {n!r}"
        elif tn != t:
            raise ValueError(
                f"mixed page_tokens in fused launch: {src} has {t} "
                f"tokens/page but pool {n!r} has {tn} — every pool and the "
                f"host sentinels must share one page size (deploy unequal "
                f"page sizes as separate caches)"
            )
    if host is not None:
        ht = int(host["page_tokens"])
        if t is None:
            t = ht
        elif ht != t:
            raise ValueError(
                f"mixed page_tokens in fused launch: {src} has {t} "
                f"tokens/page but host sentinels declare {ht} — sentinel "
                f"would-have-touched mass would be mis-scaled"
            )
    return 1 if t is None else t


def _tier_col(table, n_rows, code):
    """Tier-code column for one table: entries past the valid prefix become
    ``TIER_INVALID``. This is the SINGLE enforcement point that keeps stale
    ``(slot, tier_code)`` rows — including rows whose slot would alias row 0
    of an empty codec class's dummy buffer — out of the fused kernel: the
    kernel contributes nothing for a row whose tier code matches no grid
    step, regardless of the slot value riding next to it."""
    mp = table.shape[1]
    valid = jnp.arange(mp, dtype=jnp.int32)[None] < n_rows[:, None]
    return jnp.where(valid, code, TIER_INVALID).astype(jnp.int32)


def _class_operands(sel, t, kv, dummy_dtype, last_dim):
    """One codec class's kernel operands + per-pool global-row offsets.

    Class-major layout (all same-class pools alias ONE buffer object —
    identity-checked): the shared buffer passes straight through with zero
    offsets, zero copies, at any pool count. Single standalone pool: also
    copy-free. Multiple standalone buffers: the legacy concat path, kept
    for back-compat and as the equivalence oracle's input layout; its
    copied bytes are counted in ``_COPY_BYTES`` (the baseline guard pins
    the serving layout to 0). Mixing shared and standalone buffers within
    a class is ambiguous (offsets would double-count pages) and raises."""
    global _COPY_BYTES
    if not sel:
        pay = jnp.zeros((1, t, kv, last_dim), dummy_dtype)
        sc = jnp.ones((1, t, kv), jnp.float32)
        return (pay, sc, pay, sc), []
    first = sel[0]
    if all(all(p[k] is first[k] for k in _CLASS_KEYS) for p in sel):
        return tuple(first[k] for k in _CLASS_KEYS), [0] * len(sel)
    for i in range(len(sel)):
        for j in range(i + 1, len(sel)):
            if any(sel[i][k] is sel[j][k] for k in _CLASS_KEYS):
                raise ValueError(
                    "same-class pools mix shared and standalone payload "
                    "buffers; either every pool of a codec class aliases "
                    "one class buffer (class-major layout) or none do"
                )
    offs, off = [], 0
    for p in sel:
        offs.append(off)
        off += int(p["k_pages"].shape[0])
    cat = tuple(jnp.concatenate([p[k] for p in sel]) for k in _CLASS_KEYS)
    _COPY_BYTES += sum(a.size * a.dtype.itemsize for a in cat)
    return cat, offs


def _check_class_bounds(uni_slot, uni_tier, rows8: int, rows4: int) -> None:
    """Eager-path guard: every VALID unified-table row must address a real
    class-buffer row. Stale rows are already ``TIER_INVALID`` (see
    ``_tier_col``) and exempt — notably an empty class's 1-row dummy buffer
    is unaddressable because no pool of that class exists to emit its tier
    code. Slot values are data, so this cannot run under tracing; eager
    callers (tests, benchmarks) get the hard check."""
    if isinstance(uni_slot, jax.core.Tracer) or isinstance(uni_tier, jax.core.Tracer):
        return
    slot = np.asarray(uni_slot)
    tier = np.asarray(uni_tier)
    for code, rows, cls in ((TIER_INT8, rows8, "int8"), (TIER_INT4, rows4, "int4")):
        sel = tier == code
        if sel.any():
            s = slot[sel]
            if int(s.min()) < 0 or int(s.max()) >= rows:
                raise IndexError(
                    f"unified table addresses {cls} class row "
                    f"{int(s.min())}..{int(s.max())} outside the class "
                    f"buffer's {rows} rows (stale slot with a live tier code?)"
                )


def _unified_operands(q, pools, recent_k, host):
    """Assemble the megakernel's operands from N tier pools: two codec-class
    payload buffers plus the unified page table.

    Class-major layout: same-class pools share one class buffer (identity-
    aliased arrays) and their tables already hold global class-buffer rows,
    so this reduces to pure table assembly — zero payload copies at any
    tier count. Legacy standalone per-pool buffers still concatenate (the
    counted back-compat path, see ``_class_operands``). Host sentinel rows
    index the summary buffer. Returns the kernel operands plus the
    {name: (col_lo, col_hi)} slot layout used to slice per-pool hotness
    back out of the unified mass."""
    b = q.shape[0]
    hd = q.shape[-1]
    kv = recent_k.shape[2]
    names = sorted(pools)
    t = _validated_page_tokens(pools, host)

    by_bits = {
        bits: [n for n in names if int(pools[n]["bits"]) == bits] for bits in (8, 4)
    }
    ops8, offs8 = _class_operands([pools[n] for n in by_bits[8]], t, kv, jnp.int8, hd)
    ops4, offs4 = _class_operands(
        [pools[n] for n in by_bits[4]], t, kv, jnp.uint8, hd // 2
    )
    base = dict(zip(by_bits[8], offs8))
    base.update(zip(by_bits[4], offs4))

    slot_cols, tier_cols = [], []
    layout: Dict[str, Tuple[int, int]] = {}
    col = 0
    for n in names:
        p = pools[n]
        mp = p["page_table"].shape[1]
        code = TIER_INT8 if int(p["bits"]) == 8 else TIER_INT4
        slot_cols.append(p["page_table"].astype(jnp.int32) + base[n])
        tier_cols.append(_tier_col(p["page_table"], p["n_pages"], code))
        layout[n] = (col, col + mp)
        col += mp
    if host is not None:
        mp = host["table"].shape[1]
        slot_cols.append(host["table"].astype(jnp.int32))
        tier_cols.append(_tier_col(host["table"], host["n"], TIER_HOST))
        layout["host"] = (col, col + mp)
        col += mp
        summary = host["summary"].astype(jnp.float32)
    else:
        summary = jnp.zeros((1, kv, hd), jnp.float32)

    if col == 0:  # no pools, no host rows: recent-window-only launch
        uni_slot = jnp.zeros((b, 1), jnp.int32)
        uni_tier = jnp.full((b, 1), TIER_INVALID, jnp.int32)
    else:
        uni_slot = jnp.concatenate(slot_cols, axis=1)
        uni_tier = jnp.concatenate(tier_cols, axis=1)

    k8, s8k, v8, s8v = ops8
    k4, s4k, v4, s4v = ops4
    _check_class_bounds(uni_slot, uni_tier, int(k8.shape[0]), int(k4.shape[0]))
    return (k8, s8k, v8, s8v, k4, s4k, v4, s4v, summary, uni_slot, uni_tier, t, layout)


def _fused_path(q, pools, recent_k, recent_v, recent_len, host, with_telemetry):
    b = q.shape[0]
    rlen = jnp.broadcast_to(jnp.asarray(recent_len, jnp.int32), (b,))
    if _USE_PALLAS:
        (k8, s8k, v8, s8v, k4, s4k, v4, s4v, summary,
         uni_slot, uni_tier, t, layout) = _unified_operands(q, pools, recent_k, host)
        _count_launch()
        # ``t`` is the launch's single validated page-tokens value — the
        # sentinel mass multiplier and the device pools' page shape agree
        # by construction (``_validated_page_tokens``).
        out, m, l, mass, base = fused_attn_kernel(
            q, k8, s8k, v8, s8v, k4, s4k, v4, s4v, summary,
            recent_k, recent_v, uni_slot, uni_tier, rlen, page_tokens=t,
        )
        if not with_telemetry:
            return out
        hot = {
            name: page_hotness(mass[:, lo:hi], base[:, lo:hi], m, l)
            for name, (lo, hi) in layout.items()
        }
        return out, hot
    _validated_page_tokens(pools, host)  # same contract as the kernel path
    out, m, l, masses = _ref.fused_tiered_attention(
        q, pools, recent_k, recent_v, rlen, host=host
    )
    if not with_telemetry:
        return out
    hot = {name: page_hotness(ms, bs, m, l) for name, (ms, bs) in masses.items()}
    return out, hot


def tiered_decode_attention(
    q: Array,  # [B, H, hd]
    pools: Dict[str, Dict[str, Array]],
    recent_k: Array,  # [B, R, KV, hd]
    recent_v: Array,
    recent_len,
    cfg=None,
    with_telemetry: bool = False,
    host: Optional[Dict[str, Array]] = None,
):
    """Attention over tiered compressed KV pools + dense recent window.

    Returns out [B, H, hd] f32; with_telemetry=True also returns
    {tier: normalized page hotness [B, MP]} (softmax mass per page). When
    ``host`` is given (dict with ``summary`` [Hs, KV, hd], ``table``
    [B, MPh], ``n`` [B], ``page_tokens``), the hotness dict additionally
    carries "host": the normalized would-have-touched mass of host-resident
    pages — telemetry for the prefetch predictor, never part of the output.

    Fused mode (default): one Pallas launch per call, O(1) in tier count.
    ``use_fused(False)``: one launch per pool + post-hoc merge (the
    equivalence oracle; outputs/hotness match to fp32 tolerance).
    """
    if _USE_FUSED:
        return _fused_path(q, pools, recent_k, recent_v, recent_len, host, with_telemetry)
    parts = [_ref.dense_recent_attention(q, recent_k, recent_v, recent_len)]
    masses = {}
    for name in sorted(pools):
        out_u, m, l, mass, base = _pool_partials(q, pools[name])
        parts.append((out_u, m, l))
        masses[name] = (mass, base)
    out = _ref.merge_partials(parts)
    if not with_telemetry:
        return out
    # Global (m_tot, l_tot) for exact normalization of page masses.
    m_tot = jnp.max(jnp.stack([p[1] for p in parts]), axis=0)  # [B,H]
    l_tot = sum(p[2] * jnp.exp(p[1] - m_tot) for p in parts)  # [B,H]
    if host is not None:
        masses["host"] = _ref.host_page_mass(
            q, host["summary"], host["table"], host["n"], host["page_tokens"]
        )
    hot = {
        name: page_hotness(mass, base, m_tot, l_tot)
        for name, (mass, base) in masses.items()
    }
    return out, hot


def page_hotness(mass: Array, base: Array, m_tot: Array, l_tot: Array) -> Array:
    """Rebase per-page local-max masses to the merged global softmax.

    Heads were collapsed in the mass telemetry; normalize by the summed
    head partition function at the global max base."""
    z = jnp.sum(l_tot * jnp.exp(m_tot - jnp.max(m_tot, -1, keepdims=True)), -1)
    mref = jnp.max(m_tot, -1)
    return mass * jnp.exp(base - mref[:, None]) / jnp.maximum(z[:, None], 1e-30)
