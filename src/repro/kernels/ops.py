"""jit'd public wrappers for the Pallas kernels.

``tiered_decode_attention`` is the serving hot path: one paged-attention
kernel launch per tier pool (each pool has its own codec width), one dense
pass over the recent uncompressed window, and an exact logsumexp merge of
the flash partials. ``page_hotness`` turns the kernels' per-page mass
telemetry into the normalized hotness the TierScape manager consumes.

``use_pallas`` toggles kernel vs pure-jnp oracle (ref.py); kernels run in
interpret mode on CPU (the TPU lowering is exercised by the dry-run).
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dequant_page import dequant_pages as dequant_pages_kernel
from repro.kernels.paged_attention import paged_quant_attention as paged_attn_kernel
from repro.kernels.quant_page import quant_pages as quant_pages_kernel
from repro.kernels.transcode_page import transcode_pages as transcode_pages_kernel

Array = jax.Array

_USE_PALLAS = True


def use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def quant_pages(pages: Array, bits: int) -> Tuple[Array, Array]:
    if _USE_PALLAS:
        out = quant_pages_kernel(pages, bits)
        return out[0], out[1]
    return _ref.quant_kv_page(pages, bits)


def dequant_pages(payload: Array, scales: Array, bits: int, out_dtype=jnp.bfloat16) -> Array:
    if _USE_PALLAS:
        return dequant_pages_kernel(payload, scales, bits, out_dtype)
    return _ref.dequant_kv_page(payload, scales, bits).astype(out_dtype)


def transcode_pages(
    payload: Array, scales: Array, src_bits: int, dst_bits: int
) -> Tuple[Array, Array]:
    """Fused tier-to-tier requantization of a [P, ...] page batch — the
    batched migration executor's single dispatch per transcoding cohort."""
    if src_bits == dst_bits:
        return payload, scales
    if _USE_PALLAS:
        out = transcode_pages_kernel(payload, scales, src_bits, dst_bits)
        return out[0], out[1]
    return _ref.transcode_kv_page(payload, scales, src_bits, dst_bits)


def _pool_partials(q: Array, pool: Dict[str, Array]):
    fn = paged_attn_kernel if _USE_PALLAS else _ref.paged_quant_attention
    return fn(
        q,
        pool["k_pages"],
        pool["k_scales"],
        pool["v_pages"],
        pool["v_scales"],
        pool["page_table"],
        pool["n_pages"],
        pool["bits"],
    )


def tiered_decode_attention(
    q: Array,  # [B, H, hd]
    pools: Dict[str, Dict[str, Array]],
    recent_k: Array,  # [B, R, KV, hd]
    recent_v: Array,
    recent_len,
    cfg=None,
    with_telemetry: bool = False,
):
    """Attention over tiered compressed KV pools + dense recent window.

    Returns out [B, H, hd] f32; with_telemetry=True also returns
    {tier: normalized page hotness [B, MP]} (softmax mass per page).
    """
    parts = [_ref.dense_recent_attention(q, recent_k, recent_v, recent_len)]
    masses = {}
    for name in sorted(pools):
        out_u, m, l, mass, base = _pool_partials(q, pools[name])
        parts.append((out_u, m, l))
        masses[name] = (mass, base)
    out = _ref.merge_partials(parts)
    if not with_telemetry:
        return out
    # Global (m_tot, l_tot) for exact normalization of page masses.
    m_tot = jnp.max(jnp.stack([p[1] for p in parts]), axis=0)  # [B,H]
    l_tot = sum(p[2] * jnp.exp(p[1] - m_tot) for p in parts)  # [B,H]
    # Heads were collapsed in the mass telemetry; normalize by the summed
    # head partition function at the global max base.
    z = jnp.sum(l_tot * jnp.exp(m_tot - jnp.max(m_tot, -1, keepdims=True)), -1)
    mref = jnp.max(m_tot, -1)  # [B]
    hot = {
        name: mass * jnp.exp(base - mref[:, None]) / jnp.maximum(z[:, None], 1e-30)
        for name, (mass, base) in masses.items()
    }
    return out, hot


def page_hotness(mass: Array, base: Array, m_tot: Array, l_tot: Array) -> Array:
    """Rebase per-page local-max masses to the merged global softmax."""
    z = jnp.sum(l_tot * jnp.exp(m_tot - jnp.max(m_tot, -1, keepdims=True)), -1)
    mref = jnp.max(m_tot, -1)
    return mass * jnp.exp(base - mref[:, None]) / jnp.maximum(z[:, None], 1e-30)
