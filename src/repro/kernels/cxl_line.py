"""Pallas TPU kernels: cxl_hw line codec (inline hardware compression model).

Grid over pages, one program per [T, KV, hd] page. Encode emits the dense
int8 payload + per-(token, kv-head) scales — exactly the int8 quant — plus
the per-hardware-line stored width (4 or 8 bits) the inline compressor
achieves, reduced over CXL_LINE_ELEMS-codeword lines on the 128-lane axis.
Decode is the plain int8 dequant: the controller decompresses inline, so the
VPU always sees the dense view. Oracles: kernels.ref.cxl_encode_kv_page /
cxl_decode_kv_page.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packing import QMAX
from repro.kernels.ref import CXL_LINE_ELEMS, CXL_NARROW_QMAX


def _cxl_encode_kernel(page_ref, payload_ref, scale_ref, bits_ref):
    x = page_ref[...].astype(jnp.float32)  # [1, T, KV, hd]
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax == 0.0, 1.0, amax / QMAX[8])
    q = jnp.clip(jnp.round(x / scale[..., None]), -QMAX[8], QMAX[8])
    payload_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale
    _, t, kv, hd = q.shape
    lines = q.astype(jnp.int32).reshape(1, t, kv, hd // CXL_LINE_ELEMS, CXL_LINE_ELEMS)
    narrow = jnp.max(jnp.abs(lines), axis=-1) <= CXL_NARROW_QMAX
    bits_ref[...] = jnp.where(narrow, 4, 8).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cxl_encode_pages(pages: jax.Array, interpret: bool = True):
    """pages [P, T, KV, hd] bf16 -> (payload int8, scales [P, T, KV] f32,
    line_bits [P, T, KV, hd // CXL_LINE_ELEMS] int32)."""
    p, t, kv, hd = pages.shape
    n_lines = hd // CXL_LINE_ELEMS
    return pl.pallas_call(
        _cxl_encode_kernel,
        grid=(p,),
        in_specs=[pl.BlockSpec((1, t, kv, hd), lambda i: (i, 0, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, t, kv, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, kv, n_lines), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, t, kv, hd), jnp.int8),
            jax.ShapeDtypeStruct((p, t, kv), jnp.float32),
            jax.ShapeDtypeStruct((p, t, kv, n_lines), jnp.int32),
        ],
        interpret=interpret,
    )(pages)


def _cxl_decode_kernel(payload_ref, scale_ref, out_ref):
    q = payload_ref[...].astype(jnp.float32)  # [1, T, KV, hd]
    out_ref[...] = q * scale_ref[...][..., None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cxl_decode_pages(payload: jax.Array, scales: jax.Array, interpret: bool = True):
    """(payload [P, T, KV, hd] int8, scales [P, T, KV]) -> pages f32."""
    p, t, kv, hd = payload.shape
    return pl.pallas_call(
        _cxl_decode_kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, t, kv, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, kv, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, t, kv, hd), jnp.float32),
        interpret=interpret,
    )(payload, scales)
