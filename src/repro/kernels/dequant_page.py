"""Pallas TPU kernel: KV-page dequantization (the tier *decompress* / fault
path). Inverse of ``quant_page``; one program per page."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packing import unpack_int4


def _dequant_kernel(payload_ref, scale_ref, out_ref, *, bits: int, out_dtype):
    scale = scale_ref[...]  # [1, T, KV]
    if bits == 8:
        q = payload_ref[...].astype(jnp.float32)
    else:
        q = unpack_int4(payload_ref[...])
    out_ref[...] = (q * scale[..., None]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bits", "out_dtype", "interpret"))
def dequant_pages(
    payload: jax.Array,
    scales: jax.Array,
    bits: int,
    out_dtype=jnp.bfloat16,
    interpret: bool = True,
):
    """payload [P, T, KV, hd(|//2)], scales [P, T, KV] -> pages [P, T, KV, hd]."""
    p, t, kv, hdp = payload.shape
    hd = hdp if bits == 8 else hdp * 2
    return pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits, out_dtype=out_dtype),
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, t, kv, hdp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, kv, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, t, kv, hd), out_dtype),
        interpret=interpret,
    )(payload, scales)
