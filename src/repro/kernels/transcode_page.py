"""Pallas TPU kernel: fused KV-page transcode (tier-to-tier requantization).

The migration hot path: moving a page between an int8 tier and an int4 tier
requires requantizing payload+scales. The naive path is two kernels and a
dense f32 round-trip through HBM (dequant_page -> quant_page); this kernel
fuses both so each page is read once (compressed), requantized entirely in
VMEM, and written once (compressed) — the software analogue of the paper's
"hardware-rate bulk (de)compression" requirement for compressed-tier
migrations.

Grid over pages; each program transcodes one [T, KV, hd] page. The dequant
multiply, absmax reduce and requant divide all vectorize on the VPU with hd
on the 128-lane axis. int4 payloads pack adjacent hd pairs into one uint8
(lo nibble = even index), matching quant_page/dequant_page.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packing import QMAX, pack_int4, unpack_int4


def _transcode_kernel(payload_ref, scale_ref, out_pay_ref, out_scale_ref,
                      *, src_bits: int, dst_bits: int):
    scale = scale_ref[...]  # [1, T, KV]
    if src_bits == 8:
        q = payload_ref[...].astype(jnp.float32)
    else:
        q = unpack_int4(payload_ref[...])
    x = q * scale[..., None]  # dense page, VMEM-resident only
    amax = jnp.max(jnp.abs(x), axis=-1)
    new_scale = jnp.where(amax == 0.0, 1.0, amax / QMAX[dst_bits])
    qn = jnp.clip(jnp.round(x / new_scale[..., None]), -QMAX[dst_bits], QMAX[dst_bits])
    if dst_bits == 8:
        out_pay_ref[...] = qn.astype(jnp.int8)
    else:
        out_pay_ref[...] = pack_int4(qn)
    out_scale_ref[...] = new_scale


@functools.partial(jax.jit, static_argnames=("src_bits", "dst_bits", "interpret"))
def transcode_pages(
    payload: jax.Array,
    scales: jax.Array,
    src_bits: int,
    dst_bits: int,
    interpret: bool = True,
):
    """payload [P, T, KV, hd(|//2)], scales [P, T, KV] ->
    (payload' [P, T, KV, hd'(|//2)], scales' [P, T, KV]) at dst_bits."""
    if src_bits == dst_bits:
        return payload, scales
    p, t, kv, hdp = payload.shape
    hd = hdp if src_bits == 8 else hdp * 2
    hd_out = hd if dst_bits == 8 else hd // 2
    out_dtype = jnp.int8 if dst_bits == 8 else jnp.uint8
    return pl.pallas_call(
        functools.partial(_transcode_kernel, src_bits=src_bits, dst_bits=dst_bits),
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, t, kv, hdp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, kv, hd_out), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, t, kv, hd_out), out_dtype),
            jax.ShapeDtypeStruct((p, t, kv), jnp.float32),
        ],
        interpret=interpret,
    )(payload, scales)
