"""Pallas TPU kernels: paged decode attention over quantized tier pools.

This is the paper's warm-data access path made cheap: instead of fault-and-
decompress (the 2-Tier cost model), the decode step *reads the compressed
pool directly* — pages are DMA'd to VMEM by the pipeline (page table drives
the BlockSpec index_map via scalar prefetch), dequantized in registers, and
consumed by an online-softmax accumulation. Per-page softmax mass is emitted
as exact hotness telemetry for the TierScape manager.

Two kernels live here:

``paged_quant_attention`` — flash partials over ONE pool. Mixed tiers run it
once per tier pool and merge the partials (exact logsumexp) together with
the dense recent-window partial post-hoc — the per-pool oracle path in
``ops.tiered_decode_attention``; one launch per tier.

``fused_tiered_attention`` — the single-launch megakernel. One unified page
table whose rows carry ``(pool_slot, tier_code)`` walks ALL compressed pages
of a sequence regardless of codec: scalar-prefetched tier codes select the
int8/int4 dequant path in-kernel, host-resident pages appear as sentinel
rows that fetch only a tiny per-page key centroid (no payload) and emit a
"would-have-touched" softmax mass as telemetry, the dense recent window runs
as the final grid step of the same launch, and the (acc, m, l) logsumexp
merge happens in VMEM scratch — one launch per decode step, O(1) in tier
count.

Grids: (batch, pages[, +1]). The page axis is sequential ("arbitrary"):
VMEM scratch carries (acc, m, l) across pages of one sequence; outputs are
written at the last page step. Invalid table slots are skipped with
@pl.when, and their index_maps clamp/gate so the pipeline still has a legal
block to fetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.packing import unpack_int4 as _unpack_int4

# jax.sharding-style API drift: CompilerParams was TPUCompilerParams in 0.4.x.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30

# Tier codes carried by the unified page table (``fused_tiered_attention``).
# Rows are (pool_slot, tier_code): the code picks the in-kernel dequant path
# (int8 vs int4 group buffer), marks host sentinels (summary fetch only, no
# payload), or invalidates the row entirely.
TIER_INT8 = 0
TIER_INT4 = 1
TIER_HOST = 2
TIER_INVALID = -1


def _paged_attn_kernel(
    # scalar-prefetch operands
    table_ref,  # [B, MP] int32
    npages_ref,  # [B] int32
    # array operands (blocked)
    q_ref,  # [1, H, hd]
    kp_ref,  # [1, T, KV, hd(|//2)]
    ks_ref,  # [1, T, KV]
    vp_ref,
    vs_ref,
    # outputs
    out_ref,  # [1, H, hd] f32 (unnormalized)
    m_ref,  # [1, H] f32
    l_ref,  # [1, H] f32
    mass_ref,  # [1, 1] f32 per (b, p): page softmax mass at its local base
    base_ref,  # [1, 1] f32 per (b, p): the local base (page max score)
    # scratch
    acc_ref,  # [KV, G, hd] f32
    run_m_ref,  # [KV, G] f32
    run_l_ref,  # [KV, G] f32
    *,
    bits: int,
    kv: int,
    group: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    mp = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        run_m_ref[...] = jnp.full_like(run_m_ref, NEG_INF)
        run_l_ref[...] = jnp.zeros_like(run_l_ref)

    valid = p < npages_ref[b]

    @pl.when(valid)
    def _accumulate():
        hd = acc_ref.shape[-1]
        q = q_ref[0].astype(jnp.float32).reshape(kv, group, hd) / (hd**0.5)
        if bits == 8:
            k = kp_ref[0].astype(jnp.float32)
            v = vp_ref[0].astype(jnp.float32)
        else:
            k = _unpack_int4(kp_ref[0].astype(jnp.int32))
            v = _unpack_int4(vp_ref[0].astype(jnp.int32))
        k = k * ks_ref[0][..., None]  # [T, KV, hd]
        v = v * vs_ref[0][..., None]

        scores = jnp.einsum("kgh,tkh->kgt", q, k)  # [KV, G, T]
        page_max = jnp.max(scores, axis=-1)  # [KV, G]
        m_old = run_m_ref[...]
        m_new = jnp.maximum(m_old, page_max)
        alpha = jnp.exp(m_old - m_new)  # rescale old accumulators
        e = jnp.exp(scores - m_new[..., None])  # [KV, G, T]
        l_new = run_l_ref[...] * alpha + jnp.sum(e, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum("kgt,tkh->kgh", e, v)
        run_m_ref[...] = m_new
        run_l_ref[...] = l_new
        # Exact per-page attention-mass telemetry at the page's local base
        # (rebased to the merged global max by ops.page_hotness).
        pbase = jnp.max(page_max)
        e_loc = jnp.exp(scores - pbase)
        mass_ref[0, 0] = jnp.sum(e_loc)
        base_ref[0, 0] = pbase

    @pl.when(jnp.logical_not(valid))
    def _skip():
        mass_ref[0, 0] = 0.0
        base_ref[0, 0] = NEG_INF

    @pl.when(p == mp - 1)
    def _finalize():
        hd = acc_ref.shape[-1]
        out_ref[0] = acc_ref[...].reshape(kv * group, hd)
        # Empty pools report m=0 (matching the ref's m_safe convention).
        m_fin = jnp.where(run_l_ref[...] > 0.0, run_m_ref[...], 0.0)
        m_ref[0] = m_fin.reshape(kv * group)
        l_ref[0] = run_l_ref[...].reshape(kv * group)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def paged_quant_attention(
    q: jax.Array,  # [B, H, hd]
    k_pages: jax.Array,  # [P, T, KV, hd(|//2)]
    k_scales: jax.Array,  # [P, T, KV]
    v_pages: jax.Array,
    v_scales: jax.Array,
    page_table: jax.Array,  # [B, MP] int32
    n_pages: jax.Array,  # [B] int32
    bits: int,
    interpret: bool = True,
):
    """Flash partials over one pool: (out_unnorm, m, l, page_mass)."""
    b, h, hd = q.shape
    pp, t, kv, hdp = k_pages.shape
    mp = page_table.shape[1]
    group = h // kv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, tab, np_: (bi, 0, 0)),
            pl.BlockSpec((1, t, kv, hdp), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0)),
            pl.BlockSpec((1, t, kv, hdp), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, tab, np_: (bi, 0, 0)),
            pl.BlockSpec((1, h), lambda bi, pi, tab, np_: (bi, 0)),
            pl.BlockSpec((1, h), lambda bi, pi, tab, np_: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi, pi, tab, np_: (bi, pi)),
            pl.BlockSpec((1, 1), lambda bi, pi, tab, np_: (bi, pi)),
        ],
        scratch_shapes=[
            pltpu.VMEM((kv, group, hd), jnp.float32),
            pltpu.VMEM((kv, group), jnp.float32),
            pltpu.VMEM((kv, group), jnp.float32),
        ],
    )
    out, m, l, mass, base = pl.pallas_call(
        functools.partial(_paged_attn_kernel, bits=bits, kv=kv, group=group),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, mp), jnp.float32),
            jax.ShapeDtypeStruct((b, mp), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(page_table, n_pages, q, k_pages, k_scales, v_pages, v_scales)
    return out, m, l, mass, base


# ---------------------------------------------------------------------------
# Single-launch multi-tier megakernel
# ---------------------------------------------------------------------------


def _fused_attn_kernel(
    # scalar-prefetch operands
    slot_ref,  # [B, MS] int32 pool slot within its tier-class buffer
    tier_ref,  # [B, MS] int32 TIER_* code per unified slot
    rlen_ref,  # [B] int32 dense recent-window fill
    # array operands (blocked)
    q_ref,  # [1, H, hd]
    k8_ref,  # [1, T, KV, hd] int8 group buffer
    s8k_ref,  # [1, T, KV]
    v8_ref,
    s8v_ref,
    k4_ref,  # [1, T, KV, hd//2] int4 group buffer
    s4k_ref,
    v4_ref,
    s4v_ref,
    sum_ref,  # [1, KV, hd] f32 host-page key centroid (sentinel rows)
    rk_ref,  # [1, R, KV, hd] dense recent window
    rv_ref,
    # outputs
    out_ref,  # [1, H, hd] f32 (NORMALIZED — merge happens in-kernel)
    m_ref,  # [1, H] f32 merged running max
    l_ref,  # [1, H] f32 merged partition mass
    mass_ref,  # [1, 1] f32 per (b, slot): softmax mass at its local base
    base_ref,  # [1, 1] f32 per (b, slot): the local base
    # scratch
    acc_ref,  # [KV, G, hd] f32
    run_m_ref,  # [KV, G] f32
    run_l_ref,  # [KV, G] f32
    *,
    kv: int,
    group: int,
    page_tokens: int,
    ms: int,
):
    """One grid step = one unified-table slot; the final step (p == ms) is
    the dense recent window + in-VMEM finalization. Pool rows accumulate
    (acc, m, l) online exactly like the per-pool kernel; host sentinel rows
    touch no payload — they score the page's key centroid against q and
    emit ``page_tokens * sum(exp(s - max s))`` as the would-have-touched
    mass (telemetry only, never accumulated)."""
    b = pl.program_id(0)
    p = pl.program_id(1)
    hd = acc_ref.shape[-1]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        run_m_ref[...] = jnp.full_like(run_m_ref, NEG_INF)
        run_l_ref[...] = jnp.zeros_like(run_l_ref)

    q = q_ref[0].astype(jnp.float32).reshape(kv, group, hd) / (hd**0.5)
    tid = tier_ref[b, jnp.minimum(p, ms - 1)]

    def _accumulate(k, v):
        # Online-softmax update over one full page ([T, KV, hd] f32 k/v).
        scores = jnp.einsum("kgh,tkh->kgt", q, k)  # [KV, G, T]
        page_max = jnp.max(scores, axis=-1)
        m_old = run_m_ref[...]
        m_new = jnp.maximum(m_old, page_max)
        alpha = jnp.exp(m_old - m_new)
        e = jnp.exp(scores - m_new[..., None])
        run_l_ref[...] = run_l_ref[...] * alpha + jnp.sum(e, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum("kgt,tkh->kgh", e, v)
        run_m_ref[...] = m_new
        pbase = jnp.max(page_max)
        mass_ref[0, 0] = jnp.sum(jnp.exp(scores - pbase))
        base_ref[0, 0] = pbase

    @pl.when((p < ms) & (tid == TIER_INT8))
    def _pool8():
        k = k8_ref[0].astype(jnp.float32) * s8k_ref[0][..., None]
        v = v8_ref[0].astype(jnp.float32) * s8v_ref[0][..., None]
        _accumulate(k, v)

    @pl.when((p < ms) & (tid == TIER_INT4))
    def _pool4():
        k = _unpack_int4(k4_ref[0].astype(jnp.int32)) * s4k_ref[0][..., None]
        v = _unpack_int4(v4_ref[0].astype(jnp.int32)) * s4v_ref[0][..., None]
        _accumulate(k, v)

    @pl.when((p < ms) & (tid == TIER_HOST))
    def _host_sentinel():
        kbar = sum_ref[0].astype(jnp.float32)  # [KV, hd]
        s = jnp.einsum("kgh,kh->kg", q, kbar)  # [KV, G]
        pbase = jnp.max(s)
        mass_ref[0, 0] = page_tokens * jnp.sum(jnp.exp(s - pbase))
        base_ref[0, 0] = pbase

    @pl.when((p < ms) & (tid < 0))
    def _skip():
        mass_ref[0, 0] = 0.0
        base_ref[0, 0] = NEG_INF

    @pl.when(p == ms)
    def _recent_and_finalize():
        rk = rk_ref[0].astype(jnp.float32)  # [R, KV, hd]
        rv = rv_ref[0].astype(jnp.float32)
        r = rk.shape[0]
        scores = jnp.einsum("kgh,rkh->kgr", q, rk)  # [KV, G, R]
        valid = jax.lax.broadcasted_iota(jnp.int32, (1, 1, r), 2) < rlen_ref[b]
        scores = jnp.where(valid, scores, NEG_INF)
        page_max = jnp.max(scores, axis=-1)
        m_old = run_m_ref[...]
        m_new = jnp.maximum(m_old, page_max)
        # Safe shift: both the recent window (rlen may be 0) and the pools
        # (all-host / empty) can be vacuous, so NEG_INF never enters exp.
        shift = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        e = jnp.where(valid, jnp.exp(scores - shift[..., None]), 0.0)
        alpha = jnp.where(m_old > NEG_INF / 2, jnp.exp(m_old - shift), 0.0)
        l_new = run_l_ref[...] * alpha + jnp.sum(e, axis=-1)
        acc = acc_ref[...] * alpha[..., None] + jnp.einsum("kgt,tkh->kgh", e, rv)
        out_ref[0] = (acc / jnp.maximum(l_new, 1e-30)[..., None]).reshape(kv * group, hd)
        m_fin = jnp.where(l_new > 0.0, m_new, 0.0)
        m_ref[0] = m_fin.reshape(kv * group)
        l_ref[0] = l_new.reshape(kv * group)


@functools.partial(jax.jit, static_argnames=("page_tokens", "interpret"))
def fused_tiered_attention(
    q: jax.Array,  # [B, H, hd]
    k8: jax.Array,  # [P8, T, KV, hd] int8 (concat of all int8 pools)
    s8k: jax.Array,  # [P8, T, KV] f32
    v8: jax.Array,
    s8v: jax.Array,
    k4: jax.Array,  # [P4, T, KV, hd//2] uint8 (concat of all int4 pools)
    s4k: jax.Array,
    v4: jax.Array,
    s4v: jax.Array,
    host_summary: jax.Array,  # [Hs, KV, hd] f32 per-page key centroids
    recent_k: jax.Array,  # [B, R, KV, hd]
    recent_v: jax.Array,
    uni_slot: jax.Array,  # [B, MS] int32
    uni_tier: jax.Array,  # [B, MS] int32 TIER_* codes
    recent_len: jax.Array,  # [B] int32
    page_tokens: int,
    interpret: bool = True,
):
    """Single launch over every tier + host sentinels + the recent window.

    Returns (out [B,H,hd] NORMALIZED f32, m [B,H], l [B,H],
             mass [B,MS], base [B,MS]) where (m, l) are the fully merged
    logsumexp stats (for hotness normalization) and mass/base follow the
    unified-table slot layout (pool pages: exact page mass at its local
    base; host sentinels: would-have-touched mass; invalid: 0 / NEG_INF).
    """
    b, h, hd = q.shape
    t = k8.shape[1]
    kv = k8.shape[2]
    ms = uni_slot.shape[1]
    r = recent_k.shape[1]
    group = h // kv
    hd4 = k4.shape[-1]

    def _gated(code, ndim):
        # Fetch the row the table names only when this row's tier matches;
        # otherwise clamp to row 0 so the pipeline has a legal block.
        def index_map(bi, pi, slot, tier, rlen):
            pp = jnp.minimum(pi, ms - 1)
            row = jnp.where(tier[bi, pp] == code, slot[bi, pp], 0)
            return (row,) + (0,) * (ndim - 1)

        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, ms + 1),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, *_: (bi, 0, 0)),
            pl.BlockSpec((1, t, kv, hd), _gated(TIER_INT8, 4)),
            pl.BlockSpec((1, t, kv), _gated(TIER_INT8, 3)),
            pl.BlockSpec((1, t, kv, hd), _gated(TIER_INT8, 4)),
            pl.BlockSpec((1, t, kv), _gated(TIER_INT8, 3)),
            pl.BlockSpec((1, t, kv, hd4), _gated(TIER_INT4, 4)),
            pl.BlockSpec((1, t, kv), _gated(TIER_INT4, 3)),
            pl.BlockSpec((1, t, kv, hd4), _gated(TIER_INT4, 4)),
            pl.BlockSpec((1, t, kv), _gated(TIER_INT4, 3)),
            pl.BlockSpec((1, kv, hd), _gated(TIER_HOST, 3)),
            pl.BlockSpec((1, r, kv, hd), lambda bi, pi, *_: (bi, 0, 0, 0)),
            pl.BlockSpec((1, r, kv, hd), lambda bi, pi, *_: (bi, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, *_: (bi, 0, 0)),
            pl.BlockSpec((1, h), lambda bi, pi, *_: (bi, 0)),
            pl.BlockSpec((1, h), lambda bi, pi, *_: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi, pi, *_: (bi, jnp.minimum(pi, ms - 1))),
            pl.BlockSpec((1, 1), lambda bi, pi, *_: (bi, jnp.minimum(pi, ms - 1))),
        ],
        scratch_shapes=[
            pltpu.VMEM((kv, group, hd), jnp.float32),
            pltpu.VMEM((kv, group), jnp.float32),
            pltpu.VMEM((kv, group), jnp.float32),
        ],
    )
    out, m, l, mass, base = pl.pallas_call(
        functools.partial(
            _fused_attn_kernel, kv=kv, group=group, page_tokens=page_tokens, ms=ms
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, ms), jnp.float32),
            jax.ShapeDtypeStruct((b, ms), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(uni_slot, uni_tier, recent_len, q, k8, s8k, v8, s8v, k4, s4k, v4, s4v,
      host_summary, recent_k, recent_v)
    return out, m, l, mass, base
