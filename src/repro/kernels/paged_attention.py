"""Pallas TPU kernel: paged decode attention over ONE quantized tier pool.

This is the paper's warm-data access path made cheap: instead of fault-and-
decompress (the 2-Tier cost model), the decode step *reads the compressed
pool directly* — pages are DMA'd to VMEM by the pipeline (page table drives
the BlockSpec index_map via scalar prefetch), dequantized in registers, and
consumed by an online-softmax accumulation. Per-page softmax mass is emitted
as exact hotness telemetry for the TierScape manager.

Mixed tiers are handled by running this kernel once per tier pool and
merging the flash partials (exact logsumexp merge) together with the dense
recent-window partial — see ``ops.tiered_decode_attention``.

Grid: (batch, max_pages). The page axis is sequential ("arbitrary"): VMEM
scratch carries (acc, m, l) across pages of one sequence; outputs are
written at the last page step. Invalid table slots (>= n_pages[b]) are
skipped with @pl.when, and their index_map clamps to page 0 so the pipeline
still has a legal block to fetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax.sharding-style API drift: CompilerParams was TPUCompilerParams in 0.4.x.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.kernels.packing import unpack_int4 as _unpack_int4

NEG_INF = -1e30


def _paged_attn_kernel(
    # scalar-prefetch operands
    table_ref,  # [B, MP] int32
    npages_ref,  # [B] int32
    # array operands (blocked)
    q_ref,  # [1, H, hd]
    kp_ref,  # [1, T, KV, hd(|//2)]
    ks_ref,  # [1, T, KV]
    vp_ref,
    vs_ref,
    # outputs
    out_ref,  # [1, H, hd] f32 (unnormalized)
    m_ref,  # [1, H] f32
    l_ref,  # [1, H] f32
    mass_ref,  # [1, 1] f32 per (b, p): page softmax mass at its local base
    base_ref,  # [1, 1] f32 per (b, p): the local base (page max score)
    # scratch
    acc_ref,  # [KV, G, hd] f32
    run_m_ref,  # [KV, G] f32
    run_l_ref,  # [KV, G] f32
    *,
    bits: int,
    kv: int,
    group: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    mp = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        run_m_ref[...] = jnp.full_like(run_m_ref, NEG_INF)
        run_l_ref[...] = jnp.zeros_like(run_l_ref)

    valid = p < npages_ref[b]

    @pl.when(valid)
    def _accumulate():
        hd = acc_ref.shape[-1]
        q = q_ref[0].astype(jnp.float32).reshape(kv, group, hd) / (hd**0.5)
        if bits == 8:
            k = kp_ref[0].astype(jnp.float32)
            v = vp_ref[0].astype(jnp.float32)
        else:
            k = _unpack_int4(kp_ref[0].astype(jnp.int32))
            v = _unpack_int4(vp_ref[0].astype(jnp.int32))
        k = k * ks_ref[0][..., None]  # [T, KV, hd]
        v = v * vs_ref[0][..., None]

        scores = jnp.einsum("kgh,tkh->kgt", q, k)  # [KV, G, T]
        page_max = jnp.max(scores, axis=-1)  # [KV, G]
        m_old = run_m_ref[...]
        m_new = jnp.maximum(m_old, page_max)
        alpha = jnp.exp(m_old - m_new)  # rescale old accumulators
        e = jnp.exp(scores - m_new[..., None])  # [KV, G, T]
        l_new = run_l_ref[...] * alpha + jnp.sum(e, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum("kgt,tkh->kgh", e, v)
        run_m_ref[...] = m_new
        run_l_ref[...] = l_new
        # Exact per-page attention-mass telemetry at the page's local base
        # (rebased to the merged global max by ops.page_hotness).
        pbase = jnp.max(page_max)
        e_loc = jnp.exp(scores - pbase)
        mass_ref[0, 0] = jnp.sum(e_loc)
        base_ref[0, 0] = pbase

    @pl.when(jnp.logical_not(valid))
    def _skip():
        mass_ref[0, 0] = 0.0
        base_ref[0, 0] = NEG_INF

    @pl.when(p == mp - 1)
    def _finalize():
        hd = acc_ref.shape[-1]
        out_ref[0] = acc_ref[...].reshape(kv * group, hd)
        # Empty pools report m=0 (matching the ref's m_safe convention).
        m_fin = jnp.where(run_l_ref[...] > 0.0, run_m_ref[...], 0.0)
        m_ref[0] = m_fin.reshape(kv * group)
        l_ref[0] = run_l_ref[...].reshape(kv * group)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def paged_quant_attention(
    q: jax.Array,  # [B, H, hd]
    k_pages: jax.Array,  # [P, T, KV, hd(|//2)]
    k_scales: jax.Array,  # [P, T, KV]
    v_pages: jax.Array,
    v_scales: jax.Array,
    page_table: jax.Array,  # [B, MP] int32
    n_pages: jax.Array,  # [B] int32
    bits: int,
    interpret: bool = True,
):
    """Flash partials over one pool: (out_unnorm, m, l, page_mass)."""
    b, h, hd = q.shape
    pp, t, kv, hdp = k_pages.shape
    mp = page_table.shape[1]
    group = h // kv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, tab, np_: (bi, 0, 0)),
            pl.BlockSpec((1, t, kv, hdp), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0)),
            pl.BlockSpec((1, t, kv, hdp), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, t, kv), lambda bi, pi, tab, np_: (tab[bi, pi], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, tab, np_: (bi, 0, 0)),
            pl.BlockSpec((1, h), lambda bi, pi, tab, np_: (bi, 0)),
            pl.BlockSpec((1, h), lambda bi, pi, tab, np_: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi, pi, tab, np_: (bi, pi)),
            pl.BlockSpec((1, 1), lambda bi, pi, tab, np_: (bi, pi)),
        ],
        scratch_shapes=[
            pltpu.VMEM((kv, group, hd), jnp.float32),
            pltpu.VMEM((kv, group), jnp.float32),
            pltpu.VMEM((kv, group), jnp.float32),
        ],
    )
    out, m, l, mass, base = pl.pallas_call(
        functools.partial(_paged_attn_kernel, bits=bits, kv=kv, group=group),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, mp), jnp.float32),
            jax.ShapeDtypeStruct((b, mp), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(page_table, n_pages, q, k_pages, k_scales, v_pages, v_scales)
    return out, m, l, mass, base
