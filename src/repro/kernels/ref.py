"""Pure-jnp oracles for every Pallas kernel in this package.

These define the exact semantics the kernels must reproduce (tests sweep
shapes/dtypes and assert_allclose kernel-vs-ref). They are also the portable
fallback path used when Pallas is unavailable.

KV-page quantization layout (serving hot path):
  page:    [T, KV, hd]  bf16 source (T tokens per page)
  int8:    payload [T, KV, hd] int8, scales [T, KV] f32 (absmax over hd)
  int4:    payload [T, KV, hd//2] uint8 (lo nibble = even idx), scales as int8

Paged attention partials follow flash-decoding: each tier's pool produces
(out_unnorm, m, l, page_mass); partials merge exactly via logsumexp. The
per-page attention mass is the paper's telemetry signal (exact hotness).
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.packing import QMAX, pack_int4, unpack_int4

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# KV-page quant / dequant
# ---------------------------------------------------------------------------


def quant_kv_page(page: Array, bits: int) -> Tuple[Array, Array]:
    """page [..., T, KV, hd] -> (payload, scales [..., T, KV])."""
    x = page.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax == 0, 1.0, amax / QMAX[bits])
    q = jnp.clip(jnp.round(x / scale[..., None]), -QMAX[bits], QMAX[bits])
    if bits == 8:
        return q.astype(jnp.int8), scale
    # int4: pack adjacent pairs along hd into one uint8 (see kernels.packing).
    return pack_int4(q), scale


def dequant_kv_page(payload: Array, scales: Array, bits: int) -> Array:
    """Inverse of quant_kv_page (returns f32)."""
    if bits == 8:
        q = payload.astype(jnp.float32)
    else:
        q = unpack_int4(payload)
    return q * scales[..., None]


# -- cxl_hw: inline line-compressed far memory ------------------------------
# Software quantizes a page to dense int8 (same layout as the int8 codec);
# the expander's controller narrows each 64-codeword hardware line to 4-bit
# storage when every value fits int4 range. The engine always reads back the
# dense int8 view — line_bits only changes stored/wire bytes, never values.

CXL_LINE_ELEMS = 64  # int8 codewords per hardware cache line
CXL_NARROW_QMAX = 7  # |q| <= 7 -> the line is stored 4-bit


def cxl_encode_kv_page(page: Array) -> Tuple[Array, Array, Array]:
    """page [..., T, KV, hd] -> (payload int8, scales [..., T, KV],
    line_bits [..., T, KV, hd // CXL_LINE_ELEMS] in {4, 8})."""
    payload, scales = quant_kv_page(page, 8)
    return payload, scales, cxl_page_line_bits(payload)


def cxl_page_line_bits(payload: Array) -> Array:
    """Stored width of each hardware line of an int8 payload."""
    hd = payload.shape[-1]
    assert hd % CXL_LINE_ELEMS == 0, f"hd {hd} not a multiple of line size"
    lines = payload.astype(jnp.int32).reshape(
        *payload.shape[:-1], hd // CXL_LINE_ELEMS, CXL_LINE_ELEMS
    )
    narrow = jnp.max(jnp.abs(lines), axis=-1) <= CXL_NARROW_QMAX
    return jnp.where(narrow, 4, 8).astype(jnp.int32)


def cxl_decode_kv_page(payload: Array, scales: Array) -> Array:
    """Inverse of cxl_encode_kv_page (controller decompression is inline and
    value-exact, so decode is plain int8 dequant)."""
    return dequant_kv_page(payload, scales, 8)


def cxl_page_line_ratio(line_bits: Array) -> float:
    """Observed line-compression ratio over a batch of pages: nominal dense
    payload bits / stored line bits. In [1, 2]."""
    import numpy as np

    total = int(np.asarray(line_bits, dtype=np.int64).sum())
    return float(8 * line_bits.size) / float(max(total, 1))


def transcode_kv_page(
    payload: Array, scales: Array, src_bits: int, dst_bits: int
) -> Tuple[Array, Array]:
    """Requantize pages between codec widths (int8 <-> int4).

    Semantics are exactly the dequant -> quant composition; the Pallas
    kernel fuses the two so the dense f32 page never round-trips HBM.
    Same-width transcode is the identity (the same-codec fast path is a
    media copy and never calls this).
    """
    if src_bits == dst_bits:
        return payload, scales
    return quant_kv_page(dequant_kv_page(payload, scales, src_bits), dst_bits)


# ---------------------------------------------------------------------------
# Paged decode attention over one quantized pool
# ---------------------------------------------------------------------------


def paged_quant_attention(
    q: Array,  # [B, H, hd]
    k_pages: Array,  # [P, T, KV, hd(|//2)] int8/uint8
    k_scales: Array,  # [P, T, KV] f32
    v_pages: Array,
    v_scales: Array,
    page_table: Array,  # [B, MP] int32 (pool page id; entries >= n_pages ignored)
    n_pages: Array,  # [B] int32 valid page-table prefix length
    bits: int,
    slot_pos: Array = None,  # [B, MP] logical slot positions (default iota);
    # sequence-parallel shards pass their global positions so validity
    # against n_pages stays correct on a table slice.
) -> Tuple[Array, Array, Array, Array, Array]:
    """Flash-decoding partials over the pool's pages.

    Returns (out_unnorm [B,H,hd] f32, m [B,H], l [B,H],
             page_mass [B,MP], page_base [B,MP]).
    page_mass is the softmax mass of each page at its *local* base
    (page_base = that page's max score over heads and tokens); the true
    normalized hotness is  mass * exp(base - m_tot) / l_tot  once the global
    (m_tot, l_tot) is known after merging (see ops.page_hotness).
    softmax uses 1/sqrt(hd) scaling; GQA broadcast by kv head grouping.
    """
    b, h, hd = q.shape
    mp = page_table.shape[1]
    kv = k_pages.shape[2]
    t = k_pages.shape[1]
    g = h // kv

    qf = q.astype(jnp.float32).reshape(b, kv, g, hd) / (hd**0.5)

    # Scan over page-table chunks with online softmax — mirrors the kernel's
    # page-at-a-time pipeline: the working set stays O(chunk) instead of
    # materializing the whole dequantized pool (impossible at 500k context).
    chunk = min(mp, 128)
    pad = (-mp) % chunk
    if slot_pos is None:
        slot_pos = jnp.broadcast_to(jnp.arange(mp, dtype=jnp.int32)[None], (b, mp))
    if pad:
        page_table = jnp.pad(page_table, ((0, 0), (0, pad)))
        slot_pos = jnp.pad(slot_pos, ((0, 0), (0, pad)), constant_values=2**30)
    n_chunks = (mp + pad) // chunk
    table_c = page_table.reshape(b, n_chunks, chunk)
    pos_c = jnp.moveaxis(slot_pos.reshape(b, n_chunks, chunk), 1, 0)

    def body(carry, xs):
        acc, m_run, l_run = carry
        tbl, pos = xs  # [B, C], [B, C]
        k = dequant_kv_page(k_pages[tbl], k_scales[tbl], bits)  # [B,C,T,KV,hd]
        v = dequant_kv_page(v_pages[tbl], v_scales[tbl], bits)
        scores = jnp.einsum("bkgh,bptkh->bkgpt", qf, k)  # [B,KV,G,C,T]
        valid = (pos < n_pages[:, None])[:, None, None, :, None]
        scores = jnp.where(valid, scores, -jnp.inf)

        c_max = jnp.max(scores, axis=(3, 4))  # [B,KV,G]
        c_max = jnp.where(jnp.isfinite(c_max), c_max, NEG_INF)
        m_new = jnp.maximum(m_run, c_max)
        shift = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        e = jnp.where(valid, jnp.exp(scores - shift[..., None, None]), 0.0)
        alpha = jnp.where(m_run > NEG_INF / 2, jnp.exp(m_run - shift), 0.0)
        l_new = l_run * alpha + jnp.sum(e, axis=(3, 4))
        acc_new = acc * alpha[..., None] + jnp.einsum("bkgpt,bptkh->bkgh", e, v)

        # Telemetry at each page's local max.
        p_base = jnp.max(scores, axis=(1, 2, 4))  # [B,C]
        b_safe = jnp.where(jnp.isfinite(p_base), p_base, 0.0)
        e_loc = jnp.where(valid, jnp.exp(scores - b_safe[:, None, None, :, None]), 0.0)
        p_mass = jnp.sum(e_loc, axis=(1, 2, 4))  # [B,C]
        p_base = jnp.where(jnp.isfinite(p_base), p_base, NEG_INF)
        return (acc_new, m_new, l_new), (p_mass, p_base)

    acc0 = jnp.zeros((b, kv, g, hd), jnp.float32)
    m0 = jnp.full((b, kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g), jnp.float32)
    (out, m, l), (masses, bases) = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.moveaxis(table_c, 1, 0), pos_c)
    )
    page_mass = jnp.moveaxis(masses, 0, 1).reshape(b, mp + pad)[:, :mp]
    page_base = jnp.moveaxis(bases, 0, 1).reshape(b, mp + pad)[:, :mp]
    m_safe = jnp.where(m > NEG_INF / 2, m, 0.0)
    return (
        out.reshape(b, h, hd),
        m_safe.reshape(b, h),
        l.reshape(b, h),
        page_mass,
        page_base,
    )


def dense_recent_attention(
    q: Array,  # [B, H, hd]
    recent_k: Array,  # [B, R, KV, hd]
    recent_v: Array,
    recent_len: Array,  # scalar or [B]
) -> Tuple[Array, Array, Array]:
    """Partials over the dense (uncompressed) recent window."""
    b, h, hd = q.shape
    kv = recent_k.shape[2]
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, g, hd) / (hd**0.5)
    scores = jnp.einsum("bkgh,brkh->bkgr", qf, recent_k.astype(jnp.float32))
    r = recent_k.shape[1]
    rl = jnp.broadcast_to(jnp.asarray(recent_len), (b,))
    valid = (jnp.arange(r)[None] < rl[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(valid, jnp.exp(scores - m_safe[..., None]), 0.0)
    l = jnp.sum(e, axis=-1)
    out = jnp.einsum("bkgr,brkh->bkgh", e, recent_v.astype(jnp.float32))
    return out.reshape(b, h, hd), m_safe.reshape(b, h), l.reshape(b, h)


def merge_partials(parts: List[Tuple[Array, Array, Array]]) -> Array:
    """Exact merge of flash partials [(out_unnorm, m, l), ...] -> out [B,H,hd]."""
    m_all = jnp.stack([p[1] for p in parts])  # [N,B,H]
    m_tot = jnp.max(m_all, axis=0)
    num = 0.0
    den = 0.0
    for out_u, m, l in parts:
        w = jnp.exp(m - m_tot)
        num = num + out_u * w[..., None]
        den = den + l * w
    den = jnp.maximum(den, 1e-30)
    return num / den[..., None]


def tiered_decode_attention(
    q: Array,
    pools: dict,
    recent_k: Array,
    recent_v: Array,
    recent_len,
    cfg=None,
) -> Array:
    """Full oracle: attention over N quantized tier pools + dense recent
    window, merged exactly. ``pools`` maps tier name -> dict with keys
    (k_pages, k_scales, v_pages, v_scales, page_table, n_pages, bits).
    Returns out [B, H, hd] (f32)."""
    parts = [dense_recent_attention(q, recent_k, recent_v, recent_len)]
    for name in sorted(pools):
        p = pools[name]
        out_u, m, l, _, _ = paged_quant_attention(
            q,
            p["k_pages"],
            p["k_scales"],
            p["v_pages"],
            p["v_scales"],
            p["page_table"],
            p["n_pages"],
            p["bits"],
        )
        parts.append((out_u, m, l))
    return merge_partials(parts)


def host_page_mass(
    q: Array,  # [B, H, hd]
    summaries: Array,  # [Hs, KV, hd] f32 per-page key centroids
    table: Array,  # [B, MPh] int32 summary-slot ids (sentinel rows)
    n_rows: Array,  # [B] int32 valid prefix length
    page_tokens: int,
) -> Tuple[Array, Array]:
    """Would-have-touched softmax mass for host-resident pages.

    Host pages are never read in-step (that access-skip is the best-TCO
    tiers' quality cost), so their exact attention mass is unknowable
    without paying the fetch. The sentinel proxy scores the page's stored
    key centroid (mean over its T tokens, computed from the dequantized K
    payload at evict time) against q and charges all ``page_tokens`` tokens
    at that score:

        mass = T * sum_{kv,g} exp(s - max s),   base = max s

    This is exactly what the fused kernel's sentinel rows emit; normalize
    with the merged (m, l) like any page mass (``ops.page_hotness``).
    Telemetry only — sentinels never contribute to (acc, m, l).
    """
    b, h, hd = q.shape
    kv = summaries.shape[1]
    g = h // kv
    mp = table.shape[1]
    qf = q.astype(jnp.float32).reshape(b, kv, g, hd) / (hd**0.5)
    kbar = summaries[table]  # [B, MPh, KV, hd]
    s = jnp.einsum("bkgh,bpkh->bkgp", qf, kbar.astype(jnp.float32))  # [B,KV,G,P]
    base = jnp.max(s, axis=(1, 2))  # [B, MPh]
    mass = page_tokens * jnp.sum(jnp.exp(s - base[:, None, None, :]), axis=(1, 2))
    valid = jnp.arange(mp, dtype=jnp.int32)[None] < n_rows[:, None]
    return jnp.where(valid, mass, 0.0), jnp.where(valid, base, NEG_INF)


def fused_tiered_attention(
    q: Array,
    pools: dict,
    recent_k: Array,
    recent_v: Array,
    recent_len,
    host: dict = None,
):
    """Oracle for the single-launch megakernel: attention over N quantized
    tier pools + dense recent window with an exact merge, plus per-pool
    page-mass telemetry and (when ``host`` is given) the would-have-touched
    mass of host sentinel rows.

    ``host`` is a dict with keys ``summary`` [Hs, KV, hd], ``table``
    [B, MPh], ``n`` [B] and ``page_tokens``. Returns
    (out [B,H,hd] normalized, m_tot [B,H], l_tot [B,H],
     masses {name: (mass, base)} incl. "host").
    """
    b = q.shape[0]
    rlen = jnp.broadcast_to(jnp.asarray(recent_len, jnp.int32), (b,))
    parts = [dense_recent_attention(q, recent_k, recent_v, rlen)]
    masses = {}
    for name in sorted(pools):
        p = pools[name]
        out_u, m, l, mass, base = paged_quant_attention(
            q, p["k_pages"], p["k_scales"], p["v_pages"], p["v_scales"],
            p["page_table"], p["n_pages"], p["bits"],
        )
        parts.append((out_u, m, l))
        masses[name] = (mass, base)
    out = merge_partials(parts)
    m_tot = jnp.max(jnp.stack([p[1] for p in parts]), axis=0)
    l_tot = sum(p[2] * jnp.exp(p[1] - m_tot) for p in parts)
    if host is not None:
        masses["host"] = host_page_mass(
            q, host["summary"], host["table"], host["n"], host["page_tokens"]
        )
    return out, m_tot, l_tot, masses


def tiered_page_masses(q, pools) -> dict:
    """Per-tier (page_mass, page_base) telemetry; normalize with
    ops.page_hotness after merging."""
    out = {}
    for name, p in pools.items():
        _, _, _, mass, base = paged_quant_attention(
            q,
            p["k_pages"],
            p["k_scales"],
            p["v_pages"],
            p["v_scales"],
            p["page_table"],
            p["n_pages"],
            p["bits"],
        )
        out[name] = (mass, base)
    return out
