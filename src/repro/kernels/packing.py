"""Shared int4 nibble-packing layout + quantization ranges.

The layout is a cross-kernel invariant: adjacent head-dim pairs pack into
one uint8 with the EVEN index in the LOW nibble, nibbles in two's
complement. quant_page, dequant_page, transcode_page and the ref oracles
all import these helpers so the convention lives in exactly one place.
Pure jnp ops — usable inside Pallas kernel bodies and in the oracles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = {8: 127.0, 4: 7.0}


def pack_int4(q: jax.Array) -> jax.Array:
    """[..., hd] integer values in [-7, 7] -> [..., hd//2] uint8."""
    qi = q.astype(jnp.int32)
    lo = qi[..., 0::2] & 0xF
    hi = qi[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(payload: jax.Array) -> jax.Array:
    """[..., hd//2] uint8 -> [..., hd] f32 values in [-8, 7]."""
    p = payload.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    q = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)
    return q.astype(jnp.float32)
