from repro.data.pipeline import DataConfig, HostLoader, synthetic_corpus

__all__ = ["DataConfig", "HostLoader", "synthetic_corpus"]
