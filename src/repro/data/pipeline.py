"""Host data pipeline: deterministic synthetic corpus, sequence packing,
shard-aware loading, background prefetch, straggler mitigation.

Production posture on a 1000+-node cluster:
  * every host loads ONLY its data-parallel shard (`shard_id/num_shards`),
  * batches are produced by a background thread into a bounded queue
    (prefetch depth), so input stalls never serialize with the step,
  * a straggler timeout on the queue get: if the loader misses the deadline
    the step re-uses the previous batch and the event is counted — training
    never blocks on one slow host (skip-and-log, the standard mitigation),
  * determinism: the corpus is a counter-based PRNG stream, so any
    (step, shard) batch is reconstructible after elastic re-sharding —
    restoring from a checkpoint replays the exact token stream.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    shard_id: int = 0
    num_shards: int = 1
    seed: int = 1234
    prefetch: int = 2
    straggler_timeout_s: float = 10.0
    pack_documents: bool = True
    mean_doc_len: int = 512


def synthetic_corpus(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic synthetic LM batch for (step, shard).

    Documents are Zipf-distributed token runs with EOS separators, packed
    back-to-back into fixed-length rows (standard packing); loss mask is 1
    everywhere except padding.
    """
    local_batch = cfg.global_batch // cfg.num_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard_id])
    )
    eos = 0
    if cfg.pack_documents:
        rows = np.empty((local_batch, cfg.seq_len + 1), np.int32)
        for b in range(local_batch):
            pos = 0
            row = np.empty(cfg.seq_len + 1, np.int32)
            while pos < cfg.seq_len + 1:
                dlen = int(rng.geometric(1.0 / cfg.mean_doc_len))
                dlen = min(dlen, cfg.seq_len + 1 - pos)
                doc = rng.zipf(1.3, size=dlen) % (cfg.vocab_size - 1) + 1
                row[pos : pos + dlen] = doc
                pos += dlen
                if pos < cfg.seq_len + 1:
                    row[pos] = eos
                    pos += 1
            rows[b] = row
    else:
        rows = (rng.zipf(1.3, size=(local_batch, cfg.seq_len + 1)) % (cfg.vocab_size - 1) + 1).astype(np.int32)
    return {
        "tokens": rows[:, :-1],
        "targets": rows[:, 1:].astype(np.int32),
        "loss_mask": np.ones((local_batch, cfg.seq_len), np.float32),
    }


class HostLoader:
    """Background prefetching loader with straggler skip-and-log."""

    def __init__(self, cfg: DataConfig, make_batch=synthetic_corpus, start_step: int = 0):
        self.cfg = cfg
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._last_batch: Optional[Dict[str, np.ndarray]] = None
        self.straggler_events = 0
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.25)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        try:
            step, batch = self._q.get(timeout=self.cfg.straggler_timeout_s)
            self._last_batch = batch
            return batch
        except queue.Empty:
            # Straggler mitigation: never stall the step on a slow host.
            self.straggler_events += 1
            if self._last_batch is not None:
                return self._last_batch
            # First batch genuinely missing: block once.
            step, batch = self._q.get()
            self._last_batch = batch
            return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
