"""Perf-per-dollar frontier with the hardware-compressed CXL expander tier.

The ZeroPoint-style ``cxl_hw`` tier changes the frontier's shape because its
*effective* capacity and bandwidth are data-dependent: the inline compressor
narrows 64-codeword lines whose values fit int4 range, so a tenant's real
payload bytes decide what the expander costs per useful byte. This benchmark
drives the mix that exposes exactly that — one **compressible** tenant
(sparse, small-magnitude payloads: lines narrow, observed ratio near 2x) and
one **incompressible** tenant (dense full-range payloads: ratio 1.0) — and
sweeps ``capacity.cxl_search_grid()`` (the default 2T/6T/split grid plus the
``cxl`` family's alpha ladder) on the ``v5e-cxlhw`` server.

The per-tenant line ratios are NOT assumed: they are measured from real
encoded payloads (``codecs.CODECS['cxl_hw'].encode`` on seeded content,
sized by ``codecs.cxl_line_ratio``) and baked into the tenant ``Workload``;
the simulator feeds them to the shared ``AdaptiveMediaDevice`` EWMA and each
manager's per-device wire-ratio at window boundaries only, so the sweep
stays bit-reproducible.

Rows: ``cxl/point-<config>`` / ``cxl/frontier-<config>`` per searched point,
a ``-summary`` row with monotonicity, reproducibility, in-sweep 2T
dominance, dominance over the committed PR-7 frontier
(``baselines/capacity_frontier.json``), and async-vs-serial placement
identity for a ``cxl_hw``-backed ``TieredKVCache``. ``--check`` exits
non-zero unless every contract holds (the perf-guard CI entrypoint);
``baseline_guard.check_cxl_frontier`` additionally pins the frontier
structure to ``baselines/cxl_frontier.json``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Csv
from repro.core import capacity, codecs, simulator
from repro.core.arbiter import TenantSpec
from repro.core.simulator import Workload

N_REGIONS = 512
ACCESSES = 200_000
WINDOWS = 16
WARMUP = 2
FLIP_WINDOW = 8
SERVER = "v5e-cxlhw"
OPERATING_YEARS = 3.0
FLEET_SCALE = 256
SEED = 0
# Elements of representative tenant content used to measure line ratios.
PROBE_ELEMS = 64 * 1024

PR7_BASELINE = os.path.join(
    os.path.dirname(__file__), "baselines", "capacity_frontier.json"
)


# ---------------------------------------------------------------------------
# Measured line ratios: real encoded payloads, not assumptions
# ---------------------------------------------------------------------------


def tenant_content(kind: str, rng: np.random.Generator) -> np.ndarray:
    """Representative block content per tenant class.

    ``compressible``: a sparse-activation analogue — tiny background values
    with one full-scale spike per scale group, so the coarse (512-codeword)
    scale is pinned by the spike and every spike-free 64-codeword line
    quantizes into int4 range. ``incompressible``: dense unit-gaussian
    payloads that use the full int8 range everywhere."""
    if kind == "compressible":
        x = rng.normal(0.0, 0.02, PROBE_ELEMS).astype(np.float32)
        x[:: codecs.GROUP["cxl_hw"]] = 1.0
        return x
    if kind == "incompressible":
        return rng.normal(0.0, 1.0, PROBE_ELEMS).astype(np.float32)
    raise ValueError(f"unknown tenant content kind {kind!r}")


def measured_line_ratios(seed: int = SEED) -> Dict[str, float]:
    """Per-tenant observed line-compression ratio from real encodes."""
    import jax.numpy as jnp

    codec = codecs.CODECS["cxl_hw"]
    out: Dict[str, float] = {}
    for kind in ("compressible", "incompressible"):
        rng = np.random.default_rng(seed)
        enc = codec.encode(jnp.asarray(tenant_content(kind, rng)))
        out[kind] = float(codecs.cxl_line_ratio(enc.payload))
    return out


# ---------------------------------------------------------------------------
# The tenant mix
# ---------------------------------------------------------------------------


def mixed_workloads() -> List[Workload]:
    """Skew-flip phases (as the PR-7 frontier) with measured line ratios:
    the compressible tenant is hot early, the incompressible tenant hot
    late, so the expander's effective capacity is earned when it matters
    and priced honestly when it isn't."""
    ratios = measured_line_ratios()
    early = simulator.skew_flip(
        n_regions=N_REGIONS, accesses_hot=ACCESSES,
        accesses_cold=ACCESSES // 10, flip_window=FLIP_WINDOW,
        hot_first=True, name="compressible",
    )
    late = simulator.skew_flip(
        n_regions=N_REGIONS, accesses_hot=ACCESSES,
        accesses_cold=ACCESSES // 10, flip_window=FLIP_WINDOW,
        hot_first=False, name="incompressible",
    )
    return [
        dataclasses.replace(early, line_ratio=ratios["compressible"]),
        dataclasses.replace(late, line_ratio=ratios["incompressible"]),
    ]


def mixed_specs() -> List[TenantSpec]:
    return [TenantSpec("compressible", sla_weight=1.0),
            TenantSpec("incompressible", sla_weight=1.0)]


def sweep(windows: int = WINDOWS, seed: int = SEED) -> dict:
    planner = capacity.CapacityPlanner(
        capacity.get_server(SERVER),
        operating_period_years=OPERATING_YEARS,
        fleet_scale=FLEET_SCALE,
    )
    return capacity.sweep_frontier(
        mixed_workloads, mixed_specs(), planner,
        configs=capacity.cxl_search_grid(),
        windows=windows, warmup_windows=WARMUP, seed=seed,
    )


# ---------------------------------------------------------------------------
# Contracts beyond the sweep itself
# ---------------------------------------------------------------------------


def dominates_committed_frontier(points: List[dict], pr7: dict) -> dict:
    """Does at least one cxl-backed point dominate the committed PR-7
    frontier — more savings at a no-worse latency proxy than some committed
    frontier point? Returns the witness (or margin -inf)."""
    best = {"dominates": False, "margin_pct": None, "config": None,
            "vs_config": None}
    margin = -np.inf
    for p in points:
        if not p["config"].startswith("cxl-"):
            continue
        for q in pr7.get("frontier", []):
            if p["p99_penalty_s"] <= q["p99_penalty_s"] + 1e-12:
                m = p["savings_pct"] - q["savings_pct"]
                if m > margin:
                    margin = m
                    best.update(
                        dominates=bool(m > 0), margin_pct=float(m),
                        config=p["config"], vs_config=q["config"],
                    )
    return best


def async_serial_placements_identical() -> bool:
    """A ``cxl_hw``-backed ``TieredKVCache`` must land byte-identical
    placements under serial and async migration: adaptive-ratio updates
    happen at window boundaries only, after the pipeline drains, so the
    observation stream (and therefore every plan) is mode-independent."""
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.core.manager import ManagerConfig
    from repro.serving.kv_cache import TieredKVCache

    cfg = ModelConfig(
        name="cxlbench", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
    )

    def run(async_migration: bool) -> np.ndarray:
        cache = TieredKVCache(
            cfg, 2, 2, 8, 64, recent_window=16,
            manager_cfg=ManagerConfig(policy="analytical", alpha=0.5,
                                      window_steps=4),
            warm_frac=0.5, async_migration=async_migration,
            host_media_device="cxl_hw",
        )
        rng = np.random.default_rng(SEED)
        coords = [(la, sl, pg) for la in range(2) for sl in range(2)
                  for pg in range(8)][:24]
        kv, hd = cfg.n_kv_heads, cfg.head_dim_()
        k = rng.normal(0, 1, (len(coords), cache.pt, kv, hd)).astype(np.float32)
        k[12:] = 0.0  # pad-tail pages: the compressible half
        v = k.copy()
        cache.append_pages(coords, jnp.asarray(k), jnp.asarray(v))
        for w in range(4):
            counts = np.zeros(cache.n_regions)
            counts[: 8 + w] = np.linspace(10, 1, 8 + w)
            cache.manager.record_access_counts(counts)
            cache.end_window()
            while cache.pipeline.busy:
                cache.pipeline.tick()
        return cache.physical.copy()

    return bool(np.array_equal(run(False), run(True)))


# ---------------------------------------------------------------------------
# Benchmark rows + check mode
# ---------------------------------------------------------------------------


def run(csv: Csv, results: dict | None = None, windows: int = WINDOWS) -> None:
    t0 = time.perf_counter()
    res = sweep(windows=windows)
    wall = (time.perf_counter() - t0) * 1e6 / max(len(res["points"]), 1)
    # Bit-reproducibility probe: the perf-guard determinism contract.
    res["reproducible"] = capacity.frontier_json(res) == capacity.frontier_json(
        sweep(windows=windows)
    )
    res["line_ratios"] = {
        k: capacity._r(v) for k, v in sorted(measured_line_ratios().items())
    }
    res["cxl_on_frontier"] = any(
        p["config"].startswith("cxl-") for p in res["frontier"]
    )
    with open(PR7_BASELINE) as f:
        pr7 = json.load(f)
    res["vs_pr7_frontier"] = dominates_committed_frontier(res["points"], pr7)
    res["placements_identical"] = async_serial_placements_identical()

    frontier_configs = {p["config"] for p in res["frontier"]}
    for p in res["points"]:
        kind = "frontier" if p["config"] in frontier_configs else "point"
        csv.add(
            f"{kind}-{p['config']}",
            wall,
            f"servers={p['servers']};fleet_usd={p['fleet_usd']:.0f};"
            f"savings_pct={p['savings_pct']:.2f};"
            f"p99_penalty_s={p['p99_penalty_s']:.4f}",
        )
    vs = res["vs_pr7_frontier"]
    csv.add(
        "summary",
        wall,
        f"monotone={res['monotone']};reproducible={res['reproducible']};"
        f"dominates_2t={res.get('dominates_2t')};"
        f"cxl_on_frontier={res['cxl_on_frontier']};"
        f"dominates_pr7={vs['dominates']};"
        f"pr7_margin_pct={vs['margin_pct']};"
        f"placements_identical={res['placements_identical']}",
    )
    if results is not None:
        results.update(res)


def check(results: dict) -> List[str]:
    """The --check contracts (baseline-independent half of the CI guard)."""
    errors: List[str] = []
    if not results.get("reproducible", False):
        errors.append("sweep is not bit-reproducible across two fresh runs")
    if not results.get("monotone", False):
        errors.append("frontier is not monotone")
    if not results.get("dominates_2t", False):
        errors.append("frontier does not dominate the in-sweep 2T baseline")
    if not results.get("cxl_on_frontier", False):
        errors.append("no cxl-backed configuration sits on the frontier")
    vs = results.get("vs_pr7_frontier", {})
    if not vs.get("dominates", False):
        errors.append(
            f"no cxl-backed point dominates the committed PR-7 frontier "
            f"(best margin {vs.get('margin_pct')})"
        )
    if not results.get("placements_identical", False):
        errors.append("async placements diverged from the serial oracle")
    ratios = results.get("line_ratios", {})
    if not ratios.get("compressible", 0.0) > ratios.get("incompressible", 2.0):
        errors.append(
            f"measured line ratios lost data-dependence: {ratios}"
        )
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="dump metrics for CI")
    ap.add_argument("--check", action="store_true",
                    help="assert every frontier contract; exit non-zero on any failure")
    args = ap.parse_args()
    csv = Csv("cxl")
    results: dict = {}
    run(csv, results)
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    if args.check:
        errors = check(results)
        if errors:
            for e in errors:
                print(f"FAIL cxl_frontier: {e}")
            raise SystemExit(1)
        print("OK cxl_frontier: all contracts hold")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
