"""Paper Fig. 12: mean and p99 access latency per config (memcached)."""

from __future__ import annotations

from benchmarks.common import Csv
from repro.core import simulator
from repro.core.manager import make_manager

THRESHOLDS = {"C": 50.0, "M": 200.0, "A": 800.0}
CONFIGS = ["2T-C", "2T-M", "2T-A", "6T-WF-C", "6T-WF-M", "6T-WF-A",
           "6T-AM-0.9", "6T-AM-0.5", "6T-AM-0.1"]


def run(csv: Csv, windows: int = 20) -> None:
    wl = simulator.gaussian_kv(n_regions=2048, accesses_per_window=500_000,
                               name="memcached")
    for cfg in CONFIGS:
        mgr = make_manager(cfg, wl.n_regions, thresholds=THRESHOLDS)
        r = simulator.simulate(wl, mgr, windows=windows, seed=1)
        csv.add(cfg, r.mean_access_us,
                f"p99_us={r.p99_access_us:.2f};mean_us={r.mean_access_us:.3f}")


def main() -> None:
    csv = Csv("fig12")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
