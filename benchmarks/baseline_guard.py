"""Consolidated, baseline-driven CI perf guard.

One registry of guards replaces the former copy-pasted per-benchmark check
scripts (``check_dispatch_baseline.py`` / ``check_media_baseline.py``): each
entry names a committed baseline JSON under ``benchmarks/baselines/``, a
runner that produces the current metrics (shapes derived from the baseline
where applicable), and a check function. ``benchmarks/run.py
--check-baselines`` drives the whole matrix and exits non-zero on any
regression.

Check semantics per guard:

  migration_dispatch — kernel-dispatch counts are deterministic, so the
    comparison is exact: batched dispatches must not exceed the baseline and
    the loop/batched ratio must not shrink. Bench sizes are the baseline's
    own keys (add a size to the baseline and CI covers it automatically).
  media_overlap — async placements must stay bit-identical to the serial
    oracle, overlap must stay > 0, bytes must transit the host swap device,
    and overlap efficiency may drift at most ``EFFICIENCY_BAND`` below the
    baseline (plan sizes wobble a little across platforms/jax versions).
  prefetch_hitrate — prefetched placements must stay bit-identical to the
    no-prefetch oracle, decode-visible swap-in stalls must be reduced, at
    least one page must be prefetched, and the hit rate must stay >= 0.5
    and within ``HIT_RATE_BAND`` of the baseline.
  capacity_frontier — the planner sweep is pure seeded numpy, so the
    contract is threefold: the sweep must be bit-reproducible (two passes
    emit identical frontier JSON), the Pareto frontier must stay monotone
    (savings strictly rise, fleet dollars never rise, as the latency proxy
    grows), and the frontier must keep dominating the 2-tier production
    baseline on the skew-flip mix by at least the paper's margin
    (``DOMINANCE_MARGIN_FLOOR_PCT`` savings points at no-worse latency).
    Frontier structure (config names + server counts + savings) is compared
    exactly against the committed baseline.
  cxl_frontier — the hardware-compressed CXL sweep inherits the
    capacity_frontier determinism contract (bit-reproducible two-pass JSON,
    monotone frontier, exact frontier structure vs the committed baseline)
    and adds the expander's own: at least one cxl-backed point must sit on
    the frontier AND dominate the committed PR-7 capacity frontier on >= 1
    operating point, measured line ratios must stay data-dependent
    (compressible > incompressible), and a cxl_hw-backed KV cache must land
    bit-identical placements under serial and async migration.
  serving_slo — the frontend schedule runs in seeded virtual time, so the
    contract is exact: two fresh runs must emit the identical summary
    (deterministic replay), preemption-to-host-tier must actually fire
    (>= 1 preemption AND >= 1 resume) while resumed requests re-prefill
    EXACTLY zero tokens, interactive p99 TTFT must stay inside the SLO
    ceiling (``serving_slo.TTFT_P99_CEILING``), and the completion /
    refusal / preemption counts must match the committed baseline exactly.
  decode_fused — launch structure and operand assembly are deterministic,
    so the comparison is exact: the fused megakernel must issue EXACTLY one
    Pallas launch per decode step at every tier count, class-major operand
    assembly must move EXACTLY zero concat copy-bytes per step (never more
    than the committed baseline, which is 0), the per-pool oracle's launch
    count must not shrink (it is the O(tiers) contrast), and fused outputs
    + normalized hotness must match the oracle to fp32 tolerance
    (``outputs_match``). Tier counts are the baseline's own keys.

Refresh any baseline by re-running its benchmark with ``--json`` and
committing the result.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List

from benchmarks.common import Csv

EFFICIENCY_BAND = 0.25
HIT_RATE_BAND = 0.15
# The paper's low-end headline: multiple software-defined tiers buy >= 22
# points of memory-TCO savings at performance parity (§1).
DOMINANCE_MARGIN_FLOOR_PCT = 22.0


# ---------------------------------------------------------------------------
# check functions (current results vs committed baseline -> list of errors)
# ---------------------------------------------------------------------------


def check_dispatch(current: dict, baseline: dict) -> List[str]:
    errors = []
    for size, base in sorted(baseline.items()):
        cur = current.get(size)
        if cur is None:
            errors.append(f"size {size}: missing from current results")
            continue
        if cur["dispatches_batched"] > base["dispatches_batched"]:
            errors.append(
                f"size {size}: batched dispatches regressed "
                f"{base['dispatches_batched']} -> {cur['dispatches_batched']}"
            )
        if cur["dispatch_ratio"] < base["dispatch_ratio"]:
            errors.append(
                f"size {size}: dispatch ratio regressed "
                f"{base['dispatch_ratio']:.1f}x -> {cur['dispatch_ratio']:.1f}x"
            )
    return errors


def check_media(current: dict, baseline: dict) -> List[str]:
    errors = []
    cur = current.get("overlap")
    base = baseline.get("overlap")
    if cur is None or base is None:
        return ["missing 'overlap' section in current or baseline results"]
    if not cur.get("placements_identical", False):
        errors.append("async placements diverged from the serial oracle")
    if cur.get("overlapped_steps", 0) < 1:
        errors.append("no decode steps retired during migration (overlap=0)")
    if cur.get("host_bytes", 0) <= 0:
        errors.append("no bytes transited the host swap device")
    floor = base["overlap_efficiency"] - EFFICIENCY_BAND
    if cur.get("overlap_efficiency", 0.0) < floor:
        errors.append(
            f"overlap efficiency regressed: {cur.get('overlap_efficiency'):.2f} "
            f"< baseline {base['overlap_efficiency']:.2f} - {EFFICIENCY_BAND}"
        )
    return errors


def check_decode_fused(current: dict, baseline: dict) -> List[str]:
    errors = []
    for n, base in sorted(baseline.items()):
        cur = current.get(n)
        if cur is None:
            errors.append(f"{n} tiers: missing from current results")
            continue
        if cur["launches_fused"] != 1:
            errors.append(
                f"{n} tiers: fused path issued {cur['launches_fused']} "
                f"launches/step (must be exactly 1)"
            )
        if cur.get("concat_copy_bytes", 0) > base.get("concat_copy_bytes", 0):
            errors.append(
                f"{n} tiers: fused operand assembly copied "
                f"{cur['concat_copy_bytes']} bytes/step (baseline "
                f"{base.get('concat_copy_bytes', 0)} — class-major layout "
                f"must concat nothing)"
            )
        if cur["launches_per_pool"] < base["launches_per_pool"]:
            errors.append(
                f"{n} tiers: per-pool oracle launch count shrank "
                f"{base['launches_per_pool']} -> {cur['launches_per_pool']} "
                f"(oracle no longer O(tiers)?)"
            )
        if not cur.get("outputs_match", False):
            errors.append(
                f"{n} tiers: fused outputs/hotness diverged from the "
                f"per-pool oracle (out_err={cur.get('out_max_err')}, "
                f"hot_err={cur.get('hot_max_err')})"
            )
    return errors


def check_capacity_frontier(current: dict, baseline: dict) -> List[str]:
    errors = []
    if not current.get("reproducible", False):
        errors.append(
            "planner sweep is not bit-reproducible (two passes on the same "
            "seed emitted different frontier JSON)"
        )
    if not current.get("monotone", False):
        errors.append(
            "frontier is not monotone (savings must strictly rise and fleet "
            "dollars never rise as the latency proxy grows)"
        )
    if not current.get("dominates_2t", False):
        errors.append("frontier no longer dominates the 2-tier baseline")
    margin = current.get("dominance_margin_pct")
    if margin is None or margin < DOMINANCE_MARGIN_FLOOR_PCT:
        errors.append(
            f"2-tier dominance margin {margin} is below the paper's floor "
            f"({DOMINANCE_MARGIN_FLOOR_PCT} savings points)"
        )
    cur_front = current.get("frontier", [])
    base_front = baseline.get("frontier", [])
    if [p["config"] for p in cur_front] != [p["config"] for p in base_front]:
        errors.append(
            f"frontier configs changed: "
            f"{[p['config'] for p in base_front]} -> "
            f"{[p['config'] for p in cur_front]}"
        )
    else:
        for cur, base in zip(cur_front, base_front):
            if cur["servers"] != base["servers"]:
                errors.append(
                    f"{cur['config']}: servers changed "
                    f"{base['servers']} -> {cur['servers']}"
                )
            if abs(cur["savings_pct"] - base["savings_pct"]) > 1e-6:
                errors.append(
                    f"{cur['config']}: savings changed "
                    f"{base['savings_pct']} -> {cur['savings_pct']}"
                )
    return errors


def check_cxl_frontier(current: dict, baseline: dict) -> List[str]:
    from benchmarks import cxl_frontier

    # The benchmark's own contracts (reproducibility, monotonicity, 2T +
    # PR-7 dominance, placement identity, ratio data-dependence)...
    errors = cxl_frontier.check(current)
    # ...plus exact frontier structure vs the committed baseline.
    cur_front = current.get("frontier", [])
    base_front = baseline.get("frontier", [])
    if [p["config"] for p in cur_front] != [p["config"] for p in base_front]:
        errors.append(
            f"frontier configs changed: "
            f"{[p['config'] for p in base_front]} -> "
            f"{[p['config'] for p in cur_front]}"
        )
    else:
        for cur, base in zip(cur_front, base_front):
            if cur["servers"] != base["servers"]:
                errors.append(
                    f"{cur['config']}: servers changed "
                    f"{base['servers']} -> {cur['servers']}"
                )
            if abs(cur["savings_pct"] - base["savings_pct"]) > 1e-6:
                errors.append(
                    f"{cur['config']}: savings changed "
                    f"{base['savings_pct']} -> {cur['savings_pct']}"
                )
    return errors


def check_prefetch(current: dict, baseline: dict) -> List[str]:
    errors = []
    cur = current.get("prefetch")
    base = baseline.get("prefetch")
    if cur is None or base is None:
        return ["missing 'prefetch' section in current or baseline results"]
    if not cur.get("placements_identical", False):
        errors.append("prefetch placements diverged from the no-prefetch oracle")
    if not cur.get("stall_reduced", False):
        errors.append("prefetch did not reduce decode-visible swap-in stalls")
    if cur.get("pages_prefetched", 0) < 1:
        errors.append("no pages were ever prefetched")
    floor = max(0.5, base["hit_rate"] - HIT_RATE_BAND)
    if cur.get("hit_rate", 0.0) < floor:
        errors.append(
            f"prefetch hit rate regressed: {cur.get('hit_rate', 0.0):.2f} "
            f"< floor {floor:.2f} (baseline {base['hit_rate']:.2f})"
        )
    return errors


# ---------------------------------------------------------------------------
# guard registry
# ---------------------------------------------------------------------------


def _run_dispatch(results: dict, baseline: dict) -> None:
    from benchmarks import migration_batch

    sizes = tuple(sorted(int(k) for k in baseline))
    migration_batch.run(Csv("migration"), sizes=sizes, results=results)


def _run_media(results: dict, baseline: dict) -> None:
    from benchmarks import media_pipeline

    media_pipeline.run(Csv("media"), results)


def check_serving_slo(current: dict, baseline: dict) -> List[str]:
    errors = []
    if not current.get("reproducible", False):
        errors.append(
            "frontend schedule is not deterministic (two fresh runs on the "
            "same trace emitted different summaries)"
        )
    if current.get("re_prefill_tokens", -1) != 0:
        errors.append(
            f"resumed requests re-prefilled "
            f"{current.get('re_prefill_tokens')} tokens (contract: resume "
            f"restores parked host pages, never recomputes the prompt)"
        )
    if current.get("preemptions", 0) < 1 or current.get("resumes", 0) < 1:
        errors.append(
            f"preemption-to-host-tier did not fire "
            f"(preemptions={current.get('preemptions')}, "
            f"resumes={current.get('resumes')}) — the burst trace must "
            f"exercise the preempt/resume path"
        )
    from benchmarks.serving_slo import TTFT_P99_CEILING

    p99 = current.get("interactive", {}).get("ttft_p99")
    if p99 is None or p99 > TTFT_P99_CEILING:
        errors.append(
            f"interactive p99 TTFT {p99} steps exceeds the SLO ceiling "
            f"({TTFT_P99_CEILING})"
        )
    for key in ("completed", "refused", "preemptions", "resumes", "arrivals"):
        if current.get(key) != baseline.get(key):
            errors.append(
                f"{key} changed vs baseline: "
                f"{baseline.get(key)} -> {current.get(key)}"
            )
    for cls in ("batch", "interactive"):
        cur_c = current.get(cls, {})
        base_c = baseline.get(cls, {})
        for key in ("completed", "ttft_p50", "ttft_p99", "tbt_p99"):
            cv, bv = cur_c.get(key), base_c.get(key)
            if cv is None or bv is None or abs(cv - bv) > 1e-6:
                errors.append(f"{cls}.{key} changed vs baseline: {bv} -> {cv}")
    return errors


def _run_prefetch(results: dict, baseline: dict) -> None:
    from benchmarks import prefetch_hitrate

    prefetch_hitrate.run(Csv("prefetch"), results)


def _run_decode_fused(results: dict, baseline: dict) -> None:
    from benchmarks import decode_fused

    tiers = tuple(sorted(int(k) for k in baseline))
    decode_fused.run(Csv("decode_fused"), tier_counts=tiers, results=results)


def _run_capacity(results: dict, baseline: dict) -> None:
    from benchmarks import capacity_frontier

    capacity_frontier.run(Csv("capacity"), results)


def _run_serving_slo(results: dict, baseline: dict) -> None:
    from benchmarks import serving_slo

    serving_slo.run(Csv("serving_slo"), results)


def _run_cxl(results: dict, baseline: dict) -> None:
    from benchmarks import cxl_frontier

    cxl_frontier.run(Csv("cxl"), results)


@dataclasses.dataclass(frozen=True)
class Guard:
    name: str
    baseline_file: str
    run: Callable[[dict, dict], None]  # (results out, baseline in)
    check: Callable[[dict, dict], List[str]]


GUARDS = (
    Guard("migration_dispatch", "migration_dispatch.json", _run_dispatch, check_dispatch),
    Guard("media_overlap", "media_overlap.json", _run_media, check_media),
    Guard("prefetch_hitrate", "prefetch_hitrate.json", _run_prefetch, check_prefetch),
    Guard("decode_fused", "decode_fused.json", _run_decode_fused, check_decode_fused),
    Guard("capacity_frontier", "capacity_frontier.json", _run_capacity,
          check_capacity_frontier),
    Guard("cxl_frontier", "cxl_frontier.json", _run_cxl, check_cxl_frontier),
    Guard("serving_slo", "serving_slo.json", _run_serving_slo,
          check_serving_slo),
)


def check_baselines(
    baseline_dir: str = "benchmarks/baselines", out_dir: str | None = None
) -> int:
    """Run every registered guard; returns a process exit code (0 = all OK).
    ``out_dir`` dumps each guard's current metrics as ``<name>.json`` (the
    CI artifact)."""
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    failures: Dict[str, List[str]] = {}
    for g in GUARDS:
        with open(os.path.join(baseline_dir, g.baseline_file)) as f:
            baseline = json.load(f)
        results: dict = {}
        g.run(results, baseline)
        if out_dir:
            with open(os.path.join(out_dir, f"{g.name}.json"), "w") as f:
                json.dump(results, f, indent=2, sort_keys=True)
        errors = g.check(results, baseline)
        if errors:
            failures[g.name] = errors
            print(f"FAIL {g.name}: regression vs {g.baseline_file}")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"OK {g.name} (vs {g.baseline_file})")
    if failures:
        print(f"{len(failures)}/{len(GUARDS)} perf guards failed")
        return 1
    print(f"all {len(GUARDS)} perf guards passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(check_baselines())
