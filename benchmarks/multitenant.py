"""Multi-tenant tiersets under the BudgetArbiter — the paper's headline
comparison (N-tier vs 2-tier) per tenant and in aggregate, on shared pools.

Scenarios (two tenants each, per §8's co-hosting direction):
  * ``hotcold``  — skewed Gaussian tenant next to a near-uniform cold tenant,
  * ``bursty``   — flash-crowd tenant next to a steady tenant,
  * ``skewflip`` — two tenants whose hotness swaps mid-run.

For each scenario and each config (6T analytical vs the 2T production
baseline) the arbiter shares one budget + one capacity vector across both
tenants. Rows: ``multitenant/<scenario>-<tenant>-<config>`` with
us_per_call = wall time per simulated window, derived = per-tenant slowdown /
TCO savings / fast-tier share / allotted budget; ``-fleet-`` rows carry the
aggregate and the single-tenant-baseline delta (must stay within 5%).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import Csv
from repro.core import simulator
from repro.core.arbiter import BudgetArbiter, TenantSpec
from repro.core.manager import make_manager
from repro.core.simulator import Workload

N_REGIONS = 512
ACCESSES = 200_000
ALPHA = 0.5


def scenarios() -> List[Tuple[str, List[Workload], List[TenantSpec]]]:
    n = N_REGIONS
    return [
        (
            "hotcold",
            [
                simulator.gaussian_kv(n_regions=n, accesses_per_window=ACCESSES,
                                      sigma_frac=0.08, name="hot"),
                simulator.uniform_scan(n_regions=n, accesses_per_window=ACCESSES // 10,
                                       compute_s_per_window=1.0, name="cold"),
            ],
            [TenantSpec("hot", sla_weight=1.0),
             TenantSpec("cold", sla_weight=1.0, alpha_floor=0.05)],
        ),
        (
            "bursty",
            [
                simulator.bursty_kv(n_regions=n, accesses_per_window=ACCESSES // 4,
                                    burst_every=8, burst_windows=2, burst_mult=8.0,
                                    name="bursty"),
                simulator.gaussian_kv(n_regions=n, accesses_per_window=ACCESSES,
                                      sigma_frac=0.12, name="steady"),
            ],
            [TenantSpec("bursty", sla_weight=2.0),
             TenantSpec("steady", sla_weight=1.0)],
        ),
        (
            "skewflip",
            [
                simulator.skew_flip(n_regions=n, accesses_hot=ACCESSES,
                                    accesses_cold=ACCESSES // 10, flip_window=12,
                                    hot_first=True, name="early"),
                simulator.skew_flip(n_regions=n, accesses_hot=ACCESSES,
                                    accesses_cold=ACCESSES // 10, flip_window=12,
                                    hot_first=False, name="late"),
            ],
            [TenantSpec("early", sla_weight=1.0),
             TenantSpec("late", sla_weight=1.0)],
        ),
    ]


def _make_arbiter(config: str, specs, n_tenants: int) -> BudgetArbiter:
    if config == "6t":
        managers = [make_manager("6T-AM-0.5", N_REGIONS, seed=t)
                    for t in range(n_tenants)]
    else:  # the paper's 2-tier production baseline
        managers = [make_manager("2T-M", N_REGIONS, seed=t)
                    for t in range(n_tenants)]
    n_opts = managers[0].tierset.n_tiers + 1
    # Shared pools: fast tier holds half the fleet, every compressed tier can
    # hold the whole fleet (capacity pressure lands on the fast tier, where
    # the arbitration fight actually is).
    cap = np.full(n_opts, float(n_tenants * N_REGIONS))
    cap[0] = n_tenants * N_REGIONS / 2
    return BudgetArbiter(specs, managers, alpha=ALPHA, tier_capacity_regions=cap)


def _single_tenant_baseline(workloads: List[Workload], config: str,
                            windows: int, warmup: int) -> float:
    """One manager over the concatenated region space (no tenant split)."""
    name = "6T-AM-0.5" if config == "6t" else "2T-M"
    m = make_manager(name, N_REGIONS * len(workloads), seed=0)
    return simulator.simulate_single_tenant_baseline(
        workloads, m, windows=windows, warmup_windows=warmup, seed=0
    )


def run(csv: Csv, windows: int = 24, warmup: int = 2) -> None:
    for scenario, workloads, specs in scenarios():
        for config in ("6t", "2t"):
            arb = _make_arbiter(config, specs, len(workloads))
            t0 = time.perf_counter()
            res = simulator.simulate_multitenant(
                workloads, arb, windows=windows, warmup_windows=warmup, seed=0
            )
            wall = (time.perf_counter() - t0) * 1e6 / windows
            for ts in res.tenants:
                csv.add(
                    f"{scenario}-{ts.tenant}-{config}",
                    wall,
                    f"slowdown_pct={ts.slowdown_pct:.2f};"
                    f"tco_savings_pct={ts.tco_savings_pct:.2f};"
                    f"fast_regions={ts.mean_fast_regions:.0f};"
                    f"budget_usd={ts.mean_budget_usd:.3f}",
                )
            single = _single_tenant_baseline(workloads, config, windows, warmup)
            csv.add(
                f"{scenario}-fleet-{config}",
                wall,
                f"tco_savings_pct={res.fleet_savings_pct:.2f};"
                f"single_tenant_pct={single:.2f};"
                f"delta_pct={abs(res.fleet_savings_pct - single):.2f};"
                f"budget_feasible_frac={res.budget_feasible_frac:.2f}",
            )


def main() -> None:
    csv = Csv("multitenant")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
