"""Paper Fig. 3 + Table 1: characterize the 12 software-defined compressed
tiers on two data distributions (the nci-vs-dickens analogue):

  * ``smooth``  — low-entropy KV-like data (decaying spectrum, highly
    quantization-friendly; nci analogue),
  * ``heavy``   — heavy-tailed activations (hard to compress; dickens).

Per tier: modeled access latency (2MB region), effective compression ratio,
unit cost, measured reconstruction error, and measured CPU codec wall time
(directional only — the target is TPU).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, time_us
from repro.core import codecs, tiers


def _datasets(n=1 << 16, seed=0):
    rng = np.random.default_rng(seed)
    # smooth: sum of a few slow sinusoids + small noise (high compressibility)
    t = np.linspace(0, 30, n)
    smooth = sum(np.sin(f * t + p) / f for f, p in [(1, 0), (2.3, 1), (4.1, 2)])
    smooth = smooth + 0.01 * rng.normal(size=n)
    # heavy: student-t heavy-tailed (outliers hurt absmax codecs)
    heavy = rng.standard_t(df=3, size=n)
    return {"smooth": jnp.asarray(smooth, jnp.float32),
            "heavy": jnp.asarray(heavy, jnp.float32)}


def run(csv: Csv) -> None:
    data = _datasets()
    region = 1 << 20  # 2MB source / 2B per elem
    for t in tiers.characterized():
        lat_us = t.access_latency_s(region) * 1e6
        ratio = t.effective_ratio(region)
        usd = t.usd_per_source_byte(region) * (1 << 30)
        for name, x in data.items():
            err = float(codecs.roundtrip_error(t.codec_name, x))
            codec = codecs.CODECS[t.codec_name]
            enc = jax.jit(lambda v: codec.encode(v).payload)
            wall = time_us(lambda: jax.block_until_ready(enc(x)), iters=3)
            csv.add(
                f"{t.tid}-{t.name}-{name}",
                wall,
                f"lat_us={lat_us:.1f};ratio={ratio:.2f};usd_gb={usd:.2f};err={err:.4f}",
            )


def main() -> None:
    csv = Csv("fig3")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
