"""Paper Fig. 9 + Fig. 10 + Fig. 11: per-window placement distributions,
fault-backs, and the TCO timeline for waterfall vs analytical on the
memcached-analogue workload."""

from __future__ import annotations

from benchmarks.common import Csv
from repro.core import simulator
from repro.core.manager import make_manager

THRESHOLDS = {"C": 50.0, "M": 200.0, "A": 800.0}


def run(csv: Csv, windows: int = 16) -> None:
    wl = simulator.gaussian_kv(n_regions=2048, accesses_per_window=500_000,
                               name="memcached")
    for cfg in ("6T-WF-M", "6T-WF-A", "6T-AM-0.5", "6T-AM-0.1"):
        mgr = make_manager(cfg, wl.n_regions, thresholds=THRESHOLDS)
        r = simulator.simulate(wl, mgr, windows=windows, seed=1)
        for w in (0, windows // 2, windows - 1):
            hist = r.placement_hists[w]
            faults = r.fault_hists[w]
            csv.add(
                f"{cfg}-w{w}",
                0.0,
                "placement=" + "/".join(str(int(x)) for x in hist)
                + ";faultblocks=" + "/".join(str(int(x)) for x in faults),
            )
        # Fig 11: TCO savings timeline summary.
        sav = r.per_window_savings
        csv.add(
            f"{cfg}-tco-timeline",
            0.0,
            f"first={sav[0]:.1f};mid={sav[len(sav)//2]:.1f};last={sav[-1]:.1f}",
        )


def main() -> None:
    csv = Csv("fig9_10_11")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
