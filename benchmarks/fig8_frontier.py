"""Paper Fig. 1 + Fig. 8: the perf-vs-TCO frontier.

Two halves:
  * per-workload configs — 2T-C/M/A vs 6T-WF-C/M/A on the five
    paper-analogue workloads (threshold policies, single tenant),
  * the alpha-sweep frontier — formerly an analytic 6T-AM-{0.9,0.5,0.1}
    trio simulated here; now owned by the fleet capacity planner
    (``benchmarks/capacity_frontier.py``): planner-driven perf-per-dollar
    points on the skew-flip multi-tenant mix, re-emitted here as
    ``fig8/frontier-<config>`` rows so the figure still carries the
    frontier axis, priced in servers and amortized dollars instead of
    bytes.
"""

from __future__ import annotations

import time

from benchmarks.common import Csv
from repro.core import simulator
from repro.core.manager import make_manager

CONFIGS = [
    "2T-C", "2T-M", "2T-A",
    "6T-WF-C", "6T-WF-M", "6T-WF-A",
]
THRESHOLDS = {"C": 50.0, "M": 200.0, "A": 800.0}


def workloads():
    return [
        simulator.gaussian_kv(n_regions=2048, accesses_per_window=500_000,
                              name="memcached", sigma_frac=0.08),
        simulator.gaussian_kv(n_regions=2048, accesses_per_window=500_000,
                              name="redis", sigma_frac=0.12, drift_frac=0.02),
        simulator.rotating_frontier(n_regions=2048, accesses_per_window=500_000,
                                    name="bfs", advance_frac=0.08),
        simulator.rotating_frontier(n_regions=2048, accesses_per_window=500_000,
                                    name="pagerank", advance_frac=0.02,
                                    frontier_frac=0.25),
        simulator.uniform_scan(n_regions=4096, accesses_per_window=500_000,
                               name="xsbench"),
    ]


def run(csv: Csv, windows: int = 24) -> None:
    for wl in workloads():
        for cfg in CONFIGS:
            mgr = make_manager(cfg, wl.n_regions, thresholds=THRESHOLDS)
            t0 = time.perf_counter()
            r = simulator.simulate(wl, mgr, windows=windows, seed=1)
            wall = (time.perf_counter() - t0) * 1e6 / windows
            csv.add(
                f"{wl.name}-{cfg}",
                wall,
                f"slowdown_pct={r.slowdown_pct:.2f};tco_savings_pct={r.tco_savings_pct:.2f}",
            )

    # Planner-driven frontier points (the alpha-sweep half of the figure).
    from benchmarks import capacity_frontier

    t0 = time.perf_counter()
    res = capacity_frontier.sweep()
    wall = (time.perf_counter() - t0) * 1e6 / max(len(res["points"]), 1)
    for p in res["frontier"]:
        csv.add(
            f"frontier-{p['config']}",
            wall,
            f"servers={p['servers']};savings_pct={p['savings_pct']:.2f};"
            f"p99_penalty_s={p['p99_penalty_s']:.4f};"
            f"perf_per_dollar={p['perf_per_dollar']:.1f}",
        )


def main() -> None:
    csv = Csv("fig8")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
