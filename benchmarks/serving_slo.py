"""Serving-frontend SLO benchmark: skew-flip + burst arrivals through the
``ContinuousScheduler`` over two tiered-engine replicas.

The trace is the placement benchmarks' skew-flip pattern expressed as
arrival skew (tenant mix flips mid-trace) plus periodic interactive bursts
pinned to the tight-TTFT class — the trigger for preemption-to-host-tier.
Reports per-class TTFT/TBT p50/p99, queue delay, preemption rate and the
zero-re-prefill contract.

Rows: ``serving_slo/<class>`` per SLA class and a ``summary`` row. The
committed baseline (``baselines/serving_slo.json``) is guarded by
``baseline_guard.check_serving_slo``: the schedule must replay
deterministically (two fresh runs emit the identical summary), resumed
requests must re-prefill ZERO tokens while preemption actually fires, and
interactive p99 TTFT must stay inside the SLO ceiling.
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import Csv

# Virtual-time knobs (one unit = one decode step).
N_REPLICAS = 2
BATCH_SLOTS = 2
PAGE_TOKENS = 8
MAX_SEQ = 96
RECENT = 16
WINDOW_STEPS = 16
PREFILL_CHUNK = 8
TRACE_STEPS = 60
SEED = 3
MAX_STEPS = 600
# Interactive p99 TTFT ceiling in steps (the "p99 TTFT bounded" guard): 3x
# the class SLO target — burst arrivals may queue one generation's worth.
TTFT_P99_CEILING = 72.0


def _engines():
    import jax

    from repro.configs import qwen1_5_4b
    from repro.configs.base import TierScapeRunConfig
    from repro.models.transformer import Model
    from repro.serving.engine import TieredEngine

    cfg = qwen1_5_4b.SMOKE
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engines = []
    for _ in range(N_REPLICAS):
        ts = TierScapeRunConfig(
            enabled=True, policy="analytical", window_steps=WINDOW_STEPS
        )
        engines.append(TieredEngine(
            model, params, batch_slots=BATCH_SLOTS, page_tokens=PAGE_TOKENS,
            max_seq_len=MAX_SEQ, recent_window=RECENT, ts=ts,
        ))
    return cfg, engines


def trace_config():
    from repro.frontend import TraceConfig

    return TraceConfig(
        kind="burst", steps=TRACE_STEPS, rate=0.10, seed=SEED,
        sla_mix=(0.85, 0.15), burst_every=24, burst_len=4, burst_mult=8.0,
        burst_sla=1, prompt_len=(10, 18), new_tokens=(8, 14),
        n_tenants=2, tenant_mix=(0.8, 0.2), tenant_flip_step=TRACE_STEPS // 2,
    )


def simulate() -> dict:
    """One full frontend run; returns the canonical summary dict."""
    from repro.frontend import ContinuousScheduler, generate

    cfg, engines = _engines()
    events = generate(trace_config())
    sched = ContinuousScheduler(
        engines, events, cfg.vocab_size, prefill_chunk_tokens=PREFILL_CHUNK
    )
    stats = sched.run(max_steps=MAX_STEPS)
    summary = stats.summary()
    summary["arrivals"] = len(events)
    summary["demand_windows"] = len(stats.demand_windows)
    return summary


def run(csv: Csv, results: dict | None = None) -> None:
    t0 = time.perf_counter()
    cur = simulate()
    # Deterministic-replay probe: a second fresh run (new engines, new
    # scheduler, same trace config) must emit the identical summary.
    rep = simulate()
    wall = (time.perf_counter() - t0) * 1e6 / 2
    cur["reproducible"] = (
        json.dumps(cur, sort_keys=True) == json.dumps(rep, sort_keys=True)
    )

    for name in ("batch", "interactive"):
        c = cur[name]
        csv.add(
            name,
            wall,
            f"completed={c['completed']};ttft_p50={c['ttft_p50']};"
            f"ttft_p99={c['ttft_p99']};tbt_p99={c['tbt_p99']};"
            f"slo_hit={c['ttft_slo_hit_rate']}",
        )
    csv.add(
        "summary",
        wall,
        f"completed={cur['completed']};refused={cur['refused']};"
        f"preemptions={cur['preemptions']};resumes={cur['resumes']};"
        f"re_prefill_tokens={cur['re_prefill_tokens']};"
        f"reproducible={cur['reproducible']}",
    )
    if results is not None:
        results.update(cur)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="dump metrics for CI")
    args = ap.parse_args()
    csv = Csv("serving_slo")
    results: dict = {}
    run(csv, results)
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
