"""Media-pipeline benchmark: decode/migration overlap on a real engine.

Runs the same traffic twice through the tiered serving engine — once with
the blocking window-boundary executor (the serial oracle), once with the
async double-buffered media pipeline — and reports:

  * overlap efficiency — decode steps retired while a migration cohort was
    in flight, per pipeline-busy tick (serial mode is 0 by construction:
    the boundary blocks until the plan finishes),
  * final-placement equivalence — the async schedule must land every page
    exactly where the serial oracle does (bit-identical ``physical``),
  * per-device bandwidth charges — the window TCO report's media column
    (modeled) and the pipeline's executed busy time per device.

Rows: ``media/overlap`` and ``media/<device>`` charges. CLI: ``--json PATH``
dumps the overlap metrics for the CI perf guard
(``benchmarks/check_media_baseline.py``).
"""

from __future__ import annotations

import argparse
import json

import numpy as np

import jax

from benchmarks.common import Csv
from repro.configs.base import ModelConfig, TierScapeRunConfig
from repro.models import Model
from repro.serving import TieredEngine

CFG = ModelConfig(
    name="bench", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
)

# Prompts long enough that prefill pages out compressible history, decode
# short enough that no further page-outs interleave with in-flight cohorts
# (so serial and async dynamics stay comparable step-for-step).
PROMPT_TOKENS = 48
MAX_STEPS = 14
WINDOW_STEPS = 4


def _run(model, params, async_migration: bool) -> TieredEngine:
    eng = TieredEngine(
        model, params, batch_slots=2, page_tokens=8, max_seq_len=128,
        recent_window=32,
        ts=TierScapeRunConfig(
            # alpha=0 (max TCO savings) guarantees the plan demotes through
            # the host swap device: the decode step now emits LIVE hotness
            # (fused-attention telemetry), so a mid-alpha model keeps this
            # tiny hot working set device-resident and would give the
            # pipeline nothing to overlap.
            enabled=True, policy="analytical", alpha=0.0,
            window_steps=WINDOW_STEPS, async_migration=async_migration,
            # This benchmark isolates demand-path overlap; speculative
            # prefetch (on by default elsewhere) would bill extra reads on
            # the queues being measured. prefetch_hitrate covers it.
            prefetch=False,
        ),
    )
    rng = np.random.default_rng(0)
    for _ in range(2):
        eng.submit(rng.integers(1, CFG.vocab_size, PROMPT_TOKENS), max_new_tokens=1000)
    eng.run(max_steps=MAX_STEPS)
    return eng


def run(csv: Csv, results: dict | None = None) -> None:
    model = Model(CFG)
    params = model.init(jax.random.PRNGKey(0))

    serial = _run(model, params, async_migration=False)
    asyn = _run(model, params, async_migration=True)

    assert serial.stats.overlapped_steps == 0  # blocking boundary: no overlap
    assert asyn.stats.migrations > 0, "no migration cohort was ever queued"
    assert asyn.stats.overlapped_steps > 0, "async pipeline never overlapped"
    identical = bool(np.array_equal(serial.cache.physical, asyn.cache.physical))
    assert identical, "async final placements diverged from the serial oracle"

    busy_ticks = asyn.cache.pipeline.busy_ticks
    efficiency = asyn.stats.overlapped_steps / max(busy_ticks, 1)

    # Window TCO report: modeled per-device charges, summed over windows.
    modeled: dict[str, int] = {}
    for ws in asyn.cache.manager.history:
        for dev, b in ws.media_bytes_by_device.items():
            modeled[dev] = modeled.get(dev, 0) + int(b)
    executed = asyn.cache.pipeline.media_busy_s()
    host_bytes = int(asyn.cache.pipeline.media_bytes().get("host_dram_pcie", 0))
    assert modeled, "window TCO report carried no media charges"

    csv.add(
        "overlap", 0.0,
        f"overlapped_steps={asyn.stats.overlapped_steps} "
        f"busy_ticks={busy_ticks} efficiency={efficiency:.2f} "
        f"migrations={asyn.stats.migrations} "
        f"placements_identical={identical}",
    )
    for dev in sorted(set(modeled) | set(executed)):
        csv.add(
            dev, executed.get(dev, 0.0) * 1e6,
            f"modeled_bytes={modeled.get(dev, 0)} "
            f"executed_busy_us={executed.get(dev, 0.0) * 1e6:.2f}",
        )
    if results is not None:
        results["overlap"] = {
            "overlapped_steps": int(asyn.stats.overlapped_steps),
            "busy_ticks": int(busy_ticks),
            "overlap_efficiency": float(efficiency),
            "placements_identical": identical,
            "host_bytes": host_bytes,
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="dump overlap metrics for CI")
    args = ap.parse_args()
    csv = Csv("media")
    results: dict = {}
    run(csv, results)
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
