"""Roofline summary over the dry-run artifacts (EXPERIMENTS.md §Roofline
reads from the same JSONs; this prints the CSV form)."""

from __future__ import annotations

import glob
import json

from benchmarks.common import Csv


def run(csv: Csv, pattern: str = "experiments/dryrun/*.json") -> None:
    files = sorted(glob.glob(pattern))
    if not files:
        csv.add("no-dryrun-artifacts", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        d = json.load(open(f))
        step_s = max(d["compute_s"], d["memory_s"], d["collective_s"])
        frac = 0.0
        if step_s > 0:
            frac = d["model_flops"] / d["chips"] / step_s / 197e12
        csv.add(
            f"{d['arch']}-{d['shape']}-{d['mesh']}",
            step_s * 1e6,
            f"bottleneck={d['bottleneck']};useful={d['useful_ratio']:.3f};"
            f"roofline_frac={frac:.4f};fits={d['fits_hbm']}",
        )


def main() -> None:
    csv = Csv("roofline")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
