"""Paper Fig. 13: TS-Daemon CPU tax (telemetry + model + migration) per
workload and model, as % of runtime."""

from __future__ import annotations

from benchmarks.common import Csv
from repro.core import simulator
from repro.core.manager import make_manager
from benchmarks.fig8_frontier import THRESHOLDS, workloads


def run(csv: Csv, windows: int = 16) -> None:
    for wl in workloads():
        for cfg in ("2T-M", "6T-WF-M", "6T-AM-0.5"):
            mgr = make_manager(cfg, wl.n_regions, thresholds=THRESHOLDS)
            r = simulator.simulate(wl, mgr, windows=windows, seed=1)
            csv.add(
                f"{wl.name}-{cfg}", mgr.total_daemon_s / windows * 1e6,
                f"tax_pct={r.daemon_tax_pct:.2f} "
                f"migr_per_win={r.mean_migrations_per_window:.1f} "
                f"cohorts_per_win={r.mean_cohorts_per_window:.1f}",
            )


def main() -> None:
    csv = Csv("fig13")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
