"""Migration-executor microbenchmark: per-page loop vs batched cohorts.

Reproduces the PR's headline claim on a real TieredKVCache: at 256+ migrated
pages per window, the batched executor needs >= 5x fewer compute-kernel
dispatches (quant / dequant / transcode launches) than the per-page loop —
O(cohorts) instead of O(pages) — and correspondingly less wall time.

Rows: ``migration/<n_pages>p-<route>`` with us_per_call = batched wall time,
derived = dispatch counts + speedup.

CLI: ``--sizes 64,128`` picks the page counts (CI runs small shapes) and
``--json PATH`` dumps the dispatch counts for the perf-guard baseline check
(``benchmarks/check_dispatch_baseline.py``).
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.configs.base import ModelConfig
from repro.core.manager import ManagerConfig
from repro.serving.kv_cache import COLD, HOST4, TieredKVCache

CFG = ModelConfig(
    name="bench", family="dense", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
)


def _make_cache(n_pages: int) -> TieredKVCache:
    slots = 4
    page_tokens = 8
    layers = 4
    max_seq = page_tokens * (n_pages // (layers * slots))
    cache = TieredKVCache(
        CFG, layers, slots, page_tokens, max_seq, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=0.5), warm_frac=1.0,
    )
    assert cache.n_regions == n_pages
    rng = np.random.default_rng(0)
    coords = [
        (la, sl, pg)
        for la in range(layers) for sl in range(slots)
        for pg in range(cache.max_pages)
    ]
    kv, hd = CFG.n_kv_heads, CFG.head_dim_()
    k = rng.normal(0, 1, (n_pages, page_tokens, kv, hd)).astype(np.float32)
    cache.append_pages(coords, jnp.asarray(k), jnp.asarray(k * 0.3))
    return cache


def _plan(cache: TieredKVCache):
    """Demote every warm page: 3/4 to the cold pool, 1/4 to the int4 host
    tier (two cohorts -> two batched dispatches vs 4 per page in the loop)."""
    rids = np.where(cache._page_exists)[0]
    dsts = np.where(np.arange(rids.size) % 4 == 3, HOST4, COLD).astype(np.int64)
    return rids, dsts


def run(csv: Csv, sizes=(256, 512), results: dict | None = None) -> None:
    for n in sizes:
        per_page_cache = _make_cache(n)
        rids, dsts = _plan(per_page_cache)
        per_page_cache.kernel_dispatches = 0
        t0 = time.perf_counter()
        for rid, dst in zip(rids, dsts):
            per_page_cache.migrate(int(rid), int(dst))
        loop_s = time.perf_counter() - t0
        loop_disp = per_page_cache.kernel_dispatches

        batched_cache = _make_cache(n)
        rids, dsts = _plan(batched_cache)
        batched_cache.kernel_dispatches = 0
        t0 = time.perf_counter()
        batched_cache.migrate_batch(rids, dsts)
        batch_s = time.perf_counter() - t0
        batch_disp = batched_cache.kernel_dispatches

        assert batch_disp * 5 <= loop_disp, (batch_disp, loop_disp)
        if results is not None:
            results[str(n)] = {
                "dispatches_loop": int(loop_disp),
                "dispatches_batched": int(batch_disp),
                "dispatch_ratio": loop_disp / max(batch_disp, 1),
            }
        csv.add(
            f"{n}p-warm_to_cold_host", batch_s * 1e6,
            f"dispatches_loop={loop_disp} dispatches_batched={batch_disp} "
            f"dispatch_ratio={loop_disp / max(batch_disp, 1):.1f}x "
            f"time_loop_us={loop_s * 1e6:.0f} speedup={loop_s / max(batch_s, 1e-12):.1f}x",
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="256,512",
                    help="comma-separated migrated-page counts")
    ap.add_argument("--json", default=None,
                    help="write dispatch counts to this path (perf-guard)")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    csv = Csv("migration")
    results: dict = {}
    run(csv, sizes=sizes, results=results)
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
