"""Planner-driven perf-per-dollar frontier on the skew-flip multi-tenant mix.

The paper's headline metric (memory-TCO savings at performance parity, §1 /
Eq. 9-12) priced at the fleet level: every searched tier configuration (2T
production baseline, the 6T alpha ladder, the warm/cold codec-split family)
runs through ``simulate_multitenant`` on the skew-flip mix, the arbiter's
``fleet_report()`` feeds the ``CapacityPlanner``, and the planner bin-packs
tenant footprints + decode demand onto ``v5e-base`` servers to emit servers
needed, amortized fleet dollars, savings % vs an all-DRAM-provisioned fleet,
and p50/p99 latency proxies.

Rows: ``capacity/point-<config>`` for every searched point and
``capacity/frontier-<config>`` for the Pareto-optimal subset; a ``-summary``
row carries monotonicity / 2T-dominance / reproducibility. The committed
baseline (``baselines/capacity_frontier.json``) is guarded by
``baseline_guard.check_capacity_frontier``: the frontier must stay monotone,
keep dominating the 2-tier baseline by the paper's margin, and the whole
sweep must be bit-reproducible (two passes emit identical JSON).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List

from benchmarks.common import Csv
from repro.core import capacity, simulator
from repro.core.arbiter import TenantSpec
from repro.core.simulator import Workload

N_REGIONS = 512
ACCESSES = 200_000
WINDOWS = 16
WARMUP = 2
FLIP_WINDOW = 8
SERVER = "v5e-base"
OPERATING_YEARS = 3.0
FLEET_SCALE = 256
SEED = 0


def skewflip_workloads() -> List[Workload]:
    """The skew-flip mix: two tenants whose hotness swaps mid-run — the
    scenario where a static tier split is wrong half the time and the
    arbiter + planner have to earn their keep."""
    return [
        simulator.skew_flip(n_regions=N_REGIONS, accesses_hot=ACCESSES,
                            accesses_cold=ACCESSES // 10,
                            flip_window=FLIP_WINDOW, hot_first=True,
                            name="early"),
        simulator.skew_flip(n_regions=N_REGIONS, accesses_hot=ACCESSES,
                            accesses_cold=ACCESSES // 10,
                            flip_window=FLIP_WINDOW, hot_first=False,
                            name="late"),
    ]


def skewflip_specs() -> List[TenantSpec]:
    return [TenantSpec("early", sla_weight=1.0),
            TenantSpec("late", sla_weight=1.0)]


def sweep(windows: int = WINDOWS, seed: int = SEED) -> dict:
    planner = capacity.CapacityPlanner(
        capacity.get_server(SERVER),
        operating_period_years=OPERATING_YEARS,
        fleet_scale=FLEET_SCALE,
    )
    return capacity.sweep_frontier(
        skewflip_workloads, skewflip_specs(), planner,
        windows=windows, warmup_windows=WARMUP, seed=seed,
    )


def run(csv: Csv, results: dict | None = None, windows: int = WINDOWS) -> None:
    t0 = time.perf_counter()
    res = sweep(windows=windows)
    wall = (time.perf_counter() - t0) * 1e6 / max(len(res["points"]), 1)
    # Bit-reproducibility probe: the same grid on the same seed must emit
    # the identical frontier JSON (the CI guard's determinism contract).
    res["reproducible"] = capacity.frontier_json(res) == capacity.frontier_json(
        sweep(windows=windows)
    )

    frontier_configs = {p["config"] for p in res["frontier"]}
    for p in res["points"]:
        kind = "frontier" if p["config"] in frontier_configs else "point"
        csv.add(
            f"{kind}-{p['config']}",
            wall,
            f"servers={p['servers']};fleet_usd={p['fleet_usd']:.0f};"
            f"savings_pct={p['savings_pct']:.2f};"
            f"p99_penalty_s={p['p99_penalty_s']:.4f}",
        )
    csv.add(
        "summary",
        wall,
        f"monotone={res['monotone']};dominates_2t={res.get('dominates_2t')};"
        f"margin_pct={res.get('dominance_margin_pct'):.2f};"
        f"reproducible={res['reproducible']}",
    )
    if results is not None:
        results.update(res)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="dump metrics for CI")
    args = ap.parse_args()
    csv = Csv("capacity")
    results: dict = {}
    run(csv, results)
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
