"""Prefetch/readahead benchmark: hide swap-in latency behind decode.

Drives a ``TieredKVCache`` (async media pipeline) through a skew-flip
workload — hot set A on device tiers, cold set B demoted to the int4 host
tier, then the skew flips and B ramps hot — twice: once with the
warming-page predictor + speculative staging enabled, once reactive-only
(the no-prefetch oracle). Reports:

  * prefetch hit rate — staged pages the boundary plan then moved (their
    demand stage pays no host read) over everything staged,
  * decode-visible swap-in stall — source-read service time paid at window
    boundaries for host-media demand stages (``pipeline.demand_swapin_s``);
    prefetch must strictly reduce it,
  * placement equivalence — final ``physical`` must be bit-identical to the
    no-prefetch oracle (speculation hides latency, never changes policy),
  * mispredict billing — speculative bytes/busy time billed on the shared
    device queues whether or not the prediction landed.

Rows: ``prefetch/overlap`` plus per-device speculative charges. CLI:
``--json PATH`` dumps the metrics for the consolidated CI perf guard
(``benchmarks/run.py --check-baselines`` vs
``benchmarks/baselines/prefetch_hitrate.json``).
"""

from __future__ import annotations

import argparse
import json

import numpy as np

import jax.numpy as jnp

from benchmarks.common import Csv
from repro.configs.base import ModelConfig
from repro.core.manager import ManagerConfig
from repro.serving.kv_cache import WARM, TieredKVCache

CFG = ModelConfig(
    name="bench", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
)

# Skew-flip schedule (per-window access counts for sets A and B): A hot
# while B idles in the host tier, then the skew flips and B ramps. Mirrors
# ``simulator.skew_flip`` at cache scale.
SCHEDULE = [
    (600, 0), (600, 0), (600, 60), (600, 240), (30, 600), (10, 600), (5, 600),
]
TICKS_PER_WINDOW = 10  # simulated decode steps between boundaries


def _make_cache(prefetch: bool) -> TieredKVCache:
    cache = TieredKVCache(
        CFG, 2, 2, 8, 128, recent_window=16,
        manager_cfg=ManagerConfig(policy="analytical", alpha=0.4),
        warm_frac=0.5, async_migration=True, ring_slots=64,
        prefetch=prefetch, prefetch_max_pages=16,
    )
    rng = np.random.default_rng(0)
    coords = [
        (la, sl, pg)
        for la in range(cache.la) for sl in range(cache.bs)
        for pg in range(cache.max_pages)
    ]
    k = rng.normal(0, 1, (len(coords), cache.pt, CFG.n_kv_heads, CFG.head_dim_()))
    k = k.astype(np.float32)
    cache.append_pages(coords, jnp.asarray(k), jnp.asarray(k * 0.3))
    return cache


def _drive(prefetch: bool) -> TieredKVCache:
    cache = _make_cache(prefetch)
    set_a = np.where(cache.physical == WARM)[0]  # landed fast at ingest
    set_b = np.setdiff1d(np.where(cache._page_exists)[0], set_a)
    for hot_a, hot_b in SCHEDULE:
        counts = np.zeros(cache.n_regions)
        counts[set_a] = hot_a
        counts[set_b] = hot_b
        cache.manager.record_access_counts(counts)
        # Mid-window decode steps: demand cohorts tick first, idle steps go
        # to speculative staging (exactly the engine's decode loop).
        for _ in range(TICKS_PER_WINDOW):
            if cache.pipeline.busy:
                cache.pipeline.tick()
            else:
                cache.prefetch_tick()
        cache.end_window()
        cache.drain_migrations()
    return cache


def run(csv: Csv, results: dict | None = None) -> None:
    # No hard asserts here: regressions must surface through the
    # consolidated perf guard's checks (baseline_guard.check_prefetch), not
    # abort the whole benchmark suite mid-run.
    reactive = _drive(prefetch=False)
    spec = _drive(prefetch=True)

    pipe = spec.pipeline
    identical = bool(np.array_equal(reactive.physical, spec.physical))
    stall_spec = pipe.demand_swapin_s
    stall_reactive = reactive.pipeline.demand_swapin_s
    hit_rate = pipe.prefetch_hit_rate()

    csv.add(
        "overlap", stall_spec * 1e6,
        f"hit_rate={hit_rate:.2f} staged={pipe.prefetch_staged} "
        f"hits={pipe.prefetch_hits} misses={pipe.prefetch_misses} "
        f"stall_reactive_us={stall_reactive * 1e6:.1f} "
        f"stall_prefetch_us={stall_spec * 1e6:.1f} "
        f"placements_identical={identical}",
    )
    for dev, read_s in sorted(pipe.prefetch_read_s_by_device.items()):
        csv.add(
            f"spec-{dev}", read_s * 1e6,
            f"speculative_bytes={pipe.prefetch_bytes_by_device[dev]} "
            f"(billed, hits and misses alike)",
        )
    if results is not None:
        results["prefetch"] = {
            "hit_rate": float(hit_rate),
            "pages_prefetched": int(pipe.prefetch_staged),
            "hits": int(pipe.prefetch_hits),
            "misses": int(pipe.prefetch_misses),
            "stall_s_reactive": float(stall_reactive),
            "stall_s_prefetch": float(stall_spec),
            "stall_reduced": bool(stall_spec < stall_reactive),
            "placements_identical": identical,
            "speculative_bytes": int(pipe.prefetch_bytes),
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="dump metrics for CI")
    args = ap.parse_args()
    csv = Csv("prefetch")
    results: dict = {}
    run(csv, results)
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
