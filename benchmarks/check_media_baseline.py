"""CI perf-guard: media-pipeline overlap metrics vs the committed baseline.

Usage: ``python benchmarks/check_media_baseline.py CURRENT.json BASELINE.json``

Fails (exit 1) when:
  * async final placements are not bit-identical to the serial oracle
    (correctness, exact — no tolerance),
  * no decode step was retired during an in-flight migration cohort
    (the overlap headline regressed to zero),
  * no bytes transited the host swap device (the staging ring fell out of
    the data path),
  * overlap efficiency fell more than 0.25 below the committed baseline
    (a band, because hotness-driven plan sizes may drift a little across
    platforms/jax versions; structural regressions blow well through it).

Refresh the baseline with ``media_pipeline.py --json`` and commit.
"""

from __future__ import annotations

import json
import sys

EFFICIENCY_BAND = 0.25


def check(current: dict, baseline: dict) -> list[str]:
    errors = []
    cur = current.get("overlap")
    base = baseline.get("overlap")
    if cur is None or base is None:
        return ["missing 'overlap' section in current or baseline results"]
    if not cur.get("placements_identical", False):
        errors.append("async placements diverged from the serial oracle")
    if cur.get("overlapped_steps", 0) < 1:
        errors.append("no decode steps retired during migration (overlap=0)")
    if cur.get("host_bytes", 0) <= 0:
        errors.append("no bytes transited the host swap device")
    floor = base["overlap_efficiency"] - EFFICIENCY_BAND
    if cur.get("overlap_efficiency", 0.0) < floor:
        errors.append(
            f"overlap efficiency regressed: {cur.get('overlap_efficiency'):.2f} "
            f"< baseline {base['overlap_efficiency']:.2f} - {EFFICIENCY_BAND}"
        )
    return errors


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        current = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)
    errors = check(current, baseline)
    if errors:
        print("media-pipeline regression vs baseline:")
        for e in errors:
            print(f"  {e}")
        return 1
    cur, base = current["overlap"], baseline["overlap"]
    print(
        f"overlap: steps={cur['overlapped_steps']} "
        f"efficiency={cur['overlap_efficiency']:.2f} "
        f"(baseline {base['overlap_efficiency']:.2f}) "
        f"identical={cur['placements_identical']} "
        f"host_bytes={cur['host_bytes']} — OK"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
