"""Single-launch fused decode attention vs the per-pool launch loop.

The paper's trade-off only holds if the compressed-tier access path stays
cheap as tiers are added; the per-pool path pays one Pallas launch per tier
pool per decode step, so tier count taxes decode latency. The fused
megakernel walks a unified page table in ONE launch regardless of tier
count (host sentinel rows ride along for free).

Pools are laid out codec-class-major, mirroring ``TieredKVCache``: every
pool of one codec width aliases ONE shared class buffer and its page table
holds global class rows, so the fused operand assembly is pure table
work — the per-step device-copy-bytes counter (``ops.concat_copy_bytes``)
must read ZERO at every tier count.

Rows: ``decode_fused/<n>t-{fused|perpool}`` with us_per_call = eager step
wall time (interpret-mode Pallas; directional), derived = launches/step +
max |fused - oracle| over outputs and normalized hotness.

``--json PATH`` dumps {n_tiers: {launches_fused, launches_per_pool,
out_max_err, hot_max_err, outputs_match, concat_copy_bytes}} for the
perf-guard baseline (``benchmarks/baseline_guard.py``: fused launches/step
must be exactly 1 and concat copy-bytes exactly 0 at every tier count, and
outputs must match the per-pool oracle).
"""

from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_us
from repro.kernels import ops, ref

B, H, KV, HD, T, MP, R = 2, 8, 2, 32, 8, 4, 8
# Tier pools alternate codec widths so every fused launch exercises both
# in-kernel dequant paths once >= 2 tiers are present.
TIER_BITS = (8, 4, 8, 4)
FP32_TOL = 2e-4


def _make_pools(n_tiers: int, rng: np.random.Generator):
    """Codec-class-major pools: one shared payload/scale buffer per codec
    width; each tier owns a contiguous global-row range of its class buffer
    and its page table addresses those global rows directly."""
    bits_of = TIER_BITS[:n_tiers]
    # One class buffer per codec width, tall enough for every tier's range.
    buf = {}
    for bits in sorted(set(bits_of)):
        rows = MP * B * bits_of.count(bits)
        pages = jnp.asarray(rng.normal(0, 1, (rows, T, KV, HD)), jnp.bfloat16)
        kp, ks = ref.quant_kv_page(pages, bits)
        vp, vs = ref.quant_kv_page(pages * 0.5, bits)
        buf[bits] = dict(k_pages=kp, k_scales=ks, v_pages=vp, v_scales=vs)
    pools = {}
    base = {bits: 0 for bits in buf}
    for i, bits in enumerate(bits_of):
        table = jnp.asarray(
            base[bits] + rng.integers(0, MP * B, (B, MP)), jnp.int32
        )
        base[bits] += MP * B
        pools[f"tier{i}"] = dict(
            **buf[bits],  # aliases the shared class buffer (zero-copy fuse)
            page_table=table,
            n_pages=jnp.asarray(rng.integers(1, MP + 1, B), jnp.int32),
            bits=bits,
        )
    return pools


def _make_host(rng: np.random.Generator):
    hs = 6
    return dict(
        summary=jnp.asarray(rng.normal(0, 1, (hs, KV, HD)), jnp.float32),
        table=jnp.asarray(rng.integers(0, hs, (B, MP)), jnp.int32),
        n=jnp.asarray(rng.integers(1, MP + 1, B), jnp.int32),
        page_tokens=T,
    )


def run(csv: Csv, tier_counts=(2, 3, 4), results: dict | None = None) -> None:
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, H, HD)), jnp.float32)
    recent_k = jnp.asarray(rng.normal(0, 1, (B, R, KV, HD)), jnp.bfloat16)
    recent_v = jnp.asarray(rng.normal(0, 1, (B, R, KV, HD)), jnp.bfloat16)
    rlen = jnp.asarray([R, R // 2], jnp.int32)

    for n in tier_counts:
        pools = _make_pools(n, rng)
        host = _make_host(rng)

        def step(telemetry=True):
            return ops.tiered_decode_attention(
                q, pools, recent_k, recent_v, rlen,
                with_telemetry=telemetry, host=host,
            )

        ops.use_fused(True)
        ops.reset_launch_count()
        ops.reset_copy_bytes()
        out_f, hot_f = step()
        launches_fused = ops.launch_count()
        copy_bytes = ops.concat_copy_bytes()
        fused_us = time_us(lambda: step(False).block_until_ready(), iters=3, warmup=1)

        ops.use_fused(False)
        ops.reset_launch_count()
        out_p, hot_p = step()
        launches_pp = ops.launch_count()
        pp_us = time_us(lambda: step(False).block_until_ready(), iters=3, warmup=1)
        ops.use_fused(True)

        out_err = float(jnp.max(jnp.abs(out_f - out_p)))
        hot_err = max(
            float(jnp.max(jnp.abs(hot_f[k] - hot_p[k]))) for k in hot_f
        )
        match = out_err <= FP32_TOL and hot_err <= FP32_TOL
        csv.add(
            f"{n}t-fused", fused_us,
            f"launches={launches_fused};copy_bytes={copy_bytes};"
            f"out_err={out_err:.1e};hot_err={hot_err:.1e}",
        )
        csv.add(f"{n}t-perpool", pp_us, f"launches={launches_pp}")
        if results is not None:
            results[str(n)] = {
                "launches_fused": launches_fused,
                "launches_per_pool": launches_pp,
                "out_max_err": out_err,
                "hot_max_err": hot_err,
                "outputs_match": match,
                "concat_copy_bytes": copy_bytes,
            }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiers", default="2,3,4", help="comma-separated tier counts")
    ap.add_argument("--json", default=None, help="dump guard metrics to PATH")
    args = ap.parse_args()
    csv = Csv("decode_fused")
    results: dict = {}
    run(csv, tier_counts=tuple(int(x) for x in args.tiers.split(",")), results=results)
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
