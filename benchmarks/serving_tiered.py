"""Serving-engine benchmark (ours; the paper's technique live on a model):
tiered-KV engine vs dense-KV decoding on a smoke-scale arch — decode step
wall time (CPU-directional), KV HBM bytes, TCO savings, output fidelity."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, time_us
import repro.configs as configs
from repro.configs.base import TierScapeRunConfig
from repro.models import Model
from repro.serving import TieredEngine


def run(csv: Csv) -> None:
    cfg = configs.get_smoke("zamba2_1_2b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, 48)

    # Dense reference decode.
    state = model.init_cache(1, 96)
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    logits, state = model.prefill(params, batch, state)
    step = jax.jit(model.decode_step)
    tok = jnp.asarray([[int(jnp.argmax(logits[0, -1]))]], jnp.int32)
    lg, state2 = step(params, tok, state)  # warm
    dense_us = time_us(lambda: jax.block_until_ready(step(params, tok, state)[0]), iters=5)
    dense_bytes = state.k_cache.size * 2 * 2
    csv.add("dense-decode", dense_us, f"kv_bytes={dense_bytes}")

    for alpha in (0.5, 0.1):
        # Runs the async-migration default: window boundaries submit cohorts
        # and return, decode steps tick them, and run() drains stragglers —
        # so decode_s/steps prices the overlapped path, not a blocked
        # boundary, and stats.migrations still counts every page moved.
        eng = TieredEngine(
            model, params, batch_slots=1, page_tokens=8, max_seq_len=96,
            recent_window=16,
            ts=TierScapeRunConfig(enabled=True, policy="analytical", alpha=alpha,
                                  window_steps=8),
        )
        eng.submit(prompt, max_new_tokens=24)
        stats = eng.run(max_steps=32)
        csv.add(
            f"tiered-decode-a{alpha}",
            stats.decode_s / max(stats.steps, 1) * 1e6,
            f"peak_tco_savings_pct={stats.tco_savings_pct:.1f};"
            f"hbm_bytes={eng.cache.hbm_bytes()};migrations={stats.migrations};"
            f"daemon_s={stats.daemon_s:.2f};"
            f"attn_launches_per_step={stats.attn_launches / max(stats.steps, 1):.0f}",
        )


def main() -> None:
    csv = Csv("serving")
    run(csv)
    csv.emit()


if __name__ == "__main__":
    main()
