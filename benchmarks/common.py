"""Shared benchmark utilities: timing and CSV emission."""

from __future__ import annotations

import time
from typing import Callable, List


def time_us(fn: Callable, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


class Csv:
    """Collects ``name,us_per_call,derived`` rows and prints them."""

    def __init__(self, table: str):
        self.table = table
        self.rows: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str) -> None:
        self.rows.append(f"{self.table}/{name},{us_per_call:.2f},{derived}")

    def emit(self) -> None:
        for r in self.rows:
            print(r)
