"""Benchmark harness — one table per paper figure. Prints
``name,us_per_call,derived`` CSV rows.

  fig3       tier characterization (latency/ratio/cost/error x 2 datasets)
  fig8       2T vs 6T-WF per workload + planner-driven frontier points
  capacity   fleet capacity planner: perf-per-dollar frontier (skew-flip mix)
  cxl        hardware-compressed CXL tier frontier (compressible vs not mix)
  fig9_10_11 placement distributions + TCO timeline
  fig12      tail latency (mean + p99)
  fig13      daemon tax
  serving    tiered-KV engine vs dense decode on a real model
  serving_slo  SLA frontend: TTFT/TBT percentiles + preemption-to-host-tier
  decode_fused  single-launch fused attention vs per-pool loop (launches/step)
  migration  batched cohort executor vs per-page loop (dispatches + time)
  media      async media pipeline: decode/migration overlap + device charges
  prefetch   speculative readahead: hit rate + swap-in stall reduction
  multitenant  N tenants sharing pools under the BudgetArbiter (6T vs 2T)
  roofline   per-(arch x shape x mesh) dry-run roofline summary

``--check-baselines`` runs the consolidated perf-guard matrix instead
(``benchmarks/baseline_guard.py``): every registered benchmark is compared
against its committed baseline under ``benchmarks/baselines/`` and the
process exits non-zero on any regression — the single CI perf-guard step.
"""

from __future__ import annotations

import argparse

from benchmarks.common import Csv
from benchmarks import (
    capacity_frontier,
    cxl_frontier,
    decode_fused,
    fig3_characterization,
    fig8_frontier,
    fig9_placement,
    fig12_tail_latency,
    fig13_daemon_tax,
    media_pipeline,
    migration_batch,
    multitenant,
    prefetch_hitrate,
    roofline_report,
    serving_slo,
    serving_tiered,
)

TABLES = {
    "fig3": fig3_characterization.run,
    "fig8": fig8_frontier.run,
    "capacity": capacity_frontier.run,
    "cxl": cxl_frontier.run,
    "fig9_10_11": fig9_placement.run,
    "fig12": fig12_tail_latency.run,
    "fig13": fig13_daemon_tax.run,
    "serving": serving_tiered.run,
    "serving_slo": serving_slo.run,
    "decode_fused": decode_fused.run,
    "migration": migration_batch.run,
    "media": media_pipeline.run,
    "prefetch": prefetch_hitrate.run,
    "multitenant": multitenant.run,
    "roofline": roofline_report.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated table names")
    ap.add_argument(
        "--check-baselines", action="store_true",
        help="run the consolidated perf-guard matrix vs benchmarks/baselines/ "
             "and exit non-zero on regression",
    )
    ap.add_argument(
        "--baseline-dir", default="benchmarks/baselines",
        help="baseline directory for --check-baselines",
    )
    ap.add_argument(
        "--guard-out", default=None,
        help="with --check-baselines: dump each guard's current metrics "
             "as <name>.json into this directory (the CI artifact)",
    )
    args = ap.parse_args()
    if args.check_baselines:
        from benchmarks.baseline_guard import check_baselines

        raise SystemExit(
            check_baselines(baseline_dir=args.baseline_dir, out_dir=args.guard_out)
        )
    names = args.only.split(",") if args.only else list(TABLES)
    print("name,us_per_call,derived")
    for name in names:
        csv = Csv(name)
        TABLES[name](csv)
        csv.emit()


if __name__ == "__main__":
    main()
