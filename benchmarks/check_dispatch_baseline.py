"""CI perf-guard: compare migration kernel-dispatch counts vs the committed
baseline.

Usage: ``python benchmarks/check_dispatch_baseline.py CURRENT.json BASELINE.json``

Fails (exit 1) when, for any size present in the baseline:
  * the batched executor needs MORE dispatches than the baseline (a cohort
    regression: O(cohorts) sliding back toward O(pages)), or
  * the loop/batched dispatch ratio falls below the baseline ratio (the
    headline batching win shrank).

Dispatch counts are deterministic (they count kernel launches, not time), so
comparisons are exact — no tolerance band needed. Lower batched counts than
the baseline are an improvement and pass; refresh the baseline by re-running
``migration_batch.py --json`` and committing the result.
"""

from __future__ import annotations

import json
import sys


def check(current: dict, baseline: dict) -> list[str]:
    errors = []
    for size, base in sorted(baseline.items()):
        cur = current.get(size)
        if cur is None:
            errors.append(f"size {size}: missing from current results")
            continue
        if cur["dispatches_batched"] > base["dispatches_batched"]:
            errors.append(
                f"size {size}: batched dispatches regressed "
                f"{base['dispatches_batched']} -> {cur['dispatches_batched']}"
            )
        if cur["dispatch_ratio"] < base["dispatch_ratio"]:
            errors.append(
                f"size {size}: dispatch ratio regressed "
                f"{base['dispatch_ratio']:.1f}x -> {cur['dispatch_ratio']:.1f}x"
            )
    return errors


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        current = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)
    errors = check(current, baseline)
    if errors:
        print("dispatch-count regression vs baseline:")
        for e in errors:
            print(f"  {e}")
        return 1
    for size, base in sorted(baseline.items()):
        cur = current[size]
        print(
            f"size {size}: batched={cur['dispatches_batched']} "
            f"(baseline {base['dispatches_batched']}), "
            f"ratio={cur['dispatch_ratio']:.1f}x "
            f"(baseline {base['dispatch_ratio']:.1f}x) — OK"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
