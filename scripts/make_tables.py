"""Regenerate the EXPERIMENTS.md tables from experiments/dryrun/*.json.

    PYTHONPATH=src python scripts/make_tables.py [--mesh pod16x16]
"""

import argparse
import glob
import json


def rows(mesh_filter=None):
    out = []
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        d = json.load(open(f))
        if mesh_filter and d["mesh"] != mesh_filter:
            continue
        out.append(d)
    return out


def roofline_table(mesh="pod16x16"):
    print(f"\n### Roofline — {mesh} ({256 if mesh=='pod16x16' else 512} chips)\n")
    print("| arch | shape | kind | compute s | memory s | collective s | bottleneck | "
          "MODEL_FLOPS | useful | roofline frac | fits |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for d in rows(mesh):
        step = max(d["compute_s"], d["memory_s"], d["collective_s"])
        frac = d["model_flops"] / d["chips"] / step / 197e12 if step else 0
        print(f"| {d['arch']} | {d['shape']} | {d['kind']} | {d['compute_s']:.2e} "
              f"| {d['memory_s']:.2e} | {d['collective_s']:.2e} | {d['bottleneck']} "
              f"| {d['model_flops']:.2e} | {d['useful_ratio']:.3f} | {frac:.4f} "
              f"| {'Y' if d['fits_hbm'] else 'N'} |")


def dryrun_table():
    print("\n### Dry-run memory/collective summary\n")
    print("| arch | shape | mesh | args GB | temps GB | cpu-upcast GB | "
          "coll bytes/dev | AR/AG/RS/A2A/CP GB | compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows():
        k = d["coll_by_kind"]
        kinds = "/".join(
            f"{k.get(n, 0)/2**30:.2f}"
            for n in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                      "collective-permute")
        )
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {d['args_bytes_pd']/2**30:.2f} | {d['temps_bytes_pd']/2**30:.2f} "
              f"| {d.get('cpu_upcast_bytes_pd', 0)/2**30:.2f} "
              f"| {d['coll_bytes_pd']:.2e} | {kinds} | {d.get('compile_s','-')} |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    if args.mesh:
        roofline_table(args.mesh)
    else:
        roofline_table("pod16x16")
        roofline_table("pod2x16x16")
        dryrun_table()
