"""Perf-iteration driver: rebuild one cell with overrides, lower, analyze,
and log the three roofline terms (experiments/perf/<cell>__<tag>.json).

    PYTHONPATH=src python scripts/hillclimb.py --arch qwen3_moe_235b \
        --shape train_4k --tag baseline [--accum 4] [--no-fsdp] [--kvseq] \
        [--tiered-kv] [--top-collectives]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import re
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--fsdp", dest="fsdp", action="store_true", default=None)
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--kvseq", dest="kvseq", action="store_true", default=None)
    ap.add_argument("--no-kvseq", dest="kvseq", action="store_false")
    ap.add_argument("--tiered-kv", action="store_true", default=None)
    ap.add_argument("--top-collectives", action="store_true")
    args = ap.parse_args()

    import repro.configs as configs
    from repro.configs.base import SHAPES, ParallelConfig
    from repro.launch import cells as cm
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as ra

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    chips = 512 if args.multi_pod else 256
    parallel = cm.default_parallel(configs.get(args.arch), args.shape, mesh)
    if args.accum is not None or args.fsdp is not None or args.kvseq is not None:
        import dataclasses as dc

        kw = {}
        if args.accum is not None:
            kw["grad_accum"] = args.accum
        if args.fsdp is not None:
            kw["fsdp"] = args.fsdp
        if args.kvseq is not None:
            kw["shard_kv_seq"] = args.kvseq
        parallel = dc.replace(parallel, **kw)

    t0 = time.time()
    cell = cm.build_cell(args.arch, args.shape, mesh, parallel=parallel,
                         tiered_kv=args.tiered_kv)
    compiled = cell.lower().compile()
    wall = time.time() - t0
    txt = compiled.as_text()

    cfg = configs.get(args.arch)
    mf = ra.model_flops_for(cfg, SHAPES[args.shape])
    rep = ra.analyze_compiled(compiled, args.arch, args.shape,
                              "pod2x16x16" if args.multi_pod else "pod16x16",
                              chips, mf, hlo_text=txt, notes=cell.notes)
    step = rep.step_time_s
    print(f"[{args.tag}] {cell.notes}")
    print(f"  compute={rep.compute_s:.3e}s memory={rep.memory_s:.3e}s "
          f"collective={rep.collective_s:.3e}s -> {rep.bottleneck}")
    print(f"  useful={rep.useful_ratio:.3f} roofline_frac={rep.roofline_fraction:.4f} "
          f"fits={rep.fits_hbm} (args={rep.args_bytes_pd/2**30:.1f}GB "
          f"temps={rep.temps_bytes_pd/2**30:.1f}GB) compile={wall:.0f}s")
    print("  coll by kind:", {k: f"{v:.2e}" for k, v in rep.coll_by_kind.items()})

    if args.top_collectives:
        from repro.roofline.hlo_stats import _split_computations, _COLL_RE, _dims, _prod
        comps, entry = _split_computations(txt)
        rows = []
        for name, lines in comps.items():
            for line in lines:
                m = _COLL_RE.search(line)
                if m:
                    n = _prod(_dims(m.group(2)))
                    meta = re.search(r'op_name="([^"]*)"', line)
                    rows.append((n * 2, m.group(3), m.group(1), m.group(2),
                                 (meta.group(1)[-80:] if meta else ""), name))
        rows.sort(key=lambda r: -r[0])
        for b, kind, dt, dims, meta, comp in rows[:12]:
            print(f"    {b/2**20:9.1f}MB {kind:18s} {dt}[{dims}]  {meta}")

    os.makedirs("experiments/perf", exist_ok=True)
    out = rep.to_json()
    out["tag"] = args.tag
    with open(f"experiments/perf/{args.arch}__{args.shape}__{args.tag}.json", "w") as f:
        json.dump(out, f, indent=2)


if __name__ == "__main__":
    main()
