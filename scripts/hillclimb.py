"""Perf-iteration driver: rebuild one cell with overrides, lower, analyze,
and log the three roofline terms (experiments/perf/<cell>__<tag>.json).

    PYTHONPATH=src python scripts/hillclimb.py --arch qwen3_moe_235b \
        --shape train_4k --tag baseline [--accum 4] [--no-fsdp] [--kvseq] \
        [--tiered-kv] [--top-collectives]

Capacity-planner mode (``--capacity``): instead of lowering a cell, sweep
tier configurations (2T baseline, 6T alpha ladder, warm/cold codec splits)
through ``simulate_multitenant`` on the skew-flip mix, feed each run's
``fleet_report()`` to the ``CapacityPlanner``, and log the perf-per-dollar
frontier to experiments/capacity/<tag>.json:

    PYTHONPATH=src:. python scripts/hillclimb.py --capacity --tag sweep1 \
        [--server v5e-base] [--operating-years 3] [--fleet-scale 256] \
        [--windows 16] [--seed 0]

Serving-frontend mode (``--serve``): sweep admission budget thresholds x
SLA-class mixes through the ``ContinuousScheduler`` on the serving_slo
burst trace (two tiered-engine replicas each run), print the
TTFT/preemption table, and log the sweep to experiments/serve/<tag>.json:

    PYTHONPATH=src:. python scripts/hillclimb.py --serve --tag sweep1 \
        [--seed 3]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time


def run_capacity(args) -> None:
    """Planner mode: sweep tier configurations, log the frontier JSON."""
    from benchmarks import capacity_frontier
    from repro.core import capacity

    planner = capacity.CapacityPlanner(
        capacity.get_server(args.server),
        operating_period_years=args.operating_years,
        fleet_scale=args.fleet_scale,
    )
    t0 = time.time()
    res = capacity.sweep_frontier(
        capacity_frontier.skewflip_workloads,
        capacity_frontier.skewflip_specs(),
        planner,
        windows=args.windows,
        seed=args.seed,
    )
    wall = time.time() - t0
    res["tag"] = args.tag

    print(f"[{args.tag}] capacity sweep: {len(res['points'])} configs, "
          f"{len(res['frontier'])} on the frontier ({wall:.1f}s)")
    print(f"  server={args.server} years={args.operating_years} "
          f"fleet_scale={args.fleet_scale} windows={args.windows}")
    for p in res["points"]:
        star = "*" if p in res["frontier"] else " "
        print(f"  {star} {p['config']:24s} servers={p['servers']:4d} "
              f"fleet_usd={p['fleet_usd']:12.0f} "
              f"savings={p['savings_pct']:6.2f}% "
              f"p99_penalty={p['p99_penalty_s']:.4f}s "
              f"perf/$={p['perf_per_dollar']:.1f}")
    print(f"  monotone={res['monotone']} dominates_2t={res.get('dominates_2t')} "
          f"margin={res.get('dominance_margin_pct')}pts")

    os.makedirs("experiments/capacity", exist_ok=True)
    out_path = f"experiments/capacity/{args.tag}.json"
    with open(out_path, "w") as f:
        f.write(capacity.frontier_json(res))
    print(f"  -> {out_path}")


def run_serve(args) -> None:
    """Frontend mode: sweep admission thresholds x SLA mixes, log JSON."""
    import dataclasses as dc

    from benchmarks import serving_slo
    from repro.frontend import (
        AdmissionController, ContinuousScheduler, DEFAULT_CLASSES, generate,
    )

    budget_fracs = (0.6, 0.75, 0.9)       # batch-class admission share
    interactive_shares = (0.15, 0.4)      # sla_mix tilt toward tight TTFT
    rows = []
    t0 = time.time()
    for frac in budget_fracs:
        classes = tuple(
            dc.replace(c, budget_frac=frac) if c.name == "batch" else c
            for c in DEFAULT_CLASSES
        )
        for share in interactive_shares:
            tc = dc.replace(
                serving_slo.trace_config(),
                sla_mix=(1.0 - share, share), seed=args.seed,
            )
            cfg, engines = serving_slo._engines()
            sched = ContinuousScheduler(
                engines, generate(tc), cfg.vocab_size,
                classes=classes,
                admission=AdmissionController(classes),
                prefill_chunk_tokens=serving_slo.PREFILL_CHUNK,
            )
            s = sched.run(max_steps=serving_slo.MAX_STEPS).summary()
            rows.append({
                "batch_budget_frac": frac,
                "interactive_share": share,
                "completed": s["completed"],
                "refused": s["refused"],
                "preemptions": s["preemptions"],
                "re_prefill_tokens": s["re_prefill_tokens"],
                "batch_ttft_p99": s["batch"]["ttft_p99"],
                "interactive_ttft_p99": s["interactive"]["ttft_p99"],
                "interactive_slo_hit": s["interactive"]["ttft_slo_hit_rate"],
                "steps": s["steps"],
            })
    wall = time.time() - t0

    print(f"[{args.tag}] serve sweep: {len(rows)} points ({wall:.1f}s)")
    print("  budget_frac int_share done refus preempt int_ttft_p99 int_slo_hit")
    for r in rows:
        print(f"  {r['batch_budget_frac']:11.2f} {r['interactive_share']:9.2f} "
              f"{r['completed']:4d} {r['refused']:5d} {r['preemptions']:7d} "
              f"{r['interactive_ttft_p99']:12.2f} {r['interactive_slo_hit']:11.3f}")

    res = {
        "tag": args.tag,
        "seed": args.seed,
        "trace": "serving_slo burst",
        "sweep": {
            "batch_budget_frac": list(budget_fracs),
            "interactive_share": list(interactive_shares),
        },
        "points": rows,
    }
    os.makedirs("experiments/serve", exist_ok=True)
    out_path = f"experiments/serve/{args.tag}.json"
    with open(out_path, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"  -> {out_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--capacity", action="store_true",
                    help="run the fleet capacity planner sweep instead of "
                         "lowering a cell")
    ap.add_argument("--serve", action="store_true",
                    help="sweep serving-frontend admission thresholds x SLA "
                         "mixes instead of lowering a cell")
    ap.add_argument("--server", default="v5e-base",
                    help="ServerSpec catalog entry for --capacity")
    ap.add_argument("--operating-years", type=float, default=3.0)
    ap.add_argument("--fleet-scale", type=int, default=256)
    ap.add_argument("--windows", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--fsdp", dest="fsdp", action="store_true", default=None)
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--kvseq", dest="kvseq", action="store_true", default=None)
    ap.add_argument("--no-kvseq", dest="kvseq", action="store_false")
    ap.add_argument("--tiered-kv", action="store_true", default=None)
    ap.add_argument("--top-collectives", action="store_true")
    args = ap.parse_args()

    if args.capacity:
        run_capacity(args)
        return
    if args.serve:
        run_serve(args)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required unless --capacity or "
                 "--serve is given")

    import repro.configs as configs
    from repro.configs.base import SHAPES
    from repro.launch import cells as cm
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as ra

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    chips = 512 if args.multi_pod else 256
    parallel = cm.default_parallel(configs.get(args.arch), args.shape, mesh)
    if args.accum is not None or args.fsdp is not None or args.kvseq is not None:
        import dataclasses as dc

        kw = {}
        if args.accum is not None:
            kw["grad_accum"] = args.accum
        if args.fsdp is not None:
            kw["fsdp"] = args.fsdp
        if args.kvseq is not None:
            kw["shard_kv_seq"] = args.kvseq
        parallel = dc.replace(parallel, **kw)

    t0 = time.time()
    cell = cm.build_cell(args.arch, args.shape, mesh, parallel=parallel,
                         tiered_kv=args.tiered_kv)
    compiled = cell.lower().compile()
    wall = time.time() - t0
    txt = compiled.as_text()

    cfg = configs.get(args.arch)
    mf = ra.model_flops_for(cfg, SHAPES[args.shape])
    rep = ra.analyze_compiled(compiled, args.arch, args.shape,
                              "pod2x16x16" if args.multi_pod else "pod16x16",
                              chips, mf, hlo_text=txt, notes=cell.notes)
    step = rep.step_time_s
    print(f"[{args.tag}] {cell.notes}")
    print(f"  compute={rep.compute_s:.3e}s memory={rep.memory_s:.3e}s "
          f"collective={rep.collective_s:.3e}s -> {rep.bottleneck}")
    print(f"  useful={rep.useful_ratio:.3f} roofline_frac={rep.roofline_fraction:.4f} "
          f"fits={rep.fits_hbm} (args={rep.args_bytes_pd/2**30:.1f}GB "
          f"temps={rep.temps_bytes_pd/2**30:.1f}GB) compile={wall:.0f}s")
    print("  coll by kind:", {k: f"{v:.2e}" for k, v in rep.coll_by_kind.items()})

    if args.top_collectives:
        from repro.roofline.hlo_stats import _split_computations, _COLL_RE, _dims, _prod
        comps, entry = _split_computations(txt)
        rows = []
        for name, lines in comps.items():
            for line in lines:
                m = _COLL_RE.search(line)
                if m:
                    n = _prod(_dims(m.group(2)))
                    meta = re.search(r'op_name="([^"]*)"', line)
                    rows.append((n * 2, m.group(3), m.group(1), m.group(2),
                                 (meta.group(1)[-80:] if meta else ""), name))
        rows.sort(key=lambda r: -r[0])
        for b, kind, dt, dims, meta, comp in rows[:12]:
            print(f"    {b/2**20:9.1f}MB {kind:18s} {dt}[{dims}]  {meta}")

    os.makedirs("experiments/perf", exist_ok=True)
    out = rep.to_json()
    out["tag"] = args.tag
    with open(f"experiments/perf/{args.arch}__{args.shape}__{args.tag}.json", "w") as f:
        json.dump(out, f, indent=2)


if __name__ == "__main__":
    main()
